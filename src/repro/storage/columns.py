"""Device column store (paper §5-6): per-column physical representation.

GQ-Fast's central claim is that heavyweight compression and fully pipelined
execution *coexist*: dense encodings (BCA / the Huffman-class dictionary
substitute) have no random access, so decompression must happen inside the
operator, never as a load-time pass. This module makes "decoded" vs "packed"
a per-column *physical property* that the rest of the engine is agnostic to:

  * :class:`DenseColumn`    — full-width int32/float32 device array (the old
    decoded-CSR layout; also the universal fallback target).
  * :class:`PackedColumn`   — BCA on device: little-endian ``width``-bit values
    in a uint32 word stream (`core.fragments._pack_words` layout). Decoded
    block-at-a-time in VMEM by the fused kernels, or wholesale by
    ``materialize()`` for strategies without a packed path.
  * :class:`DictPackedColumn` — the DictBCA/Huffman substitute: a global
    frequency-sorted dictionary plus fixed-width packed dictionary indices.
    (The host DictBCA codec's escape coding is a byte-stream space refinement
    that needs a column-wide cumsum; the device layout keeps the block-local
    decode property instead: index width = ⌈log2 #distinct⌉.)

Uniform contract every column kind honors:

  * ``materialize()`` — the full decoded device array (``out_dtype``).
  * ``gather(ids)``   — decoded values at ``ids`` without materializing the
    column (double-word bit extraction + optional dictionary lookup).
  * ``device_nbytes`` — real bytes the column occupies in device memory.

Strategies with no packed execution path (fragment_loop scalar loops, the
edge-sharded distributed variant) call ``materialize()`` once per prepare /
shard — a correct, documented fallback (DESIGN.md §Storage).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ref import bitgather_ref as _gather_packed
from ..robust import faults as _faults

#: Re-reads a verified materialize attempts before declaring the corruption
#: persistent and raising IntegrityError (a transient flip heals from the
#: memo; repeated mismatches mean the stored state itself is bad).
READ_HEAL_RETRIES = 2


def _quarantine_check(col) -> None:
    if col._quarantined:
        from ..obs.metrics import REGISTRY
        from ..robust.errors import IntegrityError

        t, k, name = col._addr or ("?", "?", "?")
        REGISTRY.counter("robust.integrity.quarantined_reads").inc()
        raise IntegrityError(
            f"column I_{t}.{k}/{name} is quarantined pending repair",
            table=t, key=k, column=name, quarantined=True,
        )


def _verify_read(col, value, reread):
    """Integrity-verified read (active only once a manifest is attached,
    ``storage/integrity.py``): hash the decoded bytes against the recorded
    digest. On mismatch, re-read up to :data:`READ_HEAL_RETRIES` times — the
    memo holds the true decode, so a *transient* corruption (a fault-injected
    flipped read) heals silently (``robust.integrity.read_heals``); a
    mismatch that survives every re-read is persistent and raises
    :class:`~repro.robust.errors.IntegrityError` rather than letting the bad
    bytes enter a trace. Tracers pass through unverified (nothing concrete
    to hash)."""
    if isinstance(value, jax.core.Tracer):
        return value
    from .integrity import crc32c

    if crc32c(np.asarray(value)) == col._expected_crc:
        return value
    from ..obs.metrics import REGISTRY
    from ..robust.errors import IntegrityError

    REGISTRY.counter("robust.integrity.read_failures").inc()
    actual = None
    for _ in range(READ_HEAL_RETRIES):
        value = reread()
        actual = crc32c(np.asarray(value))
        if actual == col._expected_crc:
            REGISTRY.counter("robust.integrity.read_heals").inc()
            return value
    t, k, name = col._addr or ("?", "?", "?")
    raise IntegrityError(
        f"decoded column I_{t}.{k}/{name} failed checksum verification",
        table=t, key=k, column=name,
        expected_crc=col._expected_crc, actual_crc=actual,
    )


def _memo_materialize(col, decode):
    """Memoize whole-column decodes so repeated prepares of fallback
    strategies (densify_plan, shard_edges) share one decoded copy instead of
    pinning a fresh full-width array per prepared query. Traced values
    (decode requested inside a jit trace, e.g. ``LCol.array`` in a complex
    measure expression) are never cached — a tracer escaping its trace would
    poison every later call.

    Fault site ``storage.materialize``: fires before the decode; corrupt-mode
    specs transform only the *returned* value, after the memo read/write, so
    the cached copy always holds the true decode (corrupt-then-restore).
    With an integrity manifest attached, every concrete return value is
    checksum-verified (:func:`_verify_read`) — the corrupt site turns from a
    silent wrong-answer generator into a detected (and usually self-healed)
    event."""
    _faults.fire("storage.materialize", kind=getattr(col, "kind", "?"))
    if col._expected_crc is not None or col._quarantined:
        _quarantine_check(col)
        if col._dense is None:
            out = decode()
            if isinstance(out, jax.core.Tracer):
                return out
            col._dense = out
        reread = lambda: _faults.corrupt("storage.materialize", col._dense)  # noqa: E731
        return _verify_read(col, reread(), reread)
    if col._dense is None:
        out = decode()
        if isinstance(out, jax.core.Tracer):
            return out
        col._dense = out
        return _faults.corrupt("storage.materialize", out)
    return _faults.corrupt("storage.materialize", col._dense)


class DeviceColumn:
    """Abstract device-resident column; see module docstring for the contract."""

    kind: str = "abstract"
    count: int

    # integrity state (class-level defaults = zero-cost until a manifest is
    # attached via storage/integrity.py; attach sets instance attributes)
    _expected_crc: int | None = None  # decoded-view CRC32C to verify reads
    _addr: tuple | None = None  # (table, key, column) for error context
    _quarantined: bool = False  # scrubber-detected, pending repair

    def materialize(self) -> jnp.ndarray:
        raise NotImplementedError

    def gather(self, ids) -> jnp.ndarray:
        raise NotImplementedError

    @property
    def device_nbytes(self) -> int:
        raise NotImplementedError

    @property
    def materialized_nbytes(self) -> int:
        """Bytes of the decoded fallback copy currently pinned by the
        ``materialize()`` memo (0 when no fallback strategy has decoded this
        column). Reported separately from ``device_nbytes`` so the space
        report stays honest: after a fragment_loop/distributed prepare a
        packed column occupies packed + dense bytes."""
        d = getattr(self, "_dense", None)
        return int(d.size) * d.dtype.itemsize if d is not None else 0


@dataclass(eq=False)
class DenseColumn(DeviceColumn):
    """Fully decoded device array — zero-cost materialize."""

    array: Any  # jnp.ndarray

    kind = "dense"

    @property
    def count(self) -> int:
        return int(self.array.shape[0])

    def materialize(self) -> jnp.ndarray:
        if self._expected_crc is not None or self._quarantined:
            # a dense column IS its own storage: there is no memo to heal a
            # mismatch from, so a failed verification is always persistent
            _quarantine_check(self)
            return _verify_read(self, self.array, lambda: self.array)
        return self.array

    def gather(self, ids) -> jnp.ndarray:
        return self.array[jnp.asarray(ids)]

    @property
    def device_nbytes(self) -> int:
        return int(self.array.size) * self.array.dtype.itemsize


@dataclass(eq=False)
class PackedColumn(DeviceColumn):
    """BCA device layout: ``count`` values at ``width`` bits in uint32 words."""

    words: Any  # jnp.ndarray uint32
    width: int
    count: int
    out_dtype: Any = jnp.int32
    _dense: Any = field(default=None, repr=False)  # materialize() memo

    kind = "packed"

    def materialize(self) -> jnp.ndarray:
        from ..kernels import ops as K

        return _memo_materialize(
            self,
            lambda: K.bitunpack(self.words, self.width, self.count).astype(
                self.out_dtype
            ),
        )

    def gather(self, ids) -> jnp.ndarray:
        return _gather_packed(self.words, self.width, ids).astype(self.out_dtype)

    @property
    def device_nbytes(self) -> int:
        return int(self.words.size) * 4


@dataclass(eq=False)
class DictPackedColumn(DeviceColumn):
    """Dictionary + packed indices: value[i] = dictionary[unpack(words)[i]].

    ``dictionary`` is frequency-sorted (popular values get small indices) so
    the index stream matches the DictBCA codec's head distribution; it lives
    in VMEM during fused decode (small: one slot per distinct value)."""

    words: Any  # jnp.ndarray uint32 — packed dictionary indices
    width: int  # ⌈log2 #distinct⌉
    count: int
    dictionary: Any  # jnp.ndarray (out dtype) — index → value
    _dense: Any = field(default=None, repr=False)  # materialize() memo

    kind = "dict"

    def materialize(self) -> jnp.ndarray:
        from ..kernels import ops as K

        return _memo_materialize(
            self,
            lambda: jnp.take(
                self.dictionary, K.bitunpack(self.words, self.width, self.count)
            ),
        )

    def gather(self, ids) -> jnp.ndarray:
        return jnp.take(self.dictionary, _gather_packed(self.words, self.width, ids))

    @property
    def device_nbytes(self) -> int:
        return int(self.words.size) * 4 + int(self.dictionary.size) * self.dictionary.dtype.itemsize
