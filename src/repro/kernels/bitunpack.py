"""Pallas TPU kernel: BCA fragment decode (paper §5 bit-aligned compressed array).

Layout contract (written by ``core.fragments._pack_words``): values are packed
little-endian at ``width`` bits each into a uint32 word stream. The kernel
decodes 1024 values per grid step. Because 1024·width ≡ 0 (mod 32), every
1024-value output block starts and ends word-aligned: the input block is exactly
32·width words and no halo is needed.

TPU mapping: the output block is shaped (32, 32) — 32 groups of 32 values — and
the input block (32, width) words, because every 32 consecutive values consume
exactly ``width`` words with a *fixed* intra-group bit-offset pattern. The two
word operands per output column are therefore **static** column selects
(unrolled slices, no dynamic gather), followed by vectorized shift/mask on the
VPU. This is the TPU-native replacement for the paper's sequential decode loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

GROUP = 32  # values per group; GROUP*width bits = width words
GROUPS_PER_BLOCK = 32  # 1024 values per grid step
BLOCK_VALUES = GROUP * GROUPS_PER_BLOCK


@functools.lru_cache(maxsize=33)
def _group_pattern(width: int):
    """Static per-column decode pattern for one 32-value group: which word
    holds each value's low bits, which its high bits, and the scalar shift
    amounts. Depends only on ``width`` — hoisted out of the kernel body (and
    memoized across traces) so no trace re-derives it and the kernel carries
    only scalar shift constants, no iota/mod/select ops per block."""
    bit0 = np.arange(GROUP) * width
    w_lo = (bit0 // 32).astype(np.int32)  # word holding the low bits
    w_hi = np.minimum(w_lo + 1, width - 1)
    off = (bit0 % 32).astype(np.int64)  # python ints below; no uint wrap
    mask = np.uint32((1 << width) - 1) if width < 32 else np.uint32(0xFFFFFFFF)
    return w_lo, w_hi, off, mask


def decode_groups(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """In-kernel group decode: (G, width) uint32 words → (G, GROUP) int32 values.

    Every row holds GROUP consecutive values (GROUP·width bits = width words)
    with a *fixed* intra-group bit-offset pattern, so the two word operands per
    output column are static column selects (unrolled, no dynamic gather on
    TPU) and the per-column shifts are scalar constants resolved at trace time
    (:func:`_group_pattern`) — the whole width-mask construction happens in
    Python, never as in-kernel vector ops. Shared by the standalone
    ``bitunpack`` kernel and the decode-fused SpMV/SpMM kernels."""
    w_lo, w_hi, off, mask = _group_pattern(width)
    cols = []
    for c in range(GROUP):
        lo = words[:, int(w_lo[c])]
        o = int(off[c])
        if o == 0:  # value starts word-aligned: no straddle term
            v = lo
        else:
            v = (lo >> jnp.uint32(o)) | (words[:, int(w_hi[c])] << jnp.uint32(32 - o))
        cols.append(v & mask)
    return jnp.stack(cols, axis=1).astype(jnp.int32)


def _kernel(width: int, packed_ref, out_ref):
    out_ref[...] = decode_groups(packed_ref[...], width)


@functools.partial(jax.jit, static_argnames=("width", "count", "interpret"))
def bitunpack(packed: jnp.ndarray, width: int, count: int, interpret: bool = False) -> jnp.ndarray:
    """Decode ``count`` ``width``-bit values from a uint32 word stream."""
    assert 1 <= width <= 32
    n_blocks = max(1, -(-count // BLOCK_VALUES))
    words_needed = n_blocks * GROUPS_PER_BLOCK * width
    pad = words_needed - packed.shape[0]
    if pad > 0:
        packed = jnp.concatenate([packed, jnp.zeros(pad, jnp.uint32)])
    packed2d = packed[:words_needed].reshape(n_blocks * GROUPS_PER_BLOCK, width)

    out = pl.pallas_call(
        functools.partial(_kernel, width),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((GROUPS_PER_BLOCK, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((GROUPS_PER_BLOCK, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * GROUPS_PER_BLOCK, GROUP), jnp.int32),
        interpret=interpret,
    )(packed2d)
    return out.reshape(-1)[:count]
