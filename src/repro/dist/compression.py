"""Compressed collectives: error-feedback int8 all-reduce (DESIGN.md §6).

``compressed_psum`` quantizes the local contribution to int8 with a per-tensor
absmax scale before the all-reduce, and returns the quantization residual as
carry-over *error feedback* (Seide et al. / EF-SGD): adding the residual into
the next step's contribution makes the long-run bias vanish while each step
moves 4× fewer bytes over the wire than fp32."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(grad: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """One EF-int8 mean-all-reduce step inside a shard_map/pmap body.

    Returns ``(mean, new_err)``: the cross-device mean of the dequantized
    contributions, and this device's residual ``(grad + err) − dequant``
    to feed back next step."""
    x = grad + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    n = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    mean = jax.lax.psum(deq, axis_name) / n
    return mean, x - deq
