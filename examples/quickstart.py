"""Quickstart: build a synthetic PubMed-like graph database, run the paper's
relationship queries through the GQ-Fast JAX engine, and cross-check against
the materializing reference engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.core.reference import run_sql
from repro.data import synth_graph as SG


def main() -> None:
    print("== GQ-Fast quickstart ==")
    schema = SG.make_pubmed(n_docs=20_000, n_terms=800, n_authors=5_000, seed=7)
    db = GQFastDatabase(schema, account_space=True)
    rep = db.space_report()
    print(f"loaded: DT={schema.relationships['DT'].num_rows} rows, "
          f"DA={schema.relationships['DA'].num_rows} rows; "
          f"GQ-Fast indices: {rep['total_bytes']/1e6:.1f} MB")
    for iname, idx in rep["indexes"].items():
        encs = {c: v["encoding"] for c, v in idx["columns"].items()}
        print(f"  {iname}: {encs}")

    eng = GQFastEngine(db)

    print("\n-- AS query (author similarity, author 17) --")
    top = eng.query_topk(SG.QUERY_AS, k=5, a0=17)
    for a, s in top:
        print(f"  author {a:6d}  score {s:10.2f}")

    print("\n-- AD query (authors publishing on terms 3 ∧ 9) --")
    top = eng.query_topk(SG.QUERY_AD, k=5, t1=3, t2=9)
    for a, s in top:
        print(f"  author {a:6d}  papers {int(s)}")

    print("\n-- sanity: engine == reference on AS --")
    got = eng.query(SG.QUERY_AS, a0=17)
    ref = run_sql(schema, SG.QUERY_AS, {"a0": 17})
    print("  match:", np.allclose(got, ref, rtol=1e-4, atol=1e-4))

    print("\n-- prepared statement, executed for 4 different authors --")
    pq = eng.prepare(SG.QUERY_AS)
    batch = pq.execute_batch(a0=np.asarray([3, 5, 17, 40]))
    print("  batch result:", batch.shape, "rows nonzero:",
          [int((batch[i] != 0).sum()) for i in range(4)])


if __name__ == "__main__":
    main()
