"""Structured span tracing for the query lifecycle (DESIGN.md §Observability).

A :class:`Tracer` records a tree of :class:`Span`\\s — prepare phases
(``parse`` / ``plan`` / ``lower`` / ``compile``), per-execution ``execute``
spans, and the per-IR-op spans the profiling walk emits from
``core.executor.walk_ir``. The active tracer lives in a :mod:`contextvars`
ContextVar, so recording composes with nested calls and never leaks across
threads/async contexts.

Zero-overhead contract: tracing is **off by default** and the disabled fast
path allocates nothing — :func:`span` returns the module-level
:data:`NULL_SPAN` singleton (a no-op context manager with ``__slots__ = ()``),
and :func:`annotate` is one ContextVar read plus a ``None`` check. Nothing in
this module imports jax at module load; :meth:`Span.fence` imports it lazily.

jit safety: spans record *around* traced calls, never inside them — the
instrumented walker guards on ``jax.core.trace_state_clean()`` and degrades to
a plain pass-through under any trace, so a recording tracer can stay enabled
across ``jax.jit`` boundaries without corrupting timings or leaking tracers
into host-side state.
"""
from __future__ import annotations

import json
import time
from contextvars import ContextVar

_TRACER: ContextVar["Tracer | None"] = ContextVar("repro_obs_tracer", default=None)


class Span:
    """One timed node: wall time (``__exit__`` − ``__enter__``) plus the
    optional device-sync'd kernel time recorded by :meth:`fence` — the
    ``block_until_ready``-fenced duration from span entry to device-done."""

    __slots__ = ("name", "meta", "children", "status", "t0", "wall_ms", "kernel_ms")

    def __init__(self, name: str, **meta):
        self.name = name
        self.meta = dict(meta)
        self.children: list[Span] = []
        self.status = "ok"
        self.t0 = 0.0
        self.wall_ms: float | None = None
        self.kernel_ms: float | None = None

    def annotate(self, **kv) -> None:
        self.meta.update(kv)

    def fence(self, value):
        """Block until the device work backing ``value`` completes and record
        the fenced duration since span entry as ``kernel_ms``. Returns
        ``value`` so call sites can fence inline. Never call on a jax tracer
        (guard with ``trace_state_clean`` — see module docstring)."""
        import jax

        jax.block_until_ready(value)
        self.kernel_ms = (time.perf_counter() - self.t0) * 1e3
        return value

    def __enter__(self) -> "Span":
        tr = _TRACER.get()
        if tr is not None:
            tr._attach(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.wall_ms = (time.perf_counter() - self.t0) * 1e3
        if exc_type is not None:
            self.status = f"error:{exc_type.__name__}"
        tr = _TRACER.get()
        if tr is not None:
            tr._detach(self)
        return False  # never swallow the exception

    def self_wall_ms(self) -> float | None:
        """Wall time minus direct children — the span's own share."""
        if self.wall_ms is None:
            return None
        child = sum(c.wall_ms or 0.0 for c in self.children)
        return max(self.wall_ms - child, 0.0)

    def to_dict(self) -> dict:
        d: dict = {"name": self.name, "status": self.status}
        if self.wall_ms is not None:
            d["wall_ms"] = round(self.wall_ms, 4)
        if self.kernel_ms is not None:
            d["kernel_ms"] = round(self.kernel_ms, 4)
        if self.meta:
            d["meta"] = {
                k: (v if isinstance(v, (int, float, str, bool, type(None))) else str(v))
                for k, v in self.meta.items()
            }
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


class _NullSpan:
    """The disabled-tracer fast path: a shared, allocation-free no-op span."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def annotate(self, **kv) -> None:
        pass

    def fence(self, value):
        return value


NULL_SPAN = _NullSpan()


class Tracer:
    """Span sink: roots + the open-span stack. Exception-safe by construction:
    ``Span.__exit__`` pops everything above (and including) itself, so a span
    abandoned by an exception mid-subtree cannot corrupt later nesting."""

    def __init__(self):
        self.roots: list[Span] = []
        self._stack: list[Span] = []

    def _attach(self, sp: Span) -> None:
        (self._stack[-1].children if self._stack else self.roots).append(sp)
        self._stack.append(sp)

    def _detach(self, sp: Span) -> None:
        while self._stack:
            top = self._stack.pop()
            if top is sp:
                break

    def current_span(self) -> Span | None:
        return self._stack[-1] if self._stack else None

    def iter_spans(self):
        """All spans, preorder."""
        stack = list(reversed(self.roots))
        while stack:
            sp = stack.pop()
            yield sp
            stack.extend(reversed(sp.children))

    def to_dict(self) -> dict:
        return {"spans": [sp.to_dict() for sp in self.roots]}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


def current() -> Tracer | None:
    """The active tracer, or None when tracing is disabled (the default)."""
    return _TRACER.get()


def enabled() -> bool:
    return _TRACER.get() is not None


def span(name: str, **meta):
    """Open a span under the active tracer; the :data:`NULL_SPAN` no-op when
    tracing is disabled. Use as ``with span("lower") as sp: ...``."""
    if _TRACER.get() is None:
        return NULL_SPAN
    return Span(name, **meta)


def annotate(**kv) -> None:
    """Attach metadata to the innermost open span (no-op when disabled)."""
    tr = _TRACER.get()
    if tr is not None and tr._stack:
        tr._stack[-1].meta.update(kv)


class recording:
    """``with recording() as tracer: ...`` — install a tracer for the block.

    Nests: an inner ``recording`` shadows the outer one for its extent (the
    outer tracer resumes afterwards — ContextVar token reset)."""

    def __init__(self, tracer: Tracer | None = None):
        self.tracer = tracer if tracer is not None else Tracer()
        self._token = None

    def __enter__(self) -> Tracer:
        self._token = _TRACER.set(self.tracer)
        return self.tracer

    def __exit__(self, exc_type, exc, tb) -> bool:
        _TRACER.reset(self._token)
        return False
