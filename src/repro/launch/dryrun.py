import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on the
production mesh with 512 placeholder host devices (the two lines above MUST
run before any jax import — jax locks device count at first init).

Per cell: ``jax.jit(fn, in_shardings, out_shardings).lower(*abstract_args)
.compile()`` then record memory_analysis / cost_analysis / per-collective
bytes parsed from the compiled HLO into artifacts/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = False, variant: str = "") -> dict:
    import jax

    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import collective_bytes_from_hlo

    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_name = f"{arch_id}__{shape_id}__{mesh_name}" + (f"__{variant}" if variant else "")
    path = os.path.join(out_dir, f"{cell_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    arch = get_arch(arch_id)
    rec: dict = {
        "arch": arch_id, "shape": shape_id, "mesh": mesh_name, "variant": variant,
        "time": time.time(),
    }
    skip = arch.skip_reason(shape_id)
    if skip:
        rec.update(status="skipped", reason=skip)
        _write(path, rec)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        # cell construction OUTSIDE the mesh context: under set_mesh, plain
        # jnp.asarray replicates real arrays across all 512 placeholder devices
        cell = arch.make_cell(shape_id, mesh, variant)
        with jax.set_mesh(mesh):
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)

        rec.update(
            status="ok",
            kind=cell.kind,
            model_flops=cell.model_flops,
            notes=cell.notes,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_dict(mem),
            flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            bytes_accessed=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
            collectives=coll,
        )
        print(f"[dryrun] {cell_name}: OK  lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops/dev {rec['flops']:.3e} bytes/dev {rec['bytes_accessed']:.3e} "
              f"coll {sum(coll.values()):.3e}B")
        print(f"  memory_analysis: {rec['memory']}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-4000:])
        print(f"[dryrun] {cell_name}: ERROR {type(e).__name__}: {e}")
    _write(path, rec)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    return out


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS, all_cells

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required unless --all"
        shapes = [args.shape] if args.shape else ARCHS[args.arch].shape_ids
        cells = [(args.arch, s) for s in shapes]

    results = []
    for mp in meshes:
        for aid, sid in cells:
            results.append(run_cell(aid, sid, mp, args.out, args.skip_existing, args.variant))
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    err = [r for r in results if r["status"] == "error"]
    print(f"\n[dryrun] {ok} ok, {sk} skipped, {len(err)} errors / {len(results)} cells")
    for r in err:
        print(f"  ERROR {r['arch']}__{r['shape']}__{r['mesh']}: {r['error']}")
    raise SystemExit(1 if err else 0)


if __name__ == "__main__":
    main()
