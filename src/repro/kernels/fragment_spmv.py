"""Pallas TPU kernel: fused fragment join-aggregate (one relationship hop).

y[dst] += w[src] · m over the edge list of a GQ-Fast index — the frontier SpMV
that every ⋈/⋉+γ hop lowers to (DESIGN.md §4). The frontier vector ``w`` and the
dense accumulator ``y`` live in VMEM for the whole pass (entity domains up to a
few M fit v5e's 16 MB VMEM in fp32 tiles); the edge arrays stream through in
blocks. The output BlockSpec maps every grid step to the same block — the
canonical Pallas accumulate-over-grid pattern — so the scatter-add stays on-chip
instead of bouncing to HBM per block (the paper's "spinlocked shared array",
contention-free).

Gather (jnp.take) and scatter-add (segment_sum) inside the body lower to Mosaic
dynamic-gather / scatter-add; on TPU generations without scatter support,
``ops.fragment_spmv`` falls back to the pure-XLA path (same math, same layout).
Edges arrive sorted by src (CSR order) which makes the gather quasi-sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EDGE_BLOCK = 4096


def _kernel(n_dst: int, w_ref, src_ref, dst_ref, m_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    m = m_ref[...]
    prod = jnp.take(w, src, fill_value=0.0) * m
    out_ref[...] += jax.ops.segment_sum(prod, dst, num_segments=n_dst)


@functools.partial(jax.jit, static_argnames=("n_dst", "interpret"))
def fragment_spmv(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst_ids: jnp.ndarray,
    measures: jnp.ndarray,
    n_dst: int,
    interpret: bool = False,
) -> jnp.ndarray:
    E = src_ids.shape[0]
    pad = (-E) % EDGE_BLOCK
    if pad:
        # padding edges: src points past the frontier (gather fill 0), measure 0
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, weights.shape[0], jnp.int32)])
        dst_ids = jnp.concatenate([dst_ids, jnp.zeros(pad, jnp.int32)])
        measures = jnp.concatenate([measures, jnp.zeros(pad, jnp.float32)])
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)

    return pl.pallas_call(
        functools.partial(_kernel, n_dst),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(weights.shape, lambda i: (0,)),  # frontier resident
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_dst,), lambda i: (0,)),  # accumulate over grid
        out_shape=jax.ShapeDtypeStruct((n_dst,), jnp.float32),
        interpret=interpret,
    )(weights, src_ids, dst_ids, measures)
