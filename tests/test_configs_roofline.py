"""Config-layer + roofline-analysis unit tests: cell construction on a tiny
mesh, spec trees align with state trees, HLO collective parsing, loop-trip
correction, and the registry covering all assigned cells."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, ASSIGNED, all_cells, get_arch
from repro.roofline.analysis import (
    Roofline,
    collective_bytes_from_hlo,
    loop_trips,
    roofline_from_record,
)


def _tiny_mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((1, 1), ("data", "model"))


def test_registry_covers_assignment():
    assert len(ASSIGNED) == 10
    cells = all_cells()
    # 10 assigned archs × 4 shapes + 4 gqfast cells
    assert len(cells) == 44
    for aid in ["codeqwen1.5-7b", "qwen2.5-3b", "llama3-8b", "arctic-480b",
                "olmoe-1b-7b", "mace", "egnn", "equiformer-v2", "schnet", "din"]:
        assert len(ARCHS[aid].shape_ids) == 4


def test_long500k_skip_documented():
    for aid in ["codeqwen1.5-7b", "qwen2.5-3b", "llama3-8b", "arctic-480b",
                "olmoe-1b-7b"]:
        reason = get_arch(aid).skip_reason("long_500k")
        assert reason and "full-attention" in reason
        assert get_arch(aid).skip_reason("train_4k") is None


@pytest.mark.parametrize("aid,shape", [
    ("llama3-8b", "train_4k"), ("qwen2.5-3b", "decode_32k"),
    ("arctic-480b", "prefill_32k"), ("schnet", "molecule"),
    ("din", "retrieval_cand"),
])
def test_cell_construction_abstract(aid, shape):
    """Cells build with ShapeDtypeStruct args (no allocation) and sharding
    trees that match the arg trees."""
    mesh = _tiny_mesh()
    cell = get_arch(aid).make_cell(shape, mesh)
    assert len(cell.args) == len(cell.in_shardings)
    for arg, sh in zip(cell.args, cell.in_shardings):
        a_leaves = jax.tree_util.tree_leaves(arg)
        s_leaves = jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: hasattr(x, "spec")
        )
        assert len(a_leaves) == len(s_leaves), (aid, shape)
    assert cell.model_flops and cell.model_flops > 0


def test_collective_parser():
    hlo = """
  %ag = f32[2048,1,128]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = bf16[64,32]{1,0} all-reduce-start(%y)
  %ar.2 = bf16[64,32]{1,0} all-reduce-done(%ar.1)
  %cp = u32[16]{0} collective-permute(%z)
  %notacoll = f32[8,8]{1,0} add(%a, %b)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 2048 * 128 * 4
    assert out["all-reduce"] == 64 * 32 * 2  # -start counted once
    assert out["collective-permute"] == 16 * 4
    assert "add" not in out and len(out) == 3


def test_loop_trips_correction():
    rec_lm = {"arch": "llama3-8b", "kind": "train", "notes": "micro=8 seq_shard=True"}
    assert loop_trips(rec_lm) == 32 * 8
    rec_dec = {"arch": "llama3-8b", "kind": "decode", "notes": ""}
    assert loop_trips(rec_dec) == 32
    rec_gnn = {"arch": "schnet", "kind": "train", "notes": ""}
    assert loop_trips(rec_gnn) == 1
    rec_gq = {"arch": "gqfast-pubmed", "kind": "serve", "notes": ""}
    assert loop_trips(rec_gq) == 1


def test_roofline_terms_and_dominant():
    rec = {"arch": "schnet", "kind": "train", "notes": "",
           "flops": 197e12, "bytes_accessed": 819e9 * 2,
           "collectives": {"all-reduce": 50e9 * 3}}
    rl = roofline_from_record(rec)
    assert abs(rl.compute_s - 1.0) < 1e-6
    assert abs(rl.memory_s - 2.0) < 1e-6
    assert abs(rl.collective_s - 3.0) < 1e-6
    assert rl.dominant == "collective" and rl.bound_s == rl.collective_s


def test_mesh_factory_requires_devices():
    from repro.launch.mesh import make_production_mesh

    with pytest.raises(RuntimeError, match="512"):
        make_production_mesh(multi_pod=True)  # 1-device test process


def test_lm_state_sharding_tree_matches_state():
    from repro.dist.sharding import lm_state_shardings
    from repro.models.transformer import TransformerConfig, init_params
    from repro.optim.adamw import AdamWConfig, adamw_init

    cfg = TransformerConfig("t", 2, 64, 4, 2, 128, 97, d_head=16, remat=False)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    opt = jax.eval_shape(lambda: adamw_init(params, AdamWConfig()))
    mesh = _tiny_mesh()
    sh = lm_state_shardings((params, opt), mesh, cfg.n_kv_heads)
    assert jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, (params, opt))
    ) == jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, sh, is_leaf=lambda x: hasattr(x, "spec"))
    )
