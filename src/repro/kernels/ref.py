"""Pure-jnp oracles for every Pallas kernel (allclose targets for the sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitunpack_ref(packed: jnp.ndarray, width: int, count: int) -> jnp.ndarray:
    """Decode little-endian ``width``-bit values from uint32 words.

    Value i occupies bits [i*width, (i+1)*width) of the word stream; a value may
    straddle two words. Returns int32[count] (width <= 31 supported on-device;
    the host codec handles wider)."""
    idx = jnp.arange(count, dtype=jnp.uint32)
    bit0 = idx * jnp.uint32(width)
    w0 = (bit0 >> 5).astype(jnp.int32)
    off = (bit0 & jnp.uint32(31)).astype(jnp.uint32)
    lo = packed[w0]
    hi = packed[jnp.minimum(w0 + 1, packed.shape[0] - 1)]
    # 64-bit-free double-word extraction: value = (lo >> off) | (hi << (32-off)),
    # with the straddle term vanishing under the width mask when off == 0 or the
    # value fits entirely in ``lo``.
    word = jnp.where(off == 0, lo, (lo >> off) | _safe_shl(hi, jnp.uint32(32) - off))
    mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
    return (word & mask).astype(jnp.int32)


def _safe_shl(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """x << s with s possibly 32 (→ 0), avoiding UB on 32-bit shifts."""
    return jnp.where(s >= 32, jnp.uint32(0), x << (s & jnp.uint32(31)))


def fragment_spmv_ref(
    weights: jnp.ndarray,  # f32[n_src]
    src_ids: jnp.ndarray,  # i32[E]
    dst_ids: jnp.ndarray,  # i32[E]
    measures: jnp.ndarray,  # f32[E]
    n_dst: int,
    op: str = "sum",
) -> jnp.ndarray:
    """One relationship hop: y[dst] = ⊕_edges w[src] ⊗ m (the frontier SpMV),
    with the combine op ⊕ selected by the aggregation semiring."""
    ws = jnp.take(weights, src_ids)
    if op == "sum":
        return jax.ops.segment_sum(ws * measures, dst_ids, num_segments=n_dst)
    if op == "bool":
        ew = ((ws > 0) & (measures != 0)).astype(jnp.float32)
        return jax.ops.segment_max(ew, dst_ids, num_segments=n_dst)
    zero = float("inf") if op == "min" else float("-inf")
    ew = jnp.where(ws == zero, zero, ws * measures)  # ∞·0 guard
    seg = jax.ops.segment_min if op == "min" else jax.ops.segment_max
    return seg(ew, dst_ids, num_segments=n_dst)


def bitmap_and_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Word-wise AND of two uint32 bitmap word arrays."""
    return a & b


def bitmap_and_popcount_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Total set bits of (a & b) — merge-intersection cardinality (paper §6.1)."""
    return jnp.sum(jax.lax.population_count(a & b).astype(jnp.int32))
