"""Fault-tolerant training loop.

Production behaviours, all exercised by tests on CPU:
  * checkpoint every ``ckpt_every`` steps (atomic; retention) + final;
  * resume-from-latest: bit-identical continuation (deterministic data sharding
    keyed by (seed, step) — a replacement host replays the same stream);
  * preemption: SIGTERM/SIGINT triggers an immediate checkpoint then a clean
    stop (the TPU-pod eviction pattern);
  * straggler telemetry: per-step wall time EWMA + outlier log — at real scale
    this feeds the scheduler; here it is recorded in history.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.manager import CheckpointManager
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0


@dataclass
class TrainResult:
    step: int
    history: list[dict] = field(default_factory=list)
    preempted: bool = False
    resumed_from: int | None = None


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig):
    """loss_fn(params, batch) -> (loss, metrics). Returns jitted step fn."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_m = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_m}

    return jax.jit(step, donate_argnums=(0, 1))


def train(
    params,
    loss_fn: Callable,
    data_fn: Callable[[int], Any],  # step -> batch (deterministic by step)
    loop_cfg: TrainLoopConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    resume: bool = True,
    preempt_at: int | None = None,  # test hook: simulate preemption
) -> tuple[Any, TrainResult]:
    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.ckpt_keep)
    # the jitted step donates its inputs; keep the caller's pytree alive
    params = jax.tree.map(lambda x: x + 0, params)
    opt_state = adamw_init(params, opt_cfg)
    start = 0
    resumed_from = None
    if resume and mgr.latest_step() is not None:
        (params, opt_state), meta = mgr.restore((params, opt_state))
        start = int(meta["step"])
        resumed_from = start

    step_fn = make_train_step(loss_fn, opt_cfg)
    result = TrainResult(step=start, resumed_from=resumed_from)

    stop = {"flag": False}

    def _handler(signum, frame):
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:
            pass  # non-main thread (tests)

    ewma = None
    try:
        for step in range(start, loop_cfg.total_steps):
            if preempt_at is not None and step == preempt_at:
                stop["flag"] = True
            if stop["flag"]:
                mgr.save(step, (params, opt_state))
                result.preempted = True
                result.step = step
                return params, result
            t0 = time.perf_counter()
            batch = data_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else (
                loop_cfg.straggler_ewma * ewma + (1 - loop_cfg.straggler_ewma) * dt
            )
            rec = {
                "step": step,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics.get("grad_norm", 0.0)),
                "step_time": dt,
                "straggler": bool(dt > loop_cfg.straggler_factor * ewma and step > start + 3),
            }
            result.history.append(rec)
            if (step + 1) % loop_cfg.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))
            result.step = step + 1
        mgr.save(loop_cfg.total_steps, (params, opt_state))
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return params, result
