"""Incremental integrity scrubbing of the device column store (DESIGN.md
§Durability).

A flipped bit in one packed BCA word silently poisons every query that
streams the column — the worst failure mode an analytics engine has, because
nothing crashes. The scrubber closes the detection gap the verified-read
path (storage/columns.py) leaves open: reads verify the *decoded* view at
materialize time, but columns consumed only through fused packed kernels are
never materialized, and at-rest corruption between reads goes unnoticed
until it is served. :class:`Scrubber` walks every device column round-robin,
a budgeted few per tick, re-hashing

  * the **encoded bytes** (packed words / dictionary / dense array — exactly
    what HBM holds) against the manifest ``encoded_crc``, and
  * the **decode memo** (``_dense``), when present, against ``decoded_crc``
    — a corrupted memo is healed for free by dropping it (the encoded truth
    re-decodes on next use).

Detection → containment → repair: a column whose encoded bytes fail is
immediately **quarantined** (every read raises
:class:`~repro.robust.errors.IntegrityError` — wrong answers become typed
errors), then **healed** from the latest checksummed snapshot
(``storage/snapshot.py``) by swapping in the snapshot's verified arrays, and
**re-verified** before the quarantine lifts. A column that cannot be healed
(no snapshot configured, or the snapshot read itself fails) stays
quarantined — detected-and-contained beats silent corruption.

Fault site ``scrub.verify``: ``raise``/``delay`` fire per scrubbed column;
``corrupt`` transforms the scrubber's *read* of the encoded bytes (the
stored arrays are untouched), emulating at-rest corruption for exactly the
fired verifications — the chaos lane's detect→heal→re-verify driver.

Metrics (``robust.integrity.*``): ``cols_verified``, ``scrub_detected``,
``scrub_repairs``, ``scrub_failures``, ``memo_drops``, and the per-tick
latency histogram ``scrub_ms``.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

import numpy as np

from ..obs.metrics import REGISTRY, MetricsRegistry
from . import faults as _faults
from .errors import IntegrityError

# NOTE: ..storage imports stay function-local throughout this module —
# storage.columns imports the robust package (fault sites), so a module-level
# import here would cycle (tests/test_storage.py guards the import order).

#: Re-reads of the encoded bytes before a mismatch counts as real (absorbs
#: fault-injected transient read corruption without a spurious heal cycle).
VERIFY_RETRIES = 2

#: Post-heal verification attempts before declaring the repair failed.
REPAIR_RETRIES = 3


def _read_encoded(col) -> list[np.ndarray]:
    """The scrubber's view of a column's stored bytes — routed through the
    ``scrub.verify`` corrupt site so chaos plans can flip what the scrubber
    *sees* without touching what the store *holds*."""
    from ..storage.integrity import encoded_parts

    return [_faults.corrupt("scrub.verify", p) for p in encoded_parts(col)]


class Scrubber:
    """Budget-bounded background scrubber over one database's device columns.

    ``cols_per_tick`` bounds the work (hashing + potential decode) done per
    :meth:`tick` so scrubbing steals bounded time from serving;
    :meth:`start`/:meth:`stop` run ticks on a daemon thread,
    :meth:`scrub_full` drives one complete pass synchronously (the serve
    loop's pre-serving gate). ``on_heal(addr)`` fires after a successful
    repair — the serve loop uses it to invalidate prepared executables that
    may have closed over the replaced arrays."""

    def __init__(self, db, snapshot_dir: str | None = None,
                 generation: int | None = None, cols_per_tick: int = 2,
                 registry: MetricsRegistry = REGISTRY,
                 on_heal: Callable[[str], None] | None = None):
        from ..storage.integrity import attach_manifest

        self.db = db
        self.snapshot_dir = snapshot_dir
        self.generation = generation
        self.cols_per_tick = max(1, int(cols_per_tick))
        self.registry = registry
        self.on_heal = on_heal
        if getattr(db.device, "integrity", None) is None:
            attach_manifest(db.device)
        self._cursor = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _columns(self) -> list[tuple[str, tuple[str, str], str, Any]]:
        from ..storage.integrity import iter_columns

        return [
            (addr, tk, name, col)
            for addr, tk, name, col in iter_columns(self.db.device)
            if addr in (self.db.device.integrity or {})
        ]

    def _count(self, name: str, n: int = 1) -> None:
        self.registry.counter(f"robust.integrity.{name}").inc(n)

    # ------------------------------------------------------------------
    def verify_column(self, addr: str, tk: tuple[str, str], name: str,
                      col) -> bool:
        """Verify one column's encoded bytes (+ memo), healing on mismatch.
        Returns True when the column is good (possibly after repair)."""
        from ..storage.integrity import crc32c_parts

        _faults.fire("scrub.verify", column=addr)
        dig = self.db.device.integrity[addr]
        expected = int(dig["encoded_crc"])
        ok = False
        for _ in range(1 + VERIFY_RETRIES):
            if crc32c_parts(_read_encoded(col)) == expected:
                ok = True
                break
        if not ok:
            self._count("scrub_detected")
            ok = self._heal(addr, tk, name, col, dig)
        if ok:
            self._verify_memo(col, dig)
            self._count("cols_verified")
        return ok

    def _verify_memo(self, col, dig: dict[str, Any]) -> None:
        """A corrupted decode memo never needs the snapshot: drop it and the
        verified encoded bytes re-decode on the next materialize."""
        from ..storage.integrity import crc32c

        memo = getattr(col, "_dense", None)
        if memo is None or memo is getattr(col, "array", None):
            return
        if crc32c(np.asarray(memo)) != int(dig["decoded_crc"]):
            col._dense = None
            self._count("memo_drops")

    def _heal(self, addr: str, tk: tuple[str, str], name: str, col,
              dig: dict[str, Any]) -> bool:
        """Quarantine → reload encoded arrays from the snapshot → re-verify →
        lift quarantine. Snapshot reads here deliberately bypass the
        ``snapshot.load`` fault site (``load_column_arrays``): the heal path
        must not be re-corrupted by a chaos spec aimed at full restores."""
        import jax.numpy as jnp

        from ..storage.columns import DenseColumn, DictPackedColumn
        from ..storage.integrity import crc32c, crc32c_parts, decode_fresh
        from ..storage.snapshot import latest_generation, load_column_arrays

        t, k = tk
        col._quarantined = True
        if self.snapshot_dir is None:
            self._count("scrub_failures")
            return False
        try:
            gen = self.generation
            if gen is None:
                gen = latest_generation(self.snapshot_dir)
            if gen is None:
                raise FileNotFoundError(
                    f"no snapshot generations in {self.snapshot_dir}"
                )
            arrays, _ = load_column_arrays(self.snapshot_dir, gen, t, k, name)
            if isinstance(col, DenseColumn):
                col.array = jnp.asarray(arrays["array"])
            else:
                col.words = jnp.asarray(arrays["words"])
                if isinstance(col, DictPackedColumn):
                    col.dictionary = jnp.asarray(
                        arrays["dict"], dtype=col.dictionary.dtype
                    )
                col._dense = None
            for _ in range(REPAIR_RETRIES):
                if (crc32c_parts(_read_encoded(col)) == int(dig["encoded_crc"])
                        and crc32c(decode_fresh(col)) == int(dig["decoded_crc"])):
                    col._quarantined = False
                    self._count("scrub_repairs")
                    if self.on_heal is not None:
                        self.on_heal(addr)
                    return True
            raise IntegrityError(
                f"column {addr} still fails verification after snapshot heal",
                table=t, key=k, column=name,
                expected_crc=int(dig["encoded_crc"]),
            )
        except Exception:  # noqa: BLE001 — a failed heal must not kill the loop
            self._count("scrub_failures")
            return False  # stays quarantined: contained, not silent

    # ------------------------------------------------------------------
    def tick(self) -> dict[str, int]:
        """Scrub the next ``cols_per_tick`` columns (round-robin). Returns
        ``{"verified": n_ok, "healed": ..., "failed": ...}`` for this tick."""
        t0 = time.perf_counter()
        stats = {"verified": 0, "healed": 0, "failed": 0}
        with self._lock:
            cols = self._columns()
            if not cols:
                return stats
            for _ in range(min(self.cols_per_tick, len(cols))):
                addr, tk, name, col = cols[self._cursor % len(cols)]
                self._cursor += 1
                before = self.registry.counter(
                    "robust.integrity.scrub_repairs"
                ).value
                if self.verify_column(addr, tk, name, col):
                    after = self.registry.counter(
                        "robust.integrity.scrub_repairs"
                    ).value
                    stats["healed" if after > before else "verified"] += 1
                else:
                    stats["failed"] += 1
        self.registry.histogram("robust.integrity.scrub_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return stats

    def scrub_full(self) -> dict[str, int]:
        """One synchronous pass over every column — the pre-serving gate."""
        total = {"verified": 0, "healed": 0, "failed": 0}
        n = len(self._columns())
        ticks = (n + self.cols_per_tick - 1) // self.cols_per_tick
        for _ in range(ticks):
            for k, v in self.tick().items():
                total[k] += v
        return total

    # ------------------------------------------------------------------
    def start(self, interval_s: float = 1.0) -> None:
        """Run :meth:`tick` every ``interval_s`` on a daemon thread. The
        caller's context (including any active chaos ``FaultPlan`` — a
        ContextVar, which threads do NOT inherit by default) is copied into
        the thread so ``scrub.verify`` faults fire there too."""
        import contextvars

        if self._thread is not None:
            return

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.tick()
                except Exception:  # noqa: BLE001 — scrubbing must not crash serve
                    self._count("scrub_failures")

        self._stop.clear()
        ctx = contextvars.copy_context()
        self._thread = threading.Thread(
            target=lambda: ctx.run(loop), name="scrubber", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._thread = None
