# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: python -m benchmarks.run [table3 table4 ...]

Each module reproduces one paper table/figure (DESIGN.md §8); the roofline
summary reads the dry-run artifacts (EXPERIMENTS.md §Roofline). Besides the
CSV stream, every suite writes an ``artifacts/bench/BENCH_<suite>.json``
artifact (name, us_per_call, derived + structured fields such as device
bytes) — the machine-readable perf trajectory CI accumulates per commit."""
from __future__ import annotations

import json
import os
import sys
import time

ARTIFACT_DIR = os.path.join("artifacts", "bench")


#: Repo-root consolidated perf file: suite → headline metrics, merged across
#: invocations (running one suite updates only its entry) so the perf
#: trajectory is tracked in-repo across PRs.
PERF_FILE = "BENCH_perf.json"


def _write_artifact(suite: str, records: list[dict], seconds: float,
                    error: str | None) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    payload = {"suite": suite, "seconds": round(seconds, 1), "records": records}
    if error:
        payload["error"] = error
    with open(os.path.join(ARTIFACT_DIR, f"BENCH_{suite}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def _run_meta(git_sha: str | None) -> dict:
    """Provenance stamp for a suite entry: device kind, jax version, and the
    git SHA the caller passed in (``--git-sha=`` / ``BENCH_GIT_SHA``; only
    falls back to asking git when neither is given)."""
    meta: dict = {"stamped_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}
    try:
        import jax

        meta["jax_version"] = jax.__version__
        meta["device"] = jax.devices()[0].device_kind
        meta["backend"] = jax.default_backend()
    except Exception as e:  # noqa: BLE001
        meta["jax_version"] = f"unavailable: {e}"
    sha = git_sha or os.environ.get("BENCH_GIT_SHA")
    if not sha:
        import subprocess

        try:
            sha = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=10, check=True,
            ).stdout.strip()
        except Exception:  # noqa: BLE001
            sha = "unknown"
    meta["git_sha"] = sha
    return meta


def _update_perf_summary(suite: str, records: list[dict], seconds: float,
                         error: str | None, meta: dict,
                         known_suites=()) -> None:
    summary: dict = {}
    if os.path.exists(PERF_FILE):
        try:
            with open(PERF_FILE) as f:
                summary = json.load(f)
        except (OSError, json.JSONDecodeError):
            summary = {}
    suites = summary.setdefault("suites", {})
    # staleness: a suite entry is replaced wholesale (metric keys the suite no
    # longer emits disappear), and entries for suites the harness no longer
    # knows about are dropped entirely
    if known_suites:
        for stale in [k for k in suites if k not in known_suites]:
            del suites[stale]
    entry: dict = {
        "seconds": round(seconds, 1),
        # explicit outcome marker: a failed suite still writes its partial
        # records above, so consumers must not read presence as success
        "status": "failed" if error else "ok",
        "meta": meta,
        "metrics": {r["name"]: r["us_per_call"] for r in records if "name" in r},
    }
    from .common import TRACES

    traces = {
        r["name"]: TRACES[r["name"]]
        for r in records if r.get("name") in TRACES
    }
    if traces:
        entry["traces"] = traces
    if error:
        entry["error"] = error
    suites[suite] = entry
    with open(PERF_FILE, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)


def main() -> None:
    from . import (
        fig14_pipelining,
        fusion,
        perf_baseline,
        fig15_parallel,
        selectivity,
        snapshot_restore,
        table3_runtime,
        table4_space,
        table56_denseid,
        table8_encodings,
        table9_decode,
        throughput,
    )

    suites = {
        "table3": table3_runtime.run,
        "table4": table4_space.run,
        "table56": table56_denseid.run,
        "fig14": fig14_pipelining.run,
        "table8": table8_encodings.run,
        "table9": table9_decode.run,
        "fig15": fig15_parallel.run,
        "perf": perf_baseline.run,
        "throughput": throughput.run,
        "selectivity": selectivity.run,
        "fusion": fusion.run,
        "snapshot": snapshot_restore.run,
    }
    from .common import RECORDS

    argv = sys.argv[1:]
    git_sha = None
    for a in list(argv):
        if a.startswith("--git-sha="):
            git_sha = a.split("=", 1)[1]
            argv.remove(a)
    picked = argv or list(suites)
    meta = _run_meta(git_sha)
    failed = []
    print("name,us_per_call,derived")
    for name in picked:
        t0 = time.time()
        start, err = len(RECORDS), None
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            failed.append(name)
            print(f"{name}/ERROR,0,{err}")
        dt = time.time() - t0
        _write_artifact(name, RECORDS[start:], dt, err)
        _update_perf_summary(name, RECORDS[start:], dt, err, meta,
                             known_suites=tuple(suites))
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    # roofline summary (if dry-run artifacts exist)
    try:
        from repro.roofline.analysis import load_records, roofline_from_record

        for rec in load_records("artifacts/dryrun"):
            if rec.get("status") != "ok" or rec.get("variant"):
                continue
            rl = roofline_from_record(rec)
            print(
                f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},"
                f"{rl.bound_s*1e6:.1f},dominant={rl.dominant}"
            )
    except Exception as e:  # noqa: BLE001
        print(f"roofline/ERROR,0,{e}")
    if failed:
        # every suite still ran and wrote its artifact, but CI must go red
        sys.exit(f"suites failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
