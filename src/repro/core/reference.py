"""Materializing numpy engine: correctness oracle + the paper's baselines.

Executes the same chain plan by *enumerating join paths* (materialized id/weight
arrays per hop) — the MonetDB/OMC/PMC execution model the paper compares against:

  * lookup='index'  — dense-ID direct offset lookup  (OMC-denseID / GQ-Fast-UA)
  * lookup='binary' — binary search on the sorted key (OMC / GQ-Fast-UA(Binary), Table 5)
  * lookup='scan'   — whole-column scan per hop       (PMC, Appendix 9.3)
  * agg='dense'     — γ¹ dense array                  (paper §6.1)
  * agg='hash'      — hash-style grouping             (GQ-Fast-UA(Map), Table 6)

``stats`` records materialized-intermediate sizes (paper Fig. 14 ablation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .algebra import (
    ChainPlan,
    EntityStep,
    Param,
    RelHop,
    SeedIds,
    SeedMask,
    eval_expr,
    expr_refs,
)
from .schema import Schema


@dataclass
class _SortedCopy:
    key_sorted: np.ndarray  # the sorted key column
    indptr: np.ndarray  # offsets per dense key id (for lookup='index')
    other: np.ndarray  # co-sorted other-FK column
    measures: dict[str, np.ndarray]
    key_raw: np.ndarray  # unsorted (for lookup='scan')
    other_raw: np.ndarray
    measures_raw: dict[str, np.ndarray]


@dataclass
class ExecStats:
    materialized_elements: int = 0
    lookups: int = 0
    hops: int = 0


class NumpyQueryEngine:
    def __init__(self, schema: Schema, lookup: str = "index", agg: str = "dense"):
        assert lookup in ("index", "binary", "scan") and agg in ("dense", "hash")
        self.schema = schema
        self.lookup = lookup
        self.agg = agg
        self.copies: dict[tuple[str, str], _SortedCopy] = {}
        for rel in schema.relationships.values():
            for key in (rel.fk1, rel.fk2):
                kcol = rel.columns[key].astype(np.int64)
                other = rel.other_fk(key)
                ocol = rel.columns[other].astype(np.int64)
                order = np.lexsort((ocol, kcol))
                h = schema.domain_size(rel.fk_entity(key))
                indptr = np.zeros(h + 1, dtype=np.int64)
                np.cumsum(np.bincount(kcol, minlength=h), out=indptr[1:])
                self.copies[(rel.name, key)] = _SortedCopy(
                    kcol[order], indptr, ocol[order],
                    {m: rel.columns[m].astype(np.float64)[order] for m in rel.measures},
                    kcol, ocol,
                    {m: rel.columns[m].astype(np.float64) for m in rel.measures},
                )
        self.stats = ExecStats()

    # ------------------------------------------------------------------
    def execute_plan(self, plan: ChainPlan, params: dict[str, Any]) -> np.ndarray:
        self.stats = ExecStats()
        ids, w, scalars = self._seed(plan, params)
        for s in plan.steps:
            if isinstance(s, RelHop):
                ids, w = self._hop(s, ids, w, params, scalars)
            else:
                ids, w = self._entity_step(s, ids, w, params, scalars)
            self.stats.materialized_elements += ids.shape[0]
        dom = self.schema.domain_size(
            plan.group_entity if plan.group_entity else _final_entity(plan)
        )
        if plan.group_entity is None:
            out = np.zeros(dom)
            out[ids[w > 0]] = 1.0
            return out
        if self.agg == "dense":
            return _gamma_dense(plan.agg, ids, w, dom)
        # hash-style grouping: γ over the compact id set, scattered to dom
        uniq, inv = np.unique(ids, return_inverse=True)
        acc = _gamma_dense(plan.agg, inv, w, uniq.shape[0])
        out = np.zeros(dom)
        out[uniq] = acc
        return out

    # ------------------------------------------------------------------
    def _seed(self, plan: ChainPlan, params):
        scalars: dict[tuple[str, str], float] = {}
        if isinstance(plan.seed, SeedIds):
            raw = plan.seed.ids if isinstance(plan.seed.ids, list) else [plan.seed.ids]
            ids = np.asarray([_res(i, params) for i in raw], dtype=np.int64)
            ent = self.schema.entities[plan.seed.entity]
            if len(ids) == 1:
                for a, col in ent.attributes.items():
                    scalars[(plan.seed.var, a)] = float(col[ids[0]])
            return ids, np.ones(ids.shape[0]), scalars
        mask = np.ones(self.schema.domain_size(plan.seed.entity), dtype=bool)
        for chain in plan.seed.chains:
            mask &= self.execute_plan(chain, params) > 0
        for c in plan.seed.entity_conds:
            col = self.schema.entities[plan.seed.entity].attributes[c.ref.attr]
            v = _res(c.value, params)
            mask &= {
                "=": col == v, ">": col > v, "<": col < v,
                ">=": col >= v, "<=": col <= v,
            }[c.op]
        ids = np.nonzero(mask)[0].astype(np.int64)
        return ids, np.ones(ids.shape[0]), scalars

    def _hop(self, s: RelHop, ids, w, params, scalars):
        cp = self.copies[(s.table, s.src_key)]
        self.stats.hops += 1
        if s.semijoin:
            keep = w > 0
            ids = np.unique(ids[keep])
            w = np.ones(ids.shape[0])
        if s.degree_filter:
            deg = np.diff(cp.indptr)
            keep = deg[ids] > 0
            return ids[keep], w[keep]
        self.stats.lookups += ids.shape[0]
        if self.lookup == "scan":
            # one whole-column scan per hop (vectorized PMC)
            sel = np.isin(cp.key_raw, ids)
            pos = np.nonzero(sel)[0]
            # map each matched row back to the weight of its source id
            wmap = np.zeros(self.schema.domain_size(s.src_entity))
            np.add.at(wmap, ids, w)  # duplicate source ids accumulate
            new_w = wmap[cp.key_raw[pos]]
            dst = cp.other_raw[pos]
            meas = {m: v[pos] for m, v in cp.measures_raw.items()}
        else:
            if self.lookup == "binary":
                starts = np.searchsorted(cp.key_sorted, ids, side="left")
                ends = np.searchsorted(cp.key_sorted, ids, side="right")
            else:
                starts = cp.indptr[ids]
                ends = cp.indptr[ids + 1]
            counts = ends - starts
            total = int(counts.sum())
            rep = np.repeat(np.arange(ids.shape[0]), counts)
            offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
            pos = np.repeat(starts, counts) + offs
            dst = cp.other[pos]
            new_w = w[rep]
            meas = {m: v[pos] for m, v in cp.measures.items()}
        if s.measure_expr is not None:
            env: dict = dict(scalars)
            for r in expr_refs(s.measure_expr):
                if r.var == s.var:
                    env[(r.var, r.attr)] = meas[r.attr]
            new_w = new_w * eval_expr(s.measure_expr, env, params, np)
        self.stats.materialized_elements += int(dst.shape[0])
        return dst.astype(np.int64), new_w

    def _entity_step(self, s: EntityStep, ids, w, params, scalars):
        ent = self.schema.entities[s.entity]
        if s.factor_expr is not None:
            env: dict = dict(scalars)
            for r in expr_refs(s.factor_expr):
                if r.var == s.var:
                    env[(r.var, r.attr)] = ent.attributes[r.attr][ids]
            w = w * eval_expr(s.factor_expr, env, params, np)
        for c in s.conds:
            col = ent.attributes[c.ref.attr][ids]
            v = _res(c.value, params)
            keep = {
                "=": col == v, ">": col > v, "<": col < v,
                ">=": col >= v, "<=": col <= v,
            }[c.op]
            ids, w = ids[keep], w[keep]
        return ids, w


def _gamma_dense(agg: str, ids: np.ndarray, w: np.ndarray, dom: int) -> np.ndarray:
    """Dense γ over [0, dom) for every supported aggregate; empty groups
    report 0 (the engine's output convention)."""
    if agg in ("count", "sum"):
        return np.bincount(ids, weights=w, minlength=dom).astype(np.float64)
    if agg == "exists":
        return (np.bincount(ids, minlength=dom) > 0).astype(np.float64)
    if agg == "avg":
        s = np.bincount(ids, weights=w, minlength=dom)
        c = np.bincount(ids, minlength=dom)
        return np.divide(s, c, out=np.zeros(dom), where=c > 0)
    if agg in ("min", "max"):
        ident = np.inf if agg == "min" else -np.inf
        acc = np.full(dom, ident)
        (np.minimum if agg == "min" else np.maximum).at(acc, ids, w)
        return np.where(acc == ident, 0.0, acc)
    raise ValueError(f"unsupported aggregate {agg}")


def _res(v, params):
    return params[v.name] if isinstance(v, Param) else v


def _final_entity(plan: ChainPlan) -> str:
    hops = [s for s in plan.steps if isinstance(s, RelHop) and not s.degree_filter]
    return hops[-1].dst_entity if hops else plan.seed.entity


def run_sql(schema: Schema, sql: str, params: dict[str, Any] | None = None,
            lookup: str = "index", agg: str = "dense") -> np.ndarray:
    from .planner import plan_query
    from .sql import parse

    eng = NumpyQueryEngine(schema, lookup, agg)
    return eng.execute_plan(plan_query(schema, parse(sql)), params or {})
