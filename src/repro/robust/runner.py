"""Deadline + degradation-ladder execution of prepared queries.

:func:`run_with_policy` executes a :class:`repro.core.engine.PreparedQuery`
under a fault-tolerance policy instead of letting exceptions escape:

  * **Deadline** — a per-query wall-clock budget. Installed in a ContextVar
    (:func:`deadline_scope`) so the executor's instrumented IR walk checks it
    *between ops* (``core.executor`` calls :func:`check_deadline`), and
    checked again around ``block_until_ready`` after every attempt. A query
    that overruns raises :class:`repro.robust.errors.DeadlineExceeded`.

  * **Degradation ladder** — on ``ExecutionError`` / ``ResourceError`` /
    deadline pressure, execution falls to the next cheaper-or-safer rung and
    the result is annotated degraded::

        active          the prepared executable as compiled (block-skipping
                        scalar-prefetch kernels where engaged, fused multi-hop
                        regions where the fusion pass formed them)
        unfused         the same plan with fused regions expanded back to
                        per-hop kernel calls (fusion="off") — sheds the
                        pipelined fused kernels, keeps block skipping
        scan            plain full-scan kernels (block_skipping="off") —
                        sheds the scalar-prefetch machinery
        xla             the pure-XLA reference math (use_pallas=False) —
                        sheds Pallas entirely
        fragment_loop   the paper-faithful scalar reference strategy — the
                        terminus, bit-identical to the frontier strategy by
                        the semiring contract (DESIGN.md §3, §Robustness)

    Every rung interprets the *same lowered physical plan*, so results agree
    bit-for-bit whenever a rung completes (skipped blocks contribute the
    ⊕-identity; the XLA fallback is the kernels' own reference math).

  * **Retry** — failures whose ``retryable`` flag is set retry on the same
    rung with capped exponential backoff + deterministic jitter
    (:class:`RetryPolicy`) before demoting.

Every execution attempt passes the ``runner.execute`` fault-injection site,
so chaos tests can fail/delay attempts without touching kernel internals.
Outcomes are returned, never raised: :class:`QueryOutcome` carries the value,
the rung that produced it, degradation status, and the terminal
:class:`QueryError` when all rungs failed.
"""
from __future__ import annotations

import random
import time
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from ..obs.metrics import REGISTRY, MetricsRegistry
from . import faults
from .admission import AdmissionController
from .errors import DeadlineExceeded, QueryError, wrap_execution_error

#: Rungs in demotion order. ``run_with_policy`` starts at the first rung and
#: walks right on failure; see module docstring for what each sheds.
LADDER = ("active", "unfused", "scan", "xla", "fragment_loop")


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

_DEADLINE: ContextVar["Deadline | None"] = ContextVar(
    "repro_query_deadline", default=None
)


class Deadline:
    """Wall-clock budget anchored at construction time."""

    __slots__ = ("deadline_ms", "t0")

    def __init__(self, deadline_ms: float):
        self.deadline_ms = float(deadline_ms)
        self.t0 = time.perf_counter()

    def elapsed_ms(self) -> float:
        return (time.perf_counter() - self.t0) * 1e3

    def remaining_ms(self) -> float:
        return self.deadline_ms - self.elapsed_ms()

    def expired(self) -> bool:
        return self.remaining_ms() <= 0.0

    def check(self, where: str = "op") -> None:
        el = self.elapsed_ms()
        if el > self.deadline_ms:
            raise DeadlineExceeded(
                f"deadline of {self.deadline_ms:.0f}ms exceeded at {where}",
                deadline_ms=self.deadline_ms, elapsed_ms=round(el, 3),
                where=where,
            )


class deadline_scope:
    """``with deadline_scope(dl): ...`` — install ``dl`` (or nothing when
    None) as the ambient deadline for the block. The executor's instrumented
    walk consults it between IR ops via :func:`check_deadline`."""

    def __init__(self, deadline: Deadline | None):
        self.deadline = deadline
        self._token = None

    def __enter__(self) -> "Deadline | None":
        self._token = _DEADLINE.set(self.deadline)
        return self.deadline

    def __exit__(self, exc_type, exc, tb) -> bool:
        _DEADLINE.reset(self._token)
        return False


def current_deadline() -> Deadline | None:
    return _DEADLINE.get()


def check_deadline(where: str = "op") -> None:
    """One ContextVar read when no deadline is active (the production fast
    path); raises :class:`DeadlineExceeded` past the budget otherwise."""
    dl = _DEADLINE.get()
    if dl is not None:
        dl.check(where)


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter: attempt k sleeps
    ``min(cap_ms, base_ms · 2^(k−1)) · (1 + jitter·u)``, u ∈ [−1, 1] drawn
    from a ``seed``-determined stream (reproducible chaos runs)."""

    max_attempts: int = 3
    base_ms: float = 5.0
    cap_ms: float = 200.0
    jitter: float = 0.2
    seed: int = 0

    def backoff_ms(self, attempt: int, rng: random.Random) -> float:
        raw = min(self.cap_ms, self.base_ms * (2.0 ** max(attempt - 1, 0)))
        return raw * (1.0 + self.jitter * rng.uniform(-1.0, 1.0))


@dataclass
class RobustPolicy:
    """Everything :func:`run_with_policy` needs: retry knobs, the ladder (a
    prefix/suffix slice of :data:`LADDER` for tests), optional admission
    control, a default deadline, and the metrics registry demotion/error
    counters land on."""

    retry: RetryPolicy = field(default_factory=RetryPolicy)
    ladder: tuple[str, ...] = LADDER
    admission: AdmissionController | None = None
    deadline_ms: float | None = None
    registry: MetricsRegistry = field(default_factory=lambda: REGISTRY)

    def __post_init__(self):
        unknown = [r for r in self.ladder if r not in LADDER]
        if unknown:
            raise ValueError(f"unknown ladder rungs {unknown}; valid: {LADDER}")
        self._rng = random.Random(self.retry.seed)


@dataclass
class QueryOutcome:
    """The structured result of one policy-governed execution. ``status`` is
    ``ok`` (first rung, first attempt), ``degraded`` (answered, but after a
    retry/demotion — ``rung``/``demotions`` say how far it fell), or
    ``error`` (``error`` holds the terminal :class:`QueryError`)."""

    status: str
    value: np.ndarray | None
    rung: str
    attempts: int = 1
    demotions: tuple[str, ...] = ()
    error: QueryError | None = None
    elapsed_ms: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status != "error"

    @property
    def degraded(self) -> bool:
        return self.status == "degraded"

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {
            "status": self.status, "rung": self.rung,
            "attempts": self.attempts, "elapsed_ms": round(self.elapsed_ms, 3),
        }
        if self.demotions:
            d["demotions"] = list(self.demotions)
        if self.error is not None:
            d.update(self.error.to_dict())
        return d


# ---------------------------------------------------------------------------
# Rung executables
# ---------------------------------------------------------------------------


def rung_fn(prepared, rung: str, batched: bool = False):
    """The executable for one ladder rung, compiled lazily from the prepared
    query's own device DB + lowered plan and cached on the PreparedQuery, so
    repeated degraded requests pay one compile per (rung, batched) pair."""
    cache = prepared.__dict__.setdefault("_rung_fns", {})
    key = (rung, batched)
    if key in cache:
        return cache[key]
    import jax

    from ..core import executor as X

    from ..core.fuse import unfuse_plan

    db, phys = prepared.device_db, prepared.phys
    # every rung below "active" runs the unfused twin of the plan: a fault in
    # the fused kernel dispatch must not follow the query down the ladder
    # (the frontier interps replay fused regions per-op only when told to)
    uphys = unfuse_plan(phys) if phys is not None else phys
    if rung == "active":
        fn = prepared.batched_fn if batched else prepared.fn
        if batched and fn is None:  # strategies without a batched entry
            fn = jax.vmap(prepared.fn)
    elif rung == "unfused":
        mk = X.compile_frontier_batched if batched else X.compile_frontier
        fn = mk(db, uphys, block_skipping=prepared.block_skipping,
                fusion="off")
    elif rung == "scan":
        mk = X.compile_frontier_batched if batched else X.compile_frontier
        fn = mk(db, uphys, block_skipping="off", fusion="off")
    elif rung == "xla":
        mk = X.compile_frontier_batched if batched else X.compile_frontier
        fn = mk(db, uphys, block_skipping="off", use_pallas=False,
                fusion="off")
    elif rung == "fragment_loop":
        single = X.compile_fragment_loop(db, uphys, use_pallas=False)
        fn = jax.vmap(single) if batched else single
    else:
        raise ValueError(f"unknown ladder rung {rung!r}; valid: {LADDER}")
    cache[key] = fn
    return fn


# ---------------------------------------------------------------------------
# The policy-governed execution loop
# ---------------------------------------------------------------------------


def _attempt(prepared, rung: str, args, deadline: Deadline | None,
             batched: bool):
    """One execution attempt on one rung: fault site → compile/lookup →
    call → device fence → deadline check. Raises QueryError on any failure."""
    import jax

    faults.fire("runner.execute", rung=rung, query=prepared.sql.strip()[:80])
    try:
        with deadline_scope(deadline):
            fn = rung_fn(prepared, rung, batched=batched)
            out = fn(*args)
            jax.block_until_ready(out)
    except QueryError:
        raise
    except Exception as e:  # noqa: BLE001 — normalize foreign exceptions
        raise wrap_execution_error(e, rung=rung, strategy=prepared.strategy)
    if deadline is not None:
        deadline.check("block_until_ready")
    return np.asarray(out)


def _run_ladder(prepared, args, policy: RobustPolicy,
                deadline: Deadline | None, batched: bool,
                t0: float) -> QueryOutcome:
    reg = policy.registry
    attempts, demotions = 0, []
    last_err: QueryError | None = None
    for rung in policy.ladder:
        retries = 0
        while True:
            attempts += 1
            try:
                value = _attempt(prepared, rung, args, deadline, batched)
                status = (
                    "ok" if attempts == 1 and not demotions else "degraded"
                )
                if status == "degraded":
                    reg.counter("robust.degraded_results").inc()
                return QueryOutcome(
                    status, value, rung, attempts, tuple(demotions),
                    elapsed_ms=(time.perf_counter() - t0) * 1e3,
                )
            except QueryError as e:
                last_err = e.with_context(rung=rung)
                reg.counter(f"robust.errors.{e.code}").inc()
                if isinstance(e, DeadlineExceeded):
                    reg.counter("robust.deadline_exceeded").inc()
                # a spent deadline is terminal: no rung can answer in time
                if deadline is not None and deadline.expired():
                    return QueryOutcome(
                        "error", None, rung, attempts, tuple(demotions),
                        error=last_err,
                        elapsed_ms=(time.perf_counter() - t0) * 1e3,
                    )
                if e.retryable and retries < policy.retry.max_attempts - 1:
                    retries += 1
                    reg.counter("robust.retries").inc()
                    wait = policy.retry.backoff_ms(retries, policy._rng)
                    if deadline is None or deadline.remaining_ms() > wait:
                        time.sleep(wait / 1e3)
                        continue
                break  # exhausted retries (or no time to back off): demote
        demotions.append(rung)
        reg.counter("robust.demotions").inc()
        reg.counter(f"robust.demotions.{rung}").inc()
    return QueryOutcome(
        "error", None, policy.ladder[-1], attempts, tuple(demotions),
        error=last_err, elapsed_ms=(time.perf_counter() - t0) * 1e3,
    )


def run_with_policy(prepared, params: dict, deadline_ms: float | None = None,
                    policy: RobustPolicy | None = None) -> QueryOutcome:
    """Execute one parameter binding of ``prepared`` under ``policy``.
    Returns a :class:`QueryOutcome`; never raises for query-shaped failures
    (validation, admission, execution, deadline) — those come back as
    ``status="error"`` with the typed error attached."""
    policy = policy if policy is not None else RobustPolicy()
    t0 = time.perf_counter()
    dms = deadline_ms if deadline_ms is not None else policy.deadline_ms
    deadline = Deadline(dms) if dms is not None else None
    try:
        prepared.validate_params(params)
        if policy.admission is not None:
            policy.admission.admit(prepared, batch=1)
    except QueryError as e:
        policy.registry.counter(f"robust.errors.{e.code}").inc()
        return QueryOutcome(
            "error", None, policy.ladder[0], 0, error=e,
            elapsed_ms=(time.perf_counter() - t0) * 1e3,
        )
    args = [params[n] for n in prepared.param_names]
    return _run_ladder(prepared, args, policy, deadline, False, t0)


def run_batch_with_policy(
    prepared, param_arrays: dict, deadline_ms: float | None = None,
    policy: RobustPolicy | None = None,
) -> list[QueryOutcome]:
    """Policy-governed form of ``PreparedQuery.execute_batch``: B parameter
    bindings in one pass, one :class:`QueryOutcome` per binding (all rows of
    a surviving batch share status/rung; a rejected/failed batch yields per-
    row error outcomes). Admission may *demote* an over-budget batch to
    serial single-query execution — degraded, but within budget."""
    from ..core.engine import batch_bucket

    policy = policy if policy is not None else RobustPolicy()
    t0 = time.perf_counter()
    dms = deadline_ms if deadline_ms is not None else policy.deadline_ms
    deadline = Deadline(dms) if dms is not None else None
    try:
        args, B = prepared._batch_args(param_arrays)
    except QueryError as e:
        policy.registry.counter(f"robust.errors.{e.code}").inc()
        n = _best_effort_batch_len(param_arrays)
        out = QueryOutcome("error", None, policy.ladder[0], 0, error=e)
        return [out] * max(n, 1)
    serial = False
    if policy.admission is not None:
        try:
            decision = policy.admission.admit(prepared, batch=B,
                                              allow_demote=True)
            serial = decision.action == "demote"
        except QueryError as e:
            policy.registry.counter(f"robust.errors.{e.code}").inc()
            out = QueryOutcome("error", None, policy.ladder[0], 0, error=e)
            return [out] * B
    if serial:
        policy.registry.counter("robust.degraded_results").inc(B)
        outs = []
        for b in range(B):
            params = {
                n: np.asarray(a[b]).item()
                for n, a in zip(prepared.param_names, args)
            }
            oc = run_with_policy(prepared, params, deadline_ms=dms,
                                 policy=policy)
            if oc.status == "ok":  # serial demotion is itself a degradation
                oc.status = "degraded"
            outs.append(oc)
        return outs
    bucket = batch_bucket(B)
    if bucket != B:
        args = [
            np.concatenate([a, np.repeat(a[-1:], bucket - B, axis=0)])
            for a in args
        ]
    oc = _run_ladder(prepared, args, policy, deadline, True, t0)
    if oc.value is not None:
        rows = oc.value[:B]
        return [
            QueryOutcome(oc.status, rows[b], oc.rung, oc.attempts,
                         oc.demotions, elapsed_ms=oc.elapsed_ms)
            for b in range(B)
        ]
    return [oc] * B


def _best_effort_batch_len(param_arrays: dict) -> int:
    for v in param_arrays.values():
        try:
            return len(v)
        except TypeError:
            continue
    return 1
