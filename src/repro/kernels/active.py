"""Frontier-sparsity metadata: per-block src ranges and active-block lists.

GQ-Fast's selective-query win (paper §4-5) comes from touching only the index
*fragments* reachable from the active sources. The streaming kernels
(:mod:`.fragment_spmv`, :mod:`.fragment_spmv_packed`, :mod:`.fragment_spmm`)
instead scan every ``EDGE_BLOCK``-edge block per hop — a 1-seed query over a
10M-edge index pays a full-domain scan. This module is the machinery that
restores fragment-level selectivity at block granularity:

  * :func:`block_ranges` — build-time (host, numpy): for each EDGE_BLOCK-sized
    block of the CSR-ordered edge arrays, its ``[src_min, src_max]`` source-id
    range. Edges are sorted by src, so block ranges are a monotone partition of
    the CSR offsets; any frontier whose support misses a block's range can skip
    that block entirely (every edge in it carries ⊕-identity weight).
  * :func:`active_flags` / :func:`compact_blocks` — per-hop (traced): from the
    frontier's nonzero support, mark blocks whose src range intersects it, and
    compact the surviving block ids into a **fixed-capacity list + count** so
    shapes stay static under jit. The list's tail repeats the last active block
    — a revisited block index costs no new DMA on TPU, and the compute is
    guarded off by the in-kernel ``i < n_active`` predicate.
  * :func:`active_block_list_np` — the eager twin: when the frontier is a
    concrete array (kernel-level callers outside an enclosing jit, e.g. the
    selectivity benchmark), the list is computed in numpy and its capacity
    bucketed to a power of two, so the grid itself shrinks to the surviving
    blocks and recompiles stay bounded at ~log2(n_blocks) per shape.

Skipping is *bit-identical* to the full scan for every combine op: a skipped
block's sources all carry the ⊕-identity, so its per-block contribution is the
⊕-identity vector and ``combine(acc, identity) == acc`` exactly (0 for sum,
±∞ for min/max, 0 for bool). Conversely an active block whose range merely
*straddles* the support (a gap block) contributes identity edge products — the
same values the scan computes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .params import EDGE_BLOCK

#: Runtime "auto" heuristic: engage skipping only while the surviving-block
#: fraction is at most this — above it the scan's simpler schedule wins and
#: the active-list work is pure overhead (the ≤1.1× full-selectivity budget).
SKIP_BLOCK_FRACTION = 0.25


def n_edge_blocks(E: int) -> int:
    """Blocks the streaming kernels use for an E-edge index (≥ 1)."""
    return max(1, -(-E // EDGE_BLOCK))


def block_ranges(src_ids) -> tuple[np.ndarray, np.ndarray]:
    """Per-block ``[src_min, src_max]`` over EDGE_BLOCK-sized blocks of the
    CSR-ordered (src-sorted) edge array. Host/numpy — runs once at
    ``build_device_db`` time. An empty relation gets the 1-entry sentinel
    ``([0], [-1])`` whose range intersects no support."""
    src = np.asarray(src_ids)
    E = src.shape[0]
    if E == 0:
        return np.zeros(1, np.int32), np.full(1, -1, np.int32)
    nb = n_edge_blocks(E)
    starts = np.arange(nb, dtype=np.int64) * EDGE_BLOCK
    ends = np.minimum(starts + EDGE_BLOCK, E) - 1
    return src[starts].astype(np.int32), src[ends].astype(np.int32)


def support_mask(w, zero: float):
    """Nonzero support of a frontier over the source domain: ``w != 0̄`` for a
    ``[n_src]`` vector; the batched ``[B, n_src]`` matrix reduces with ∨ over
    rows (one shared block list serves all B queries — a block survives when
    *any* query's support intersects it)."""
    nz = w != zero
    if nz.ndim == 2:
        nz = nz.any(axis=0)
    return nz


def active_flags(support, src_min, src_max):
    """bool[n_blocks]: does any supported source fall in ``[src_min, src_max]``?
    One exclusive prefix count over the source domain turns each block test
    into two gathers — O(n_src + n_blocks), no per-block scan."""
    cs = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(support.astype(jnp.int32))]
    )
    return cs[src_max + 1] > cs[src_min]


def compact_blocks(flags):
    """Fixed-capacity compaction: ``(block_idx int32[n_blocks], n_active
    int32[1])`` with the surviving block ids first (ascending — stable argsort
    on the inactive flag) and the tail repeating the last active block, so the
    scalar-prefetch ``index_map`` always names a valid block and inactive grid
    steps re-request the resident one (no new DMA)."""
    nb = flags.shape[0]
    order = jnp.argsort(~flags, stable=True).astype(jnp.int32)
    n_active = jnp.sum(flags).astype(jnp.int32)
    last = order[jnp.maximum(n_active - 1, 0)]
    idx = jnp.where(jnp.arange(nb, dtype=jnp.int32) < n_active, order, last)
    return idx, n_active.reshape(1)


def active_block_list(w, zero: float, src_min, src_max):
    """Traced path: frontier → (block_idx[n_blocks], n_active[1])."""
    return compact_blocks(active_flags(support_mask(w, zero), src_min, src_max))


def bucket_capacity(n: int, nb: int) -> int:
    """Smallest power-of-two ≥ n, capped at nb (and ≥ 1) — the eager path's
    grid size, bucketed so the per-shape compile count stays ~log2(nb)."""
    if n >= nb:
        return nb
    return max(1, min(nb, 1 << (max(1, n) - 1).bit_length()))


def active_block_list_np(support, src_min, src_max):
    """Eager twin of :func:`active_block_list` for concrete frontiers:
    ``(block_idx int32[C], n_active int32[1], active_fraction float)`` with
    ``C = bucket_capacity(n_active, n_blocks)`` — the grid really shrinks."""
    sup = np.asarray(support).astype(np.int64)
    cs = np.concatenate([np.zeros(1, np.int64), np.cumsum(sup)])
    flags = cs[np.asarray(src_max) + 1] > cs[np.asarray(src_min)]
    act = np.flatnonzero(flags).astype(np.int32)
    nb = int(flags.shape[0])
    C = bucket_capacity(int(act.shape[0]), nb)
    idx = np.full(C, act[-1] if act.size else 0, np.int32)
    idx[: act.shape[0]] = act
    n_active = np.asarray([act.shape[0]], np.int32)
    return idx, n_active, act.shape[0] / nb
