"""Pallas TPU kernel: batched fragment join-aggregate — the multi-query SpMM.

``Y[b, dst] ⊕= W[b, src] ⊗ m`` over the edge list of a GQ-Fast index, for all
``B`` frontier rows at once. This is the serving-path upgrade of
:mod:`.fragment_spmv`: OLAP dashboards issue many concurrent queries that
differ only in parameter bindings (paper §2 scenarios), and a ``vmap`` over
the single-query hop streams the CSR edge arrays from HBM ``B`` times —
batch-64 costs ~64× batch-1. Here the frontier *matrix* ``W[B, n_src]`` and
the accumulator ``Y[B, n_dst]`` are VMEM-resident for the whole pass and each
``EDGE_BLOCK``-edge block (src/dst/measure) is loaded from HBM **exactly once
per pass** and applied to all ``B`` rows — the classic operand-reuse move of
dense-accumulator graph engines, turning the hop from memory-bound SpMV into
compute-dense SpMM.

Same semiring surface as the SpMV (``op``: 'sum' | 'min' | 'max' | 'bool'),
same block geometry (:mod:`.params`), same padding contract (src pads past the
frontier so the gather fills the ⊕-identity; measure pads 0), and per-block
math identical to the single-query kernel run row-wise — so a batched result
is bit-identical to ``B`` independent SpMV calls.

:func:`fragment_spmm_packed` is the decode-fused variant: dst/measure columns
arrive as BCA bit-packed uint32 word streams and decode block-at-a-time in
VMEM via :func:`.bitunpack.decode_groups` — one decode serves all ``B`` rows,
so bit-packed columns keep their space win (and amortize their decode cost)
under batching. Operand layout and spec construction are shared with the
packed SpMV (:mod:`.fragment_spmv_packed`).

:func:`fragment_spmm_active` / :func:`fragment_spmm_packed_active` are the
frontier-sparsity variants (kernels/active.py): the batch's supports union
into **one** block list (a block survives when any query's support intersects
it — the contract ``support_mask`` implements for ``[B, n_src]`` frontiers),
which rides in SMEM via ``pltpu.PrefetchScalarGridSpec`` and drives the edge
streams' ``index_map`` so only surviving blocks are DMA'd once per pass.

The measure operand is shared across the batch (one edge list, one measure
column, B frontiers). Per-row measures (e.g. seed-scalar-dependent measure
expressions) have no single-stream formulation — ``ops.fragment_spmm`` routes
those to the XLA vmap fallback instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fragment_spmv import IDENTITY, _combine
from .fragment_spmv_packed import (
    _active_specs,
    _decode_block,
    _packed_operands,
    _scan_specs,
)
from .params import EDGE_BLOCK


def _edge_product_batched(W, src, m, op: str):
    """W[:, src] ⊗ m for all rows: [B, E_blk], with the same ⊕-identity guard
    as the single-query kernel (∞·0 = NaN on the min/max lattices)."""
    zero = IDENTITY[op]
    ws = jnp.take(W, src, axis=1, fill_value=zero)  # [B, EDGE_BLOCK]
    if op == "sum":
        return ws * m
    if op == "bool":
        return ((ws > 0) & (m != 0)).astype(jnp.float32)
    return jnp.where(ws == zero, zero, ws * m)


def _segment_combine_batched(prod, dst, n_dst: int, op: str):
    """Scatter-⊕ of [B, E_blk] edge products into [B, n_dst]: one segment
    reduction with the batch as trailing lanes (segment ids index axis 0)."""
    if op == "sum":
        seg = jax.ops.segment_sum
    elif op == "min":
        seg = jax.ops.segment_min
    else:  # max | bool
        seg = jax.ops.segment_max
    return seg(prod.T, dst, num_segments=n_dst).T


def _kernel(n_dst: int, op: str, w_ref, src_ref, dst_ref, m_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    prod = _edge_product_batched(w_ref[...], src_ref[...], m_ref[...], op)
    blk = _segment_combine_batched(prod, dst_ref[...], n_dst, op)
    out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(jax.jit, static_argnames=("n_dst", "op", "interpret"))
def fragment_spmm(
    weights: jnp.ndarray,  # f32[B, n_src] — the frontier matrix
    src_ids: jnp.ndarray,  # i32[E]
    dst_ids: jnp.ndarray,  # i32[E]
    measures: jnp.ndarray,  # f32[E] — shared across the batch
    n_dst: int,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    B = weights.shape[0]
    E = src_ids.shape[0]
    if E == 0:  # empty relation: no edge contributes, everything is ⊕-identity
        return jnp.full((B, n_dst), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    if pad:
        # same padding contract as the SpMV: src past the frontier (gather
        # fills the ⊕-identity), measure 0 ⇒ identity contribution per op
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, weights.shape[1], jnp.int32)])
        dst_ids = jnp.concatenate([dst_ids, jnp.zeros(pad, jnp.int32)])
        measures = jnp.concatenate([measures, jnp.zeros(pad, jnp.float32)])
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)

    return pl.pallas_call(
        functools.partial(_kernel, n_dst, op),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(weights.shape, lambda i: (0, 0)),  # frontier resident
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((B, n_dst), lambda i: (0, 0)),  # accumulate
        out_shape=jax.ShapeDtypeStruct((B, n_dst), jnp.float32),
        interpret=interpret,
    )(weights, src_ids, dst_ids, measures)


def _kernel_active(n_dst: int, op: str, na_ref, bi_ref,
                   w_ref, src_ref, dst_ref, m_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    @pl.when(i < na_ref[0])
    def _compute():
        prod = _edge_product_batched(w_ref[...], src_ref[...], m_ref[...], op)
        blk = _segment_combine_batched(prod, dst_ref[...], n_dst, op)
        out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(jax.jit, static_argnames=("n_dst", "op", "interpret"))
def fragment_spmm_active(
    weights: jnp.ndarray,  # f32[B, n_src]
    src_ids: jnp.ndarray,
    dst_ids: jnp.ndarray,
    measures: jnp.ndarray,
    block_idx: jnp.ndarray,  # i32[C] — union of the B queries' active blocks
    n_active: jnp.ndarray,  # i32[1]
    n_dst: int,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    """Frontier-sparsity batched hop: only the blocks named by ``block_idx``
    (the union of per-query supports) are DMA'd, each applied to all B rows.
    Same math and combine order as :func:`fragment_spmm` → bit-identical."""
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    B = weights.shape[0]
    E = src_ids.shape[0]
    if E == 0:
        return jnp.full((B, n_dst), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    if pad:
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, weights.shape[1], jnp.int32)])
        dst_ids = jnp.concatenate([dst_ids, jnp.zeros(pad, jnp.int32)])
        measures = jnp.concatenate([measures, jnp.zeros(pad, jnp.float32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (n_active, block_idx) land in SMEM
        grid=(block_idx.shape[0],),
        in_specs=[
            pl.BlockSpec(weights.shape, lambda i, na, bi: (0, 0)),  # resident
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
        ],
        out_specs=pl.BlockSpec((B, n_dst), lambda i, na, bi: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_active, n_dst, op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_dst), jnp.float32),
        interpret=interpret,
    )(n_active, block_idx, weights, src_ids, dst_ids, measures)


def _kernel_packed(
    n_dst: int, op: str, dst_width: int, m_mode: str, m_width: int, *refs
):
    w_ref, src_ref, dst_ref, *rest, out_ref = refs

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    dst, m = _decode_block(dst_width, m_mode, m_width, dst_ref, rest)
    prod = _edge_product_batched(w_ref[...], src_ref[...], m, op)
    blk = _segment_combine_batched(prod, dst, n_dst, op)
    out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(
    jax.jit,
    static_argnames=("n_dst", "op", "dst_width", "m_mode", "m_width", "interpret"),
)
def fragment_spmm_packed(
    weights: jnp.ndarray,  # f32[B, n_src]
    src_ids: jnp.ndarray,  # i32[E]
    dst: jnp.ndarray,  # uint32 words if dst_width else i32[E]
    measure: jnp.ndarray | None,  # uint32 words | f32[E] | None, per m_mode
    mdict: jnp.ndarray | None,  # f32[u] dictionary, m_mode == 'dict' only
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode-fused batched hop: one in-VMEM block decode serves all B rows.
    Same operand layout and per-block math as ``fragment_spmv_packed``."""
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    B = weights.shape[0]
    E = src_ids.shape[0]
    if E == 0:
        return jnp.full((B, n_dst), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)
    operands, kinds = _packed_operands(
        weights, src_ids, dst, measure, mdict,
        dst_width, m_mode, m_width, n_blocks, pad,
    )
    return pl.pallas_call(
        functools.partial(_kernel_packed, n_dst, op, dst_width, m_mode, m_width),
        grid=(n_blocks,),
        in_specs=_scan_specs(kinds),
        out_specs=pl.BlockSpec((B, n_dst), lambda i: (0, 0)),  # accumulate
        out_shape=jax.ShapeDtypeStruct((B, n_dst), jnp.float32),
        interpret=interpret,
    )(*operands)


def _kernel_packed_active(
    n_dst: int, op: str, dst_width: int, m_mode: str, m_width: int, *refs
):
    na_ref, bi_ref, w_ref, src_ref, dst_ref, *rest, out_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    @pl.when(i < na_ref[0])
    def _compute():
        dst, m = _decode_block(dst_width, m_mode, m_width, dst_ref, rest)
        prod = _edge_product_batched(w_ref[...], src_ref[...], m, op)
        blk = _segment_combine_batched(prod, dst, n_dst, op)
        out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(
    jax.jit,
    static_argnames=("n_dst", "op", "dst_width", "m_mode", "m_width", "interpret"),
)
def fragment_spmm_packed_active(
    weights: jnp.ndarray,  # f32[B, n_src]
    src_ids: jnp.ndarray,
    dst: jnp.ndarray,
    measure: jnp.ndarray | None,
    mdict: jnp.ndarray | None,
    block_idx: jnp.ndarray,  # i32[C] — union of the B queries' active blocks
    n_active: jnp.ndarray,  # i32[1]
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    """Frontier-sparsity decode-fused batched hop: only surviving blocks are
    DMA'd and decoded, each serving all B rows. Bit-identical to
    :func:`fragment_spmm_packed`."""
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    B = weights.shape[0]
    E = src_ids.shape[0]
    if E == 0:
        return jnp.full((B, n_dst), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)
    operands, kinds = _packed_operands(
        weights, src_ids, dst, measure, mdict,
        dst_width, m_mode, m_width, n_blocks, pad,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(block_idx.shape[0],),
        in_specs=_active_specs(kinds),
        out_specs=pl.BlockSpec((B, n_dst), lambda i, na, bi: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(
            _kernel_packed_active, n_dst, op, dst_width, m_mode, m_width
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_dst), jnp.float32),
        interpret=interpret,
    )(n_active, block_idx, *operands)
