"""GQ-Fast engine facade (paper Fig. 4 architecture).

``GQFastDatabase`` = Loader: builds both fragment indices per relationship table
(+ metadata: encodings, space). ``GQFastEngine`` = Query Processor: SQL → RQNA
(parse + normalize/verify) → physical chain plan → compiled executable
(prepare once / execute many, as JDBC-style prepared statements)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from . import executor as X
from .algebra import ChainPlan
from .fragments import FragmentIndex, build_index
from .lower import PhysicalPlan, lower
from .planner import plan_query
from .schema import RelationshipTable, Schema
from .sql import parse


class GQFastDatabase:
    """In-memory GQ-Fast database: both directions of every relationship table.

    ``keep_packed`` (default True, matching ``fragments.build_index``) keeps
    the host-side bit-packed words on each ``ColumnFragments`` — the kernel
    wire layout the device column store reuses. Setting it False only trades
    host memory for a re-pack when a packed device encoding is chosen; the
    device representation is governed solely by ``device_encodings``
    (``"auto"`` | ``"dense"`` | ``"packed"`` | per-column dict keyed by
    ``(table, key, column)`` — see ``executor.build_device_db``). Deployments
    that only run the fallback strategies (``fragment_loop`` / a mesh) should
    pass ``device_encodings="dense"``: their prepares materialize every packed
    column anyway, so packed storage would cost packed *plus* dense bytes
    (visible as ``space_report()["device"]["materialized_bytes"]``)."""

    def __init__(
        self,
        schema: Schema,
        encodings: dict[tuple[str, str, str], str] | None = None,
        account_space: bool = True,
        keep_packed: bool = True,
        device_encodings: str | dict | None = "auto",
    ):
        schema.validate()
        self.schema = schema
        self.host_indexes: dict[tuple[str, str], FragmentIndex] = {}
        for rel in schema.relationships.values():
            for key in (rel.fk1, rel.fk2):
                enc = {
                    col: e
                    for (t, k, col), e in (encodings or {}).items()
                    if t == rel.name and k == key
                }
                self.host_indexes[(rel.name, key)] = build_index(
                    schema, rel, key, enc or None,
                    keep_packed=keep_packed, account_space=account_space,
                )
        self.device = X.build_device_db(schema, self.host_indexes, device_encodings)

    def space_report(self) -> dict[str, Any]:
        """Host byte-array accounting (paper §5 analytic model) plus the
        ``device`` section: real bytes the device column store holds, per
        column, with the decoded-CSR baseline for the compression ratio."""
        from ..storage import device_space_report

        rep: dict[str, Any] = {"indexes": {}, "total_bytes": 0}
        for (t, k), idx in self.host_indexes.items():
            cols = {
                c: {"encoding": cf.encoding, "bytes": cf.encoded_bytes}
                for c, cf in idx.columns.items()
            }
            b = idx.total_bytes()
            rep["indexes"][f"I_{t}.{k}"] = {"columns": cols, "lookup_bytes": idx.lookup_bytes(), "bytes": b}
            rep["total_bytes"] += b
        rep["device"] = device_space_report(self.device)
        return rep


#: Ragged batches pad up to one of these sizes so the batched executable
#: compiles a bounded number of times: powers of two up to 64, then
#: multiples of 64 (a B=65 burst compiles the 128 bucket, not its own).
BATCH_BUCKET_CAP = 64


def batch_bucket(b: int) -> int:
    """Smallest bucket ≥ b: next power of two up to BATCH_BUCKET_CAP, then
    the next multiple of BATCH_BUCKET_CAP."""
    if b <= BATCH_BUCKET_CAP:
        return 1 << (b - 1).bit_length()
    return -(-b // BATCH_BUCKET_CAP) * BATCH_BUCKET_CAP


@dataclass
class PreparedQuery:
    sql: str
    plan: ChainPlan
    fn: Callable[..., Any]
    param_names: list[str]
    group_entity: str | None
    phys: PhysicalPlan | None = None  # lowered IR (None only for legacy callers)
    batched_fn: Callable[..., Any] | None = None  # SpMM batch entry (frontier)

    def __call__(self, **params) -> np.ndarray:
        args = [params[n] for n in self.param_names]
        return np.asarray(self.fn(*args))

    def _batch_args(self, param_arrays: dict) -> tuple[list[np.ndarray], int]:
        """Validate one [B] array (or Python list) per parameter: every
        parameter present, none scalar, all the same length."""
        if not self.param_names:
            raise ValueError(
                "execute_batch needs a parameterized query (this one has none);"
                " call the prepared query directly instead"
            )
        missing = [n for n in self.param_names if n not in param_arrays]
        if missing:
            raise TypeError(f"execute_batch missing parameter arrays: {missing}")
        args, B = [], None
        for n in self.param_names:
            a = np.asarray(param_arrays[n])
            if a.ndim == 0:
                raise ValueError(
                    f"execute_batch parameter {n!r} is a scalar; pass a list or"
                    " 1-D array with one value per query (a scalar would"
                    " silently broadcast to every query in the batch)"
                )
            if a.ndim != 1:
                raise ValueError(
                    f"execute_batch parameter {n!r} must be 1-D, got shape {a.shape}"
                )
            if B is None:
                B = a.shape[0]
            elif a.shape[0] != B:
                raise ValueError(
                    f"ragged batch: parameter {n!r} has length {a.shape[0]} but"
                    f" {self.param_names[0]!r} has length {B}; all parameter"
                    " arrays must have one entry per query"
                )
            args.append(a)
        if B == 0:
            raise ValueError("execute_batch got empty parameter arrays")
        return args, B

    def execute_batch(self, **param_arrays) -> np.ndarray:
        """Serve B parameter bindings of this query in one pass → [B, out_dom].

        On the frontier strategy this runs the batched SpMM executable
        (``compile_frontier_batched``): each hop streams the edge arrays once
        for the whole batch. Ragged B pads up to a bucket size (repeating the
        last row; the pad rows are sliced off) so recompiles are bounded.
        Strategies without a batched interpreter (fragment_loop, distributed
        meshes) fall back to ``jax.vmap`` over the single-query executable —
        same results, no edge-stream reuse."""
        args, B = self._batch_args(param_arrays)
        bucket = batch_bucket(B)
        if bucket != B:  # bound recompiles on the fallback path too
            args = [
                np.concatenate([a, np.repeat(a[-1:], bucket - B, axis=0)])
                for a in args
            ]
        if self.batched_fn is None:
            import jax

            return np.asarray(jax.vmap(self.fn)(*args))[:B]
        return np.asarray(self.batched_fn(*args))[:B]


class GQFastEngine:
    def __init__(self, db: GQFastDatabase, strategy: str = "frontier",
                 mesh=None, shard_axes: tuple[str, ...] = ("data",)):
        self.db = db
        self.strategy = strategy
        self.mesh = mesh
        self.shard_axes = shard_axes
        self._cache: dict[tuple[str, str], PreparedQuery] = {}

    def prepare(self, sql: str) -> PreparedQuery:
        key = (sql, self.strategy)
        if key in self._cache:
            return self._cache[key]
        plan = plan_query(self.db.schema, parse(sql))
        # lower once: every strategy interprets the same physical IR, and the
        # per-execute mask/ref-resolution work is hoisted out of the hot path
        phys = lower(self.db.device, plan)
        names = list(phys.param_names)
        bfn = None
        if self.mesh is not None:
            sdb = X.shard_edges(self.db.device, self.mesh, self.shard_axes)
            fn = X.compile_frontier_distributed(
                self.db.device, phys, self.mesh, self.shard_axes,
                sharded_db=sdb,
            )
            if names:  # shard_map body vmaps over the parameter vectors
                bfn = X.compile_frontier_distributed(
                    self.db.device, phys, self.mesh, self.shard_axes,
                    batched=True, sharded_db=sdb,
                )
        else:
            strategy = self.strategy
            if strategy == "auto":
                strategy = self._pick_strategy(plan)
            fn = X.STRATEGIES[strategy](self.db.device, phys)
            if strategy == "frontier" and names:
                # the SpMM serving path: one edge stream per hop for the whole
                # batch. fragment_loop keeps the vmap fallback so its batched
                # results stay bit-identical to its own single-query calls.
                bfn = X.compile_frontier_batched(self.db.device, phys)
        pq = PreparedQuery(sql, plan, fn, names, plan.group_entity, phys, bfn)
        self._cache[key] = pq
        return pq

    def _pick_strategy(self, plan: ChainPlan) -> str:
        """Beyond-paper: cost-based strategy choice. The paper's fragment-at-a-
        time execution is *work-efficient* (touches only reachable fragments);
        the vectorized frontier pass is *throughput-efficient* (whole-relation
        SpMV). Estimate the touched fraction from average degrees: sparse seeds
        → fragment_loop, dense traversals → frontier (EXPERIMENTS.md §Perf)."""
        from .algebra import RelHop, SeedIds

        if not isinstance(plan.seed, SeedIds):
            return "frontier"  # mask seeds are whole-domain already
        frontier_est = 1.0
        worst_fraction = 0.0
        first = True
        for s in plan.steps:
            if not isinstance(s, RelHop) or s.degree_filter:
                continue
            idx = self.db.host_indexes[(s.table, s.src_key)]
            edges = max(idx.num_edges, 1)
            h = idx.indptr.shape[0] - 1
            deg = np.diff(idx.indptr)
            # first hop: plan for the worst (max-degree) seed — the prepared
            # query serves arbitrary parameters and Zipf heads dominate cost;
            # later hops mix many fragments, so the average is representative
            est_deg = float(deg.max()) if first else edges / max(h, 1)
            first = False
            touched_edges = frontier_est * est_deg
            worst_fraction = max(worst_fraction, min(touched_edges / edges, 1.0))
            frontier_est = min(touched_edges, self.db.schema.domain_size(s.dst_entity))
        # crossover measured on this host (benchmarks/perf_baseline): the scalar
        # loop wins while < ~15% of the relation is touched; on TPU the vector
        # path's advantage is larger, so deployments should retune this knob
        return "fragment_loop" if worst_fraction < 0.15 else "frontier"

    def query(self, sql: str, **params) -> np.ndarray:
        return self.prepare(sql)(**params)

    def query_topk(self, sql: str, k: int = 10, **params) -> list[tuple[int, float]]:
        scores = self.query(sql, **params)
        return self._topk(scores, k)

    def query_topk_batch(
        self, sql: str, k: int = 10, **param_arrays
    ) -> list[list[tuple[int, float]]]:
        """Batched form of :meth:`query_topk`: one [B]-array per parameter,
        one SpMM pass, one top-k list per query (dashboard panels)."""
        scores = self.prepare(sql).execute_batch(**param_arrays)
        return [self._topk(row, k) for row in scores]

    @staticmethod
    def _topk(scores: np.ndarray, k: int) -> list[tuple[int, float]]:
        idx = np.argsort(-scores)[:k]
        return [(int(i), float(scores[i])) for i in idx if scores[i] != 0]
