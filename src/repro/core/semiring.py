"""Aggregation semirings for the dense γ accumulator (DESIGN.md §3).

Every relationship query reduces, per group key, an aggregate over the set of
join paths reaching that key; the per-path weight is the ⊗-product of the hop
factors. A :class:`Semiring` packages the (⊕, ⊗, 0̄, 1̄) the executor needs so
that SUM/COUNT, MIN/MAX and EXISTS all run through the *same* lowered-IR walker
and the same kernels:

  * ``sum``  — (+, ×, 0, 1): SUM/COUNT, the paper's γ accumulator.
  * ``min``  — (min, ×, +∞, 1): MIN over path scores. Distributes over the hop
    product only for non-negative factors (monotone extension) — the measure
    columns of a GQ-Fast index are counts/frequencies, which satisfy this.
  * ``max``  — (max, ×, −∞, 1): MAX, same monotonicity caveat.
  * ``bool`` — (∨, ∧, 0, 1) on {0,1}: EXISTS / pure reachability; also the
    algebra every IN-subquery mask chain runs under.

AVG is not a semiring element of its own: the executor runs the ``sum``
semiring twice inside one traced program — once weighted, once in count mode
(measures suppressed) — and divides at finalize (the fused SUM+COUNT pair).

The zero element 0̄ marks "no path reaches this entity". ⊗-extension guards it
explicitly (``extend``) because +∞·0 would poison min/max lattices with NaNs,
and predicate masks replace excluded entries by 0̄ (``mask``) instead of
multiplying by 0, which is only correct for the sum semiring.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Semiring:
    """The executor-facing contract; all arrays are float32 frontier vectors."""

    name: str  # 'sum' | 'min' | 'max' | 'bool'
    zero: float  # identity of ⊕ ("unreachable")
    one: float = 1.0  # identity of ⊗ (seed weight)

    # -- ⊕ ------------------------------------------------------------------
    def combine(self, a, b):
        if self.name == "sum":
            return a + b
        if self.name == "min":
            return jnp.minimum(a, b)
        return jnp.maximum(a, b)  # max | bool

    def segment(self, vals, seg_ids, num_segments: int):
        """Scatter-⊕ of per-edge values into the destination domain. The
        segment identities (0 / +∞ / −∞) equal ``zero`` by construction."""
        if self.name == "sum":
            return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments)
        if self.name == "min":
            return jax.ops.segment_min(vals, seg_ids, num_segments=num_segments)
        return jax.ops.segment_max(vals, seg_ids, num_segments=num_segments)

    def preduce(self, x, axes):
        """Cross-shard ⊕ (the distributed strategy's one collective per hop)."""
        if self.name == "sum":
            return jax.lax.psum(x, axes)
        if self.name == "min":
            return jax.lax.pmin(x, axes)
        return jax.lax.pmax(x, axes)

    # -- ⊗ ------------------------------------------------------------------
    def extend(self, w, factor):
        """w ⊗ factor with the 0̄ guard (0̄ absorbs: no path stays no path)."""
        if self.name == "sum":
            return w * factor
        if self.name == "bool":
            return jnp.where((w > 0) & (factor != 0), 1.0, 0.0)
        return jnp.where(w == self.zero, self.zero, w * factor)

    # -- structural ops ------------------------------------------------------
    def mask(self, w, keep):
        """Predicate filter: keep where ``keep`` (bool/0-1), else 0̄."""
        return jnp.where(keep > 0, w, self.zero)

    def from_mask(self, m):
        """0/1 mask → frontier of 1̄/0̄ (seeding from an intersection mask)."""
        return jnp.where(m > 0, self.one, self.zero)

    def binarize(self, w):
        """Semijoin ⋉: collapse path multiplicity to one path (paper §6.1)."""
        if self.name == "sum":
            return (w > 0).astype(jnp.float32)
        return jnp.where(w != self.zero, self.one, self.zero)

    def to_mask(self, w):
        """Accumulator → 0/1 membership mask (mask-producing chains)."""
        if self.name in ("sum", "bool"):
            return (w > 0).astype(jnp.float32)
        return (w != self.zero).astype(jnp.float32)

    def finalize(self, w):
        """Output convention: unreached groups report 0, not 0̄."""
        if self.zero == 0.0:
            return w
        return jnp.where(w == self.zero, 0.0, w)

    # -- scalar strategy hooks ----------------------------------------------
    def scatter(self, acc, idx, val):
        """Single-path ⊕-update of the dense accumulator (fragment-at-a-time
        strategy: one scalar update per completed path, paper Fig. 3)."""
        if self.name == "sum":
            return acc.at[idx].add(val)
        if self.name == "min":
            return acc.at[idx].min(val)
        return acc.at[idx].max(val)

    def select(self, keep, w):
        """Scalar weight filter: ``w`` if keep else 0̄ (a 0̄-weighted path is
        discarded by ``scatter`` since 0̄ is the ⊕ identity... except for sum,
        where adding 0 is equally a no-op)."""
        return jnp.where(keep, w, self.zero)


SUM_PRODUCT = Semiring("sum", zero=0.0)
MIN_PRODUCT = Semiring("min", zero=float("inf"))
MAX_PRODUCT = Semiring("max", zero=float("-inf"))
BOOL_OR_AND = Semiring("bool", zero=0.0)

SEMIRINGS = {
    "sum": SUM_PRODUCT,
    "count": SUM_PRODUCT,
    "avg": SUM_PRODUCT,  # fused SUM+COUNT pair, divided at finalize
    "min": MIN_PRODUCT,
    "max": MAX_PRODUCT,
    "exists": BOOL_OR_AND,
    None: BOOL_OR_AND,  # mask-producing plans are reachability queries
}


def semiring_for(agg: str | None) -> Semiring:
    try:
        return SEMIRINGS[agg]
    except KeyError:
        raise ValueError(f"no semiring registered for aggregate {agg!r}") from None
