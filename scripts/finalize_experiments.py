"""Inject the roofline baseline table and §Perf variant comparisons into
EXPERIMENTS.md from artifacts/dryrun/*.json. Idempotent."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, "src")

from repro.roofline.analysis import (  # noqa: E402
    load_records,
    report,
    roofline_from_record,
)

MARK = "<!-- ROOFLINE_TABLE -->"
END = "<!-- ROOFLINE_TABLE_END -->"


def _fmt_variant_rows() -> str:
    recs = {f"{r['arch']}|{r['shape']}|{r['mesh']}|{r.get('variant','')}": r
            for r in load_records()}

    def row(arch, shape, base_variant, opt_variant, label, mesh="pod_16x16"):
        b = recs.get(f"{arch}|{shape}|{mesh}|{base_variant}")
        o = recs.get(f"{arch}|{shape}|{mesh}|{opt_variant}")
        if not b or not o or b["status"] != "ok" or o["status"] != "ok":
            return f"| {arch} × {shape} | {label} | (artifact missing) | | |"
        bt, ot = b["memory"].get("temp_size_in_bytes", 0), o["memory"].get("temp_size_in_bytes", 0)
        bc, oc = sum(b.get("collectives", {}).values()), sum(o.get("collectives", {}).values())
        brl, orl = roofline_from_record(b), roofline_from_record(o)
        return (
            f"| {arch} × {shape} | {label} | "
            f"temp {bt/1e9:.1f}→{ot/1e9:.1f} GB | "
            f"coll {bc/1e9:.2f}→{oc/1e9:.2f} GB ({brl.collective_s*1e3:.1f}→{orl.collective_s*1e3:.1f} ms) | "
            f"dominant {brl.dominant}→{orl.dominant} |"
        )

    lines = [
        "| cell | change (naive → optimized) | memory | collective bytes (term) | dominant |",
        "|---|---|---|---|---|",
        row("llama3-8b", "train_4k", "naive", "", "SP residual + 8× grad-accum"),
        row("arctic-480b", "train_4k", "naive", "", "SP + grad-accum + FSDP grad constraints"),
        row("mace", "ogb_products", "naive", "", "channel-TP + per-block remat + edge hints"),
        row("gqfast-pubmed", "as_b8", "", "bf16_frontier", "fp32→bf16 frontier psum"),
        row("gqfast-pubmed", "as_b8", "data_only", "", "edge shards 16→256 (data→data×model)"),
    ]
    return "\n".join(lines)


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        doc = f.read()

    table = (
        MARK + "\n\n### Baseline roofline — single pod (16×16 = 256 chips)\n\n"
        + report(mesh="pod_16x16")
        + "\n\n### Baseline roofline — multi-pod (2×16×16 = 512 chips)\n\n"
        + report(mesh="multipod_2x16x16")
        + "\n\n### §Perf variant comparisons (artifact pairs)\n\n"
        + _fmt_variant_rows()
        + "\n\n" + END
    )
    if END in doc:
        pre = doc.split(MARK)[0]
        post = doc.split(END)[1]
        doc = pre + table + post
    else:
        doc = doc.replace(MARK, table)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    recs = load_records()
    ok = sum(1 for r in recs if r["status"] == "ok" and not r.get("variant"))
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = sum(1 for r in recs if r["status"] == "error")
    print(f"finalized: {ok} ok baseline cells, {sk} skipped, {er} errors")


if __name__ == "__main__":
    main()
