"""GNN arch configs: the four assigned equivariant/molecular GNNs × the four
assigned graph shapes. Edge counts are padded to multiples of 512 so the edge
axis shards over (data×model); non-molecular shapes use synthesized positions
and a node-classification head (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import gnn_input_shardings, named, replicated
from ..models.gnn.models import GNNConfig, gnn_init, gnn_loss
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .base import ArchConfig, Cell


def _pad512(n: int) -> int:
    return -(-n // 512) * 512


GNN_SHAPES = {
    # shape_id: nodes, edges, d_feat, n_classes, graphs (0 → node-level)
    "full_graph_sm": dict(n=2708, e=_pad512(10556), d_feat=1433, n_classes=7, graphs=0),
    "minibatch_lg": dict(n=1024 * (1 + 15 + 150), e=1024 * 15 * (1 + 10),
                         d_feat=602, n_classes=41, graphs=0),
    "ogb_products": dict(n=2_449_029, e=_pad512(61_859_140), d_feat=100,
                         n_classes=47, graphs=0),
    "molecule": dict(n=128 * 30, e=128 * 64, d_feat=0, n_classes=0, graphs=128),
}


class GNNArch(ArchConfig):
    kind = "gnn"
    shape_ids = list(GNN_SHAPES)

    def __init__(self, arch_id: str, base: GNNConfig, smoke_cfg: GNNConfig):
        self.arch_id = arch_id
        self.base = base
        self.smoke_cfg = smoke_cfg
        self.opt = AdamWConfig(lr=1e-3, weight_decay=0.0)

    def _cfg_for(self, shape_id: str) -> GNNConfig:
        sh = GNN_SHAPES[shape_id]
        return dataclasses.replace(
            self.base, d_feat=sh["d_feat"], n_classes=sh["n_classes"]
        )

    def make_cell(self, shape_id: str, mesh, variant: str = "") -> Cell:
        sh = GNN_SHAPES[shape_id]
        cfg = self._cfg_for(shape_id)
        N, E, G = sh["n"], sh["e"], sh["graphs"]
        f32, i32 = jnp.float32, jnp.int32
        batch_abs = {
            "pos": jax.ShapeDtypeStruct((N, 3), f32),
            "z": jax.ShapeDtypeStruct((N,), i32),
            "edge_src": jax.ShapeDtypeStruct((E,), i32),
            "edge_dst": jax.ShapeDtypeStruct((E,), i32),
            "node_mask": jax.ShapeDtypeStruct((N,), f32),
            "edge_mask": jax.ShapeDtypeStruct((E,), f32),
        }
        if sh["d_feat"]:
            batch_abs["node_feat"] = jax.ShapeDtypeStruct((N, sh["d_feat"]), f32)
        if G:
            batch_abs["graph_ids"] = jax.ShapeDtypeStruct((N,), i32)
            batch_abs["labels"] = jax.ShapeDtypeStruct((G,), f32)
        else:
            batch_abs["labels"] = jax.ShapeDtypeStruct((N,), i32)

        params_abs = jax.eval_shape(lambda: gnn_init(cfg, jax.random.key(0)))
        opt_abs = jax.eval_shape(functools.partial(adamw_init, cfg=self.opt), params_abs)
        state_abs = (params_abs, opt_abs)
        n_graphs = G or 1

        def fn(state, batch):
            from ..models.gnn import common as gcommon, models as gmodels

            gcommon.EDGE_HINTS = variant != "naive"
            gmodels.REMAT = variant != "naive"
            params, opt_state = state
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: gnn_loss(p, batch, cfg, n_graphs), has_aux=True
            )(params)
            gcommon.EDGE_HINTS = True
            gmodels.REMAT = True
            params, opt_state, om = adamw_update(grads, opt_state, params, self.opt)
            return (params, opt_state), {**metrics, **om}

        state_sh = replicated(state_abs, mesh)
        batch_sh = gnn_input_shardings(batch_abs, mesh)
        n_params = sum(x.size for x in jax.tree.leaves(params_abs))
        return Cell(self.arch_id, shape_id, fn, (state_abs, batch_abs),
                    (state_sh, batch_sh), None, "train", 6.0 * n_params * N)

    def smoke(self) -> dict:
        from ..data.graphs import make_molecule_batch

        cfg = self.smoke_cfg
        mol = make_molecule_batch(batch=4, n_nodes=8, n_edges=16)
        batch = mol.as_inputs()
        params = gnn_init(cfg, jax.random.key(0))
        opt = adamw_init(params, self.opt)
        (loss, _), grads = jax.value_and_grad(
            lambda p: gnn_loss(p, batch, cfg, 4), has_aux=True
        )(params)
        params2, _, om = adamw_update(grads, opt, params, self.opt)
        return {
            "loss": float(loss),
            "grad_norm": float(om["grad_norm"]),
            "finite": bool(jnp.isfinite(loss))
            and all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(params2)),
        }


# the four assigned architectures (exact hyperparameters from the assignment)
MACE = GNNArch(
    "mace",
    GNNConfig("mace", "mace", n_layers=2, d_hidden=128, l_max=2, correlation=3,
              n_rbf=8, cutoff=5.0),
    GNNConfig("mace-smoke", "mace", n_layers=2, d_hidden=16, l_max=2,
              correlation=3, n_rbf=8, cutoff=6.0),
)
EGNN = GNNArch(
    "egnn",
    GNNConfig("egnn", "egnn", n_layers=4, d_hidden=64),
    GNNConfig("egnn-smoke", "egnn", n_layers=2, d_hidden=16),
)
EQUIFORMER_V2 = GNNArch(
    "equiformer-v2",
    GNNConfig("equiformer-v2", "equiformer_v2", n_layers=12, d_hidden=128,
              l_max=6, m_max=2, n_heads=8, n_rbf=16, cutoff=8.0),
    GNNConfig("eqv2-smoke", "equiformer_v2", n_layers=2, d_hidden=16, l_max=3,
              m_max=2, n_heads=4, n_rbf=8, cutoff=6.0),
)
SCHNET = GNNArch(
    "schnet",
    GNNConfig("schnet", "schnet", n_layers=3, d_hidden=64, n_rbf=300, cutoff=10.0),
    GNNConfig("schnet-smoke", "schnet", n_layers=2, d_hidden=16, n_rbf=16, cutoff=10.0),
)
