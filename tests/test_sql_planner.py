"""SQL parser + RQNA normalizer/verifier tests."""
import pytest

from repro.core.algebra import EntityStep, RelHop, SeedIds, SeedMask
from repro.core.planner import NotRelationshipQuery, plan_query
from repro.core.sql import parse
from repro.data import synth_graph as SG


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=100, n_terms=20, n_authors=50)


def test_parse_as(pubmed):
    q = parse(SG.QUERY_AS)
    assert len(q.tables) == 5
    assert len(q.join_conds) == 4
    assert q.group_by is not None


def test_plan_as_chain(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_AS))
    kinds = [type(s).__name__ for s in p.steps]
    assert kinds == ["RelHop", "RelHop", "RelHop", "EntityStep", "RelHop"]
    assert isinstance(p.seed, SeedIds) and p.seed.entity == "Author"
    assert p.group_entity == "Author" and p.agg == "sum"
    # measures attached to the two DT hops; year factor on the entity step
    dt_hops = [s for s in p.steps if isinstance(s, RelHop) and s.table == "DT"]
    assert all(h.measure_expr is not None for h in dt_hops)
    ent = [s for s in p.steps if isinstance(s, EntityStep)][0]
    assert ent.factor_expr is not None


def test_plan_ad_semijoin_mask(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_AD))
    assert isinstance(p.seed, SeedMask) and p.seed.entity == "Document"
    assert len(p.seed.chains) == 2
    assert p.steps[0].semijoin and p.agg == "count"


def test_plan_recent_authors_degree_filter(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_RECENT_AUTHORS))
    assert p.group_entity is None and p.output_ref.attr == "Author"
    assert isinstance(p.seed, SeedMask) and len(p.seed.chains) == 2
    assert p.seed.entity_conds, "Year > :y must become an entity condition"
    # third chain projects da.Doc → degree-filter hop
    sub = p.seed.chains[-1]
    assert sub.steps[-1].degree_filter


def test_plan_cs_comma_joins():
    sem = SG.make_semmeddb(50, 60, 80, 200)
    p = plan_query(sem, parse(SG.QUERY_CS))
    assert [s.table for s in p.steps] == ["SP", "PA", "CS"]
    assert p.steps[0].semijoin
    assert p.group_entity == "Concept"


def test_group_by_relationship_id_quirk(pubmed):
    # the paper writes GROUP BY da2.ID on a relationship variable
    p = plan_query(pubmed, parse(SG.QUERY_AS))
    assert p.group_ref.attr == "Author"


def test_rejects_non_key_join(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt JOIN Document d ON dt.Fre = d.Year WHERE dt.Doc = 1 GROUP BY dt.Doc"
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_rejects_unknown_table(pubmed):
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse("SELECT x.A FROM Nope x WHERE x.A = 1"))


def test_rejects_no_seed(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt GROUP BY dt.Doc"
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_rejects_nonmultiplicative_score(pubmed):
    bad = """SELECT dt2.Doc, SUM(dt1.Fre + dt2.Fre)
             FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
             WHERE dt1.Doc = 1 GROUP BY dt2.Doc"""
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_parse_intersect_inside_parens(pubmed):
    q = """SELECT da.Author, COUNT(*) FROM DA da WHERE da.Doc IN
           ((SELECT dt.Doc FROM DT dt WHERE dt.Term = 1)
            INTERSECT (SELECT dt.Doc FROM DT dt WHERE dt.Term = 2))
           GROUP BY da.Author"""
    p = plan_query(pubmed, parse(q))
    assert len(p.seed.chains) == 2


def test_parse_errors():
    with pytest.raises(SyntaxError):
        parse("SELECT FROM x")
    with pytest.raises(SyntaxError):
        parse("SELECT a.b FROM T t WHERE a.b ~ 3")
