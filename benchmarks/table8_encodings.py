"""Paper Table 8: encoded column sizes under UA/BCA/BB/Huffman (+DictBCA, the
TPU substitute) on the PubMed-MS-shaped dataset. Bold-winner per column should
match the Fig.-12 chooser."""
from __future__ import annotations

import numpy as np

from repro.core import codecs as C
from repro.core.fragments import build_index

from .common import emit, pubmed_ms


def run() -> None:
    schema = pubmed_ms()
    for rel_name, key, col in [
        ("DT", "Term", "Doc"),   # dt2.Doc
        ("DT", "Doc", "Term"),   # dt1.Term
        ("DT", "Doc", "Fre"),    # dt1.Fre
        ("DT", "Term", "Fre"),   # dt2.Fre
        ("DA", "Author", "Doc"), # da1.Doc
        ("DA", "Doc", "Author"), # da2.Author
    ]:
        rel = schema.relationships[rel_name]
        sizes = {}
        for enc in ("UA", "BCA", "BB", "Huffman", "DictBCA"):
            if enc == "BB" and col in rel.measures:
                continue  # bitmaps need unique values (paper Table 8 N/A)
            idx = build_index(schema, rel, key, encodings={col: enc},
                              keep_packed=False, account_space=True)
            sizes[enc] = idx.columns[col].encoded_bytes
        best = min(sizes, key=sizes.__getitem__)
        chosen = build_index(schema, rel, key, keep_packed=False,
                             account_space=True).columns[col].encoding
        for enc, b in sizes.items():
            emit(f"table8/{rel_name}.{key}/{col}/{enc}", b,
                 f"best={best} chooser={chosen}" if enc == best else "")


if __name__ == "__main__":
    run()
