"""Query-lifecycle observability (DESIGN.md §Observability).

Zero-dependency subsystem threaded through parse → lower → compile → execute:

  * :mod:`.trace`   — context-var span tracer (no-op when disabled).
  * :mod:`.metrics` — counters / gauges / fixed-bucket histograms + registry.
  * :mod:`.profile` — ``QueryProfile`` (per-IR-op timings, predicted-vs-
    observed hop fractions, device memory) behind ``PreparedQuery.profile()``
    and ``explain(analyze=True)``.

Importing this package pulls no jax; the profiling module imports it lazily.
"""
from . import metrics, trace  # noqa: F401
from .metrics import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from .trace import Tracer, annotate, current, enabled, recording, span  # noqa: F401
