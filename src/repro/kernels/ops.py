"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute via ``interpret=True`` (Pallas body run
as Python/XLA — the correctness validation mode mandated for this environment);
on TPU they run compiled. ``use_pallas=False`` selects the pure-XLA fallback
(identical math from :mod:`repro.kernels.ref`)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bitmap_ops import bitmap_and as _bitmap_and
from .bitmap_ops import bitmap_and_popcount as _bitmap_and_popcount
from .bitunpack import bitunpack as _bitunpack
from .fragment_spmm import fragment_spmm as _fragment_spmm
from .fragment_spmm import fragment_spmm_packed as _fragment_spmm_packed
from .fragment_spmv import fragment_spmv as _fragment_spmv
from .fragment_spmv_packed import fragment_spmv_packed as _fragment_spmv_packed


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bitunpack(packed, width: int, count: int, use_pallas: bool = True):
    if not use_pallas:
        return ref.bitunpack_ref(jnp.asarray(packed, jnp.uint32), width, count)
    return _bitunpack(jnp.asarray(packed, jnp.uint32), width, count, interpret=_interpret())


def fragment_spmv(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True):
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if not use_pallas:
        return ref.fragment_spmv_ref(w, s, d, m, n_dst, op=op)
    return _fragment_spmv(w, s, d, m, n_dst, op=op, interpret=_interpret())


def fragment_spmm(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True):
    """Batched multi-query hop: ``Y[b, dst] ⊕= W[b, src] ⊗ m`` with one edge
    stream serving all B frontier rows (see fragment_spmm.py). ``measures``
    may be [E] (shared — the fused-kernel case) or [B, E] (per-row, e.g. a
    seed-scalar-dependent measure expression): per-row streams have no
    single-pass formulation and always take the XLA fallback, a vmap'd
    segment-combine."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if m.ndim == 2 or not use_pallas:
        return ref.fragment_spmm_ref(w, s, d, m, n_dst, op=op)
    return _fragment_spmm(w, s, d, m, n_dst, op=op, interpret=_interpret())


def fragment_spmm_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True):
    """Decode-fused batched hop: packed dst/measure word streams decode once
    per 4096-edge block in VMEM and serve all B frontier rows."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmm_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    return _fragment_spmm_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )


def fragment_spmv_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True):
    """Decode-fused hop: ``dst``/``measure`` may be BCA word streams that are
    unpacked block-at-a-time inside the SpMV (see fragment_spmv_packed.py)."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmv_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    return _fragment_spmv_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )


def bitmap_and(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_ref(a, b)
    return _bitmap_and(a, b, interpret=_interpret())


def bitmap_and_popcount(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_popcount_ref(a, b)
    return _bitmap_and_popcount(a, b, interpret=_interpret())
