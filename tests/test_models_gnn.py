"""GNN + equivariance tests: SH/Wigner/CG properties (hypothesis over random
rotations), model rotation invariance, permutation invariance, shapes."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.graphs import CSRGraph, NeighborSampler, make_feature_graph, make_molecule_batch
from repro.models.gnn.equivariant import (
    l_slices,
    real_cg,
    real_sph_harm,
    rotation_to_edge_frame,
    wigner_d_real,
)
from repro.models.gnn.models import GNNConfig, gnn_apply, gnn_init, gnn_loss

settings.register_profile("g", deadline=None, max_examples=10)
settings.load_profile("g")

CONFIGS = [
    GNNConfig("schnet-s", "schnet", 2, 32, n_rbf=8, cutoff=6.0),
    GNNConfig("egnn-s", "egnn", 2, 32),
    GNNConfig("mace-s", "mace", 2, 16, n_rbf=8, cutoff=6.0, l_max=2, correlation=3),
    GNNConfig("eqv2-s", "equiformer_v2", 2, 16, l_max=3, m_max=2, n_heads=4,
              n_rbf=8, cutoff=6.0),
]


def _rand_rot(seed):
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return jnp.asarray(Q, jnp.float32)


@given(st.integers(0, 2**31))
def test_sph_harm_equivariance(seed):
    R = _rand_rot(seed)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(20, 3))
    v = jnp.asarray(v / np.linalg.norm(v, axis=1, keepdims=True), jnp.float32)
    l_max = 4
    Y = real_sph_harm(l_max, v)
    Yr = real_sph_harm(l_max, jnp.einsum("ij,nj->ni", R, v))
    D = wigner_d_real(l_max, R)
    for l, sl in enumerate(l_slices(l_max)):
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("mk,nk->nm", D[l], Y[:, sl])),
            np.asarray(Yr[:, sl]), atol=5e-5,
        )


@given(st.integers(0, 2**31))
def test_wigner_orthogonality(seed):
    D = wigner_d_real(4, _rand_rot(seed))
    for l, d in enumerate(D):
        np.testing.assert_allclose(np.asarray(d @ d.T), np.eye(2 * l + 1), atol=5e-5)


@pytest.mark.parametrize("l1,l2,l3", [(1, 1, 2), (2, 2, 2), (1, 2, 3), (2, 2, 0)])
def test_real_cg_equivariance(l1, l2, l3):
    C = jnp.asarray(real_cg(l1, l2, l3))
    R = _rand_rot(l1 * 100 + l2 * 10 + l3)
    D = wigner_d_real(max(l1, l2, l3), R)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2 * l1 + 1,)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(2 * l2 + 1,)), jnp.float32)
    z = jnp.einsum("ijk,i,j->k", C, x, y)
    zr = jnp.einsum("ijk,i,j->k", C, D[l1] @ x, D[l2] @ y)
    np.testing.assert_allclose(np.asarray(D[l3] @ z), np.asarray(zr), atol=1e-5)


def test_edge_frame_maps_to_z():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(50, 3)), jnp.float32)
    R = rotation_to_edge_frame(v)
    n = v / jnp.linalg.norm(v, axis=1, keepdims=True)
    out = jnp.einsum("eij,ej->ei", R, n)
    np.testing.assert_allclose(np.asarray(out[:, 2]), 1.0, atol=1e-5)
    # proper rotations
    det = np.linalg.det(np.asarray(R))
    np.testing.assert_allclose(det, 1.0, atol=1e-5)


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.arch for c in CONFIGS])
def test_rotation_invariance(cfg):
    mol = make_molecule_batch(batch=4, n_nodes=8, n_edges=16)
    binp = mol.as_inputs()
    rot = dict(binp)
    rot["pos"] = binp["pos"] @ _rand_rot(7).T
    p = gnn_init(cfg, jax.random.key(0))
    e1 = gnn_apply(p, binp, cfg, 4)
    e2 = gnn_apply(p, rot, cfg, 4)
    scale = float(jnp.abs(e1).max()) + 1e-9
    assert float(jnp.abs(e1 - e2).max()) / scale < 2e-2, cfg.arch


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.arch for c in CONFIGS])
def test_translation_invariance(cfg):
    mol = make_molecule_batch(batch=2, n_nodes=6, n_edges=12)
    binp = mol.as_inputs()
    tr = dict(binp)
    tr["pos"] = binp["pos"] + jnp.asarray([1.5, -2.0, 0.7])
    p = gnn_init(cfg, jax.random.key(0))
    e1, e2 = gnn_apply(p, binp, cfg, 2), gnn_apply(p, tr, cfg, 2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.arch for c in CONFIGS])
def test_edge_mask_zeroes_padding(cfg):
    """Adding masked-out padding edges must not change the output."""
    mol = make_molecule_batch(batch=2, n_nodes=6, n_edges=12)
    b = mol.as_inputs()
    p = gnn_init(cfg, jax.random.key(0))
    e1 = gnn_apply(p, b, cfg, 2)
    b2 = dict(b)
    pad = 8
    b2["edge_src"] = jnp.concatenate([b["edge_src"], jnp.zeros(pad, jnp.int32)])
    b2["edge_dst"] = jnp.concatenate([b["edge_dst"], jnp.ones(pad, jnp.int32)])
    b2["edge_mask"] = jnp.concatenate([b["edge_mask"], jnp.zeros(pad)])
    e2 = gnn_apply(p, b2, cfg, 2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-5)


def test_node_classification_head():
    g = make_feature_graph(100, 400, d_feat=16, n_classes=5)
    cfg = GNNConfig("s", "schnet", 2, 16, n_rbf=8, d_feat=16, n_classes=5)
    p = gnn_init(cfg, jax.random.key(0))
    logits = gnn_apply(p, g.as_inputs(), cfg)
    assert logits.shape == (100, 5)
    loss, _ = gnn_loss(p, g.as_inputs(), cfg)
    assert bool(jnp.isfinite(loss))


def test_neighbor_sampler_budgets():
    g = CSRGraph.random(5000, 50000, d_feat=8)
    s = NeighborSampler(g, fanouts=[5, 3], batch_nodes=64)
    batch = s.sample()
    assert batch.edge_src.shape == batch.edge_dst.shape == batch.edge_mask.shape
    assert batch.edge_src.shape[0] == 64 * 5 * (1 + 3)
    assert int(batch.edge_src.max()) < batch.pos.shape[0]
    # sampled edges actually exist in the CSR graph (for unmasked entries)
    uniq = np.unique(np.concatenate([np.asarray(batch.edge_src), np.asarray(batch.edge_dst)]))
    assert uniq.shape[0] <= batch.pos.shape[0]
