"""Fault-tolerant query lifecycle tests (DESIGN.md §Robustness).

Covers the typed QueryError taxonomy, admission control + the prepared-query
LRU, the deadline machinery, the degradation ladder (including result
agreement across rungs), deterministic fault injection, and an in-process
chaos serve smoke.
"""
import numpy as np
import pytest

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG
from repro.obs.metrics import MetricsRegistry
from repro.robust import (
    LADDER,
    AdmissionController,
    Deadline,
    DeadlineExceeded,
    ExecutionError,
    MemoryBudget,
    ParseError,
    PlanError,
    PreparedCache,
    QueryError,
    ResourceError,
    RetryPolicy,
    RobustPolicy,
    ValidationError,
    estimate_query_bytes,
    run_batch_with_policy,
    run_with_policy,
    wrap_execution_error,
)
from repro.robust import faults
from repro.robust.runner import rung_fn


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=60, n_terms=40, n_authors=30, seed=0)


@pytest.fixture(scope="module")
def db(pubmed):
    return GQFastDatabase(pubmed)


@pytest.fixture(scope="module")
def engine(db):
    return GQFastEngine(db)


@pytest.fixture(scope="module")
def prepared_sd(engine):
    return engine.prepare(SG.QUERY_SD)


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


def test_taxonomy_codes_and_compat():
    # every class carries a stable machine-readable code and keeps the
    # builtin-exception compatibility contract (existing callers' excepts)
    cases = [
        (ParseError, "PARSE", (SyntaxError,)),
        (PlanError, "PLAN", (ValueError,)),
        (ValidationError, "VALIDATION", (ValueError, TypeError)),
        (ResourceError, "ADMISSION_OR_RESOURCE", (RuntimeError,)),
        (DeadlineExceeded, "DEADLINE", (TimeoutError,)),
        (ExecutionError, "EXECUTION", (RuntimeError,)),
    ]
    for cls, _, bases in cases:
        e = cls("boom", extra=1)
        assert isinstance(e, QueryError)
        for b in bases:
            assert isinstance(e, b), (cls, b)
        assert e.code  # non-empty default code
        assert e.retryable in (True, False)
        d = e.to_dict()
        assert d["code"] == e.code and d["retryable"] == e.retryable
        assert d["context"]["extra"] == 1
        assert "boom" in str(e)


def test_with_context_setdefault_semantics():
    e = ExecutionError("x", op="HopOp")
    e.with_context(op="other", rung="scan")
    assert e.context["op"] == "HopOp"  # original context wins
    assert e.context["rung"] == "scan"


def test_wrap_execution_error_passthrough_and_foreign():
    orig = ValidationError("bad")
    assert wrap_execution_error(orig, rung="scan") is orig
    wrapped = wrap_execution_error(KeyError("k"), rung="scan")
    assert isinstance(wrapped, ExecutionError) and not wrapped.retryable
    assert isinstance(wrapped.__cause__, KeyError)


def test_prepare_failures_are_typed_with_query_context(engine):
    with pytest.raises(ParseError) as ei:
        engine.prepare("SELECT FROM x")
    assert ei.value.context.get("position") is not None
    with pytest.raises(PlanError) as ei:
        engine.prepare("SELECT x.A FROM Nope x WHERE x.A = 1")
    assert "query" in ei.value.context
    # unknown GROUP BY variable used to escape as a raw KeyError
    with pytest.raises(PlanError):
        engine.prepare(
            "SELECT dt.Doc, COUNT(*) FROM DT dt WHERE dt.Doc = 1"
            " GROUP BY zz.Doc"
        )


def test_param_validation(engine, prepared_sd):
    with pytest.raises(ValidationError, match="missing"):
        prepared_sd()
    with pytest.raises(ValidationError, match="unknown"):
        prepared_sd(d0=1, nope=2)
    pad = engine.prepare(SG.QUERY_AD)
    with pytest.raises(ValidationError, match="ragged"):
        pad._batch_args({"t1": [1, 2], "t2": [1]})
    with pytest.raises(ValidationError, match="scalar"):
        prepared_sd._batch_args({"d0": 3})
    # the taxonomy keeps execute_batch's historical TypeError contract
    with pytest.raises(TypeError, match="missing"):
        prepared_sd._batch_args({})


def test_bad_block_skipping_is_validation_error(engine):
    with pytest.raises(ValidationError, match="block_skipping"):
        engine.prepare(SG.QUERY_SD, block_skipping="sometimes")


# ---------------------------------------------------------------------------
# Admission control + prepared LRU
# ---------------------------------------------------------------------------


def test_estimate_monotonic_in_batch(prepared_sd):
    e1 = estimate_query_bytes(prepared_sd, 1)
    e64 = estimate_query_bytes(prepared_sd, 64)
    assert e1["resident_bytes"] == e64["resident_bytes"] > 0
    assert e64["working_bytes"] > e1["working_bytes"] > 0


def test_admission_admit_demote_reject(prepared_sd):
    reg = MetricsRegistry()
    est1 = estimate_query_bytes(prepared_sd, 1)["total_bytes"]
    est64 = estimate_query_bytes(prepared_sd, 64)["total_bytes"]
    # budget between the single and batched footprint → demote
    mid = AdmissionController(
        MemoryBudget(limit_bytes=int((est1 + est64) / 2 / 0.9)), reg
    )
    assert mid.decide(prepared_sd, 1).action == "admit"
    assert mid.decide(prepared_sd, 64).action == "demote"
    with pytest.raises(ResourceError):
        mid.admit(prepared_sd, 64)  # demote without allow_demote raises
    assert mid.admit(prepared_sd, 64, allow_demote=True).action == "demote"
    tiny = AdmissionController(MemoryBudget(limit_bytes=16), reg)
    assert tiny.decide(prepared_sd, 1).action == "reject"
    with pytest.raises(ResourceError) as ei:
        tiny.admit(prepared_sd, 1)
    assert ei.value.code == "ADMISSION"
    assert reg.counter("robust.admission_rejections").snapshot() >= 1
    assert reg.counter("robust.admission_demotions").snapshot() >= 1
    # no budget configured → everything admits
    free = AdmissionController(MemoryBudget(), reg)
    assert free.decide(prepared_sd, 4096).action == "admit"


def test_prepared_cache_lru():
    reg = MetricsRegistry()
    c = PreparedCache(capacity=2, registry=reg)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1  # refresh: 'b' is now LRU
    c.put("c", 3)
    assert "b" not in c and "a" in c and "c" in c
    assert reg.counter("engine.prepared_cache_evictions").snapshot() == 1
    assert reg.counter("engine.prepared_cache_hits").snapshot() == 1
    with pytest.raises(ValueError):
        PreparedCache(capacity=0)


def test_engine_prepare_cache_bounded(db):
    eng = GQFastEngine(db, max_prepared=2)
    a = eng.prepare(SG.QUERY_SD)
    assert eng.prepare(SG.QUERY_SD) is a  # hit
    eng.prepare(SG.QUERY_AD)
    eng.prepare(SG.QUERY_FAD)  # evicts QUERY_SD
    assert eng.prepare(SG.QUERY_SD) is not a


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------


def test_deadline_object():
    dl = Deadline(10_000.0)
    dl.check("nowhere")  # plenty of budget
    dl2 = Deadline(0.0)
    assert dl2.expired()
    with pytest.raises(DeadlineExceeded) as ei:
        dl2.check("op[HopOp]")
    assert ei.value.context["where"] == "op[HopOp]"


def test_deadline_trips_on_injected_delay(prepared_sd):
    plan = faults.FaultPlan(seed=1).add(
        faults.FaultSpec(site="runner.execute", mode="delay", delay_ms=60.0)
    )
    with faults.active(plan):
        oc = run_with_policy(prepared_sd, {"d0": 3}, deadline_ms=25.0)
    assert oc.status == "error" and oc.error.code == "DEADLINE"
    # without the delay the same deadline is generous
    oc = run_with_policy(prepared_sd, {"d0": 3}, deadline_ms=10_000.0)
    assert oc.status == "ok"


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


def test_ladder_rungs_agree_sd(engine, prepared_sd):
    want = prepared_sd(d0=3)
    for rung in LADDER:
        got = np.asarray(rung_fn(prepared_sd, rung)(3))
        # integer-semiring results are bit-identical on every rung
        assert np.array_equal(got, want), rung


def test_ladder_rungs_agree_float_measures(engine):
    # float-measure chains: scan/xla are bit-identical (same ⊕ order);
    # fragment_loop accumulates per-edge and agrees to float tolerance
    # (the documented bit-identity caveat, DESIGN.md §Robustness)
    p = engine.prepare(SG.QUERY_AS)
    want = p(a0=2)
    for rung in ("scan", "xla"):
        assert np.array_equal(np.asarray(rung_fn(p, rung)(2)), want), rung
    got = np.asarray(rung_fn(p, "fragment_loop")(2))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_retry_then_success_is_degraded(prepared_sd):
    reg = MetricsRegistry()
    plan = faults.FaultPlan(seed=2).add(
        faults.FaultSpec(site="runner.execute", mode="raise", max_fires=1)
    )
    pol = RobustPolicy(retry=RetryPolicy(max_attempts=3, base_ms=0.1),
                       registry=reg)
    with faults.active(plan):
        oc = run_with_policy(prepared_sd, {"d0": 3}, policy=pol)
    assert oc.status == "degraded" and oc.rung == "active"
    assert oc.attempts == 2 and not oc.demotions
    assert reg.counter("robust.retries").snapshot() == 1
    assert np.array_equal(oc.value, prepared_sd(d0=3))


def test_exhausted_retries_demote_down_ladder(prepared_sd):
    reg = MetricsRegistry()
    plan = faults.FaultPlan(seed=2).add(
        faults.FaultSpec(site="runner.execute", mode="raise", max_fires=3)
    )
    pol = RobustPolicy(retry=RetryPolicy(max_attempts=2, base_ms=0.1),
                       registry=reg)
    with faults.active(plan):
        oc = run_with_policy(prepared_sd, {"d0": 3}, policy=pol)
    assert oc.status == "degraded" and oc.demotions == ("active",)
    assert oc.rung == "unfused"
    assert reg.counter("robust.demotions.active").snapshot() == 1
    assert np.array_equal(oc.value, prepared_sd(d0=3))


def test_all_rungs_failing_returns_typed_error(prepared_sd):
    plan = faults.FaultPlan(seed=2).add(
        faults.FaultSpec(site="runner.execute", mode="raise")
    )
    pol = RobustPolicy(retry=RetryPolicy(max_attempts=1))
    with faults.active(plan):
        oc = run_with_policy(prepared_sd, {"d0": 3}, policy=pol)
    assert oc.status == "error" and not oc.ok
    assert oc.error.code == "FAULT_INJECTED"
    assert oc.demotions == LADDER


def test_run_with_policy_never_raises_on_bad_params(prepared_sd):
    oc = run_with_policy(prepared_sd, {"wrong": 1})
    assert oc.status == "error" and oc.error.code == "VALIDATION"


def test_batch_policy_matches_execute_batch(prepared_sd):
    arr = np.arange(6)
    ocs = run_batch_with_policy(prepared_sd, {"d0": arr})
    ref = prepared_sd.execute_batch(d0=arr)
    assert len(ocs) == 6 and all(o.status == "ok" for o in ocs)
    for i, o in enumerate(ocs):
        assert np.array_equal(o.value, ref[i])


def test_batch_admission_demotes_to_serial(prepared_sd):
    est1 = estimate_query_bytes(prepared_sd, 1)["total_bytes"]
    est64 = estimate_query_bytes(prepared_sd, 64)["total_bytes"]
    ctl = AdmissionController(
        MemoryBudget(limit_bytes=int((est1 + est64) / 2 / 0.9)),
        MetricsRegistry(),
    )
    pol = RobustPolicy(admission=ctl, registry=MetricsRegistry())
    arr = np.arange(64)
    ocs = run_batch_with_policy(prepared_sd, {"d0": arr}, policy=pol)
    ref = prepared_sd.execute_batch(d0=arr)
    assert all(o.status == "degraded" for o in ocs)  # served, but serially
    for i, o in enumerate(ocs):
        assert np.array_equal(o.value, ref[i])


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------


def test_fault_determinism_and_counting():
    def run(seed):
        plan = faults.FaultPlan(seed=seed).add(
            faults.FaultSpec(site="x", mode="raise", prob=0.5, max_fires=50)
        )
        seq = []
        with faults.active(plan):
            for _ in range(30):
                try:
                    faults.fire("x")
                    seq.append(0)
                except ExecutionError:
                    seq.append(1)
        return seq, plan

    s5, p5 = run(5)
    s5b, _ = run(5)
    s6, _ = run(6)
    assert s5 == s5b and s5 != s6
    assert p5.total_fires() == sum(s5)
    assert p5.stats()["x:raise"]["calls"] == 30


def test_fault_prefix_after_and_max_fires():
    plan = faults.FaultPlan().add(
        faults.FaultSpec(site="ops.", mode="raise", after=2, max_fires=1)
    )
    with faults.active(plan):
        faults.fire("ops.fragment_spmv")   # skipped (after)
        faults.fire("ops.fragment_spmm")   # skipped (after)
        with pytest.raises(ExecutionError) as ei:
            faults.fire("ops.fragment_spmv_packed")
        assert ei.value.retryable and ei.value.code == "FAULT_INJECTED"
        faults.fire("ops.fragment_spmv")   # max_fires exhausted
        faults.fire("other.site")          # prefix does not match
    assert plan.total_fires() == 1


def test_fire_is_noop_without_plan():
    faults.fire("ops.fragment_spmv")
    assert faults.corrupt("storage.materialize", 7) == 7


def test_storage_corrupt_then_restore(pubmed):
    db = GQFastDatabase(pubmed, device_encodings="packed")
    col = next(
        c for di in db.device.indexes.values()
        for c in ([di.dst_col] + list(di.measure_cols.values()))
        if getattr(c, "kind", None) in ("packed", "dict")
    )
    truth = np.asarray(col.materialize())
    plan = faults.FaultPlan().add(
        faults.FaultSpec(site="storage.materialize", mode="corrupt")
    )
    with faults.active(plan):
        bad = np.asarray(col.materialize())
    assert plan.total_fires() >= 1
    assert not np.array_equal(bad, truth)
    # the memo kept the true decode: corruption never persists
    assert np.array_equal(np.asarray(col.materialize()), truth)


def test_kernel_fault_at_trace_time_degrades_to_working_rung(pubmed, engine):
    # fresh engine: prepare must re-trace so the ops.* sites actually fire
    eng = GQFastEngine(GQFastDatabase(pubmed))
    plan = faults.FaultPlan(seed=3).add(
        faults.FaultSpec(site="ops.", mode="raise")
    )
    with faults.active(plan):
        pq = eng.prepare(SG.QUERY_AD)
        oc = run_with_policy(
            pq, {"t1": 5, "t2": 7},
            policy=RobustPolicy(retry=RetryPolicy(max_attempts=1)),
        )
    # Pallas dispatch is poisoned on every compile → the ladder must land on
    # a rung that doesn't dispatch Pallas at all (xla or fragment_loop)
    assert oc.ok and oc.rung in ("xla", "fragment_loop"), oc.to_dict()
    assert plan.total_fires() >= 1
    ref = engine.prepare(SG.QUERY_AD)(t1=5, t2=7)
    assert np.array_equal(oc.value, ref)


def test_fused_kernel_fault_degrades_to_unfused(pubmed, engine):
    # poison only the fused-region dispatch site: the ladder must shed the
    # fused kernels at the first demotion (the "unfused" rung re-runs the
    # same plan as per-hop kernel calls, keeping block skipping) and agree
    # bit-for-bit with an unpoisoned prepare
    from repro.core.fuse import has_fused

    eng = GQFastEngine(GQFastDatabase(pubmed))
    plan = faults.FaultPlan(seed=4).add(
        faults.FaultSpec(site="ops.fragment_spmv_fused", mode="raise")
    )
    with faults.active(plan):
        # fusion='on': the pubmed reach matrix is dense, so 'auto' would
        # decline the region and never reach the poisoned site
        pq = eng.prepare(SG.QUERY_AS, fusion="on")
        assert has_fused(pq.phys)  # the poisoned site is on the active path
        oc = run_with_policy(
            pq, {"a0": 2},
            policy=RobustPolicy(retry=RetryPolicy(max_attempts=1)),
        )
    assert oc.ok and oc.status == "degraded", oc.to_dict()
    assert oc.rung == "unfused" and oc.demotions == ("active",)
    assert plan.total_fires() >= 1
    ref = engine.prepare(SG.QUERY_AS)(a0=2)
    assert np.array_equal(oc.value, ref)


# ---------------------------------------------------------------------------
# Chaos serve smoke (in-process micro version of the CI lane)
# ---------------------------------------------------------------------------


def test_chaos_serve_smoke(engine, prepared_sd):
    reg = MetricsRegistry()
    pol = RobustPolicy(retry=RetryPolicy(max_attempts=2, base_ms=0.1),
                       registry=reg)
    plan = (
        faults.FaultPlan(seed=9)
        .add(faults.FaultSpec(site="runner.execute", mode="raise",
                              prob=0.3, max_fires=6))
        .add(faults.FaultSpec(site="runner.execute", mode="delay",
                              delay_ms=5.0, prob=0.2))
    )
    rng = np.random.default_rng(0)
    outcomes = []
    with faults.active(plan):
        for _ in range(8):  # 8 micro-batches of 4 → 32 requests
            arr = rng.integers(0, 50, size=4)
            outcomes.extend(
                run_batch_with_policy(prepared_sd, {"d0": arr}, policy=pol)
            )
    assert len(outcomes) == 32
    assert all(o.status in ("ok", "degraded", "error") for o in outcomes)
    answered = [o for o in outcomes if o.ok]
    assert answered, "chaos must not take the service fully down"
    assert any(o.degraded for o in outcomes), "injected faults must degrade"
    # counters exported for the metrics artifact
    errs = reg.counters_with_prefix("robust.errors.")
    assert sum(errs.values()) > 0
    # structured wire form round-trips
    for o in outcomes:
        d = o.to_dict()
        assert d["status"] == o.status and "rung" in d


@pytest.mark.slow
def test_ladder_terminus_agrees_on_full_query_suite(engine):
    cases = {
        "SD": (SG.QUERY_SD, {"d0": 3}, True),
        "FSD": (SG.QUERY_FSD, {"d0": 3}, False),
        "AS": (SG.QUERY_AS, {"a0": 2}, False),
        "AD": (SG.QUERY_AD, {"t1": 2, "t2": 3}, True),
        "FAD": (SG.QUERY_FAD, {"t1": 2, "t2": 3}, True),
    }
    for name, (q, params, exact) in cases.items():
        p = engine.prepare(q)
        want = p(**params)
        args = [params[n] for n in p.param_names]
        for rung in LADDER:
            got = np.asarray(rung_fn(p, rung)(*args))
            if exact or rung != "fragment_loop":
                assert np.array_equal(got, want), (name, rung)
            else:
                np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)
