"""Serving launcher: GQ-Fast analytics micro-batching server, or LM decode.

  PYTHONPATH=src python -m repro.launch.serve --workload analytics
  PYTHONPATH=src python -m repro.launch.serve --workload lm

The analytics workload is the paper's target deployment turned into a real
serving loop: many concurrent dashboard queries that differ only in parameter
bindings. The server collects queued requests per query shape, pads each
micro-batch to a fixed bucket size (one compile per shape), runs ONE batched
SpMM pass over the engine (``PreparedQuery.execute_batch`` — every hop
streams the edge arrays once for the whole bucket), scatters the result rows
back to their requests, and reports measured queries/sec against the
sequential single-query baseline.
"""
from __future__ import annotations

import argparse
import time
from collections import deque


def _serve_analytics(args) -> None:
    import numpy as np

    from repro.core.engine import GQFastDatabase, GQFastEngine, batch_bucket
    from repro.data import synth_graph as SG

    print("loading database…")
    t0 = time.time()
    schema = SG.make_pubmed(
        n_docs=args.docs, n_terms=1_200, n_authors=args.docs // 5, seed=5
    )
    db = GQFastDatabase(schema, account_space=False)
    eng = GQFastEngine(db)
    n_authors = schema.entities["Author"].size
    print(f"  {time.time()-t0:.1f}s "
          f"(DT {schema.relationships['DT'].num_rows} rows, "
          f"DA {schema.relationships['DA'].num_rows} rows)")

    queries = {
        "AS": SG.QUERY_AS, "SD": SG.QUERY_SD, "FSD": SG.QUERY_FSD,
        "AD": SG.QUERY_AD, "FAD": SG.QUERY_FAD,
    }
    prepared = {name: eng.prepare(sql) for name, sql in queries.items()}
    rng = np.random.default_rng(0)

    def sample_params(kind: str) -> dict[str, int]:
        if kind == "AS":
            return {"a0": int(rng.integers(0, n_authors))}
        if kind in ("SD", "FSD"):
            return {"d0": int(rng.integers(0, args.docs))}
        return {"t1": int(rng.integers(0, 50)), "t2": int(rng.integers(0, 50))}

    bucket = batch_bucket(args.batch)
    names = list(queries)
    stream = [
        (i, names[int(rng.integers(0, len(names)))]) for i in range(args.requests)
    ]
    stream = [(i, kind, sample_params(kind)) for i, kind in stream]

    print(f"warmup (one batched compile per shape, bucket={bucket})…")
    t0 = time.time()
    for kind in names:
        p = sample_params(kind)
        prepared[kind](**p)  # single-query executable (baseline)
        prepared[kind].execute_batch(
            **{k: np.full(bucket, v) for k, v in p.items()}
        )
    print(f"  {time.time()-t0:.1f}s")

    # sequential baseline: the same request mix served one query at a time
    base_n = min(args.requests, 25)
    t0 = time.perf_counter()
    for _, kind, params in stream[:base_n]:
        prepared[kind](**params)
    seq_qps = base_n / (time.perf_counter() - t0)

    print(f"serving {args.requests} requests, micro-batch ≤ {args.batch}…")
    results: list = [None] * len(stream)
    queue = deque(stream)
    sizes: list[int] = []
    t0 = time.perf_counter()
    while queue:
        # collect: drain up to `batch` queued requests of the head's shape
        i0, kind, p0 = queue.popleft()
        group = [(i0, p0)]
        skipped: deque = deque()
        while queue and len(group) < args.batch:
            item = queue.popleft()
            if item[1] == kind:
                group.append((item[0], item[2]))
            else:
                skipped.append(item)
        queue.extendleft(reversed(skipped))
        # pad to the bucket (repeat the last binding; rows sliced off below)
        arrays = {
            k: np.asarray([p[k] for _, p in group] + [group[-1][1][k]] * (bucket - len(group)))
            for k in p0
        }
        out = prepared[kind].execute_batch(**arrays)  # one SpMM pass
        for row, (req_id, _) in enumerate(group):  # scatter to requests
            results[req_id] = out[row]
        sizes.append(len(group))
    dt = time.perf_counter() - t0

    assert all(r is not None for r in results)
    qps = args.requests / dt
    print(f"\n  {args.requests} requests in {dt:.2f}s over {len(sizes)} batched "
          f"passes (mean occupancy {np.mean(sizes):.1f}/{bucket})")
    print(f"  micro-batched: {qps:8.1f} queries/s")
    print(f"  sequential:    {seq_qps:8.1f} queries/s "
          f"(speedup ×{qps/seq_qps:.1f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["analytics", "lm"], default="analytics")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default: 256 analytics, 60 lm)")
    ap.add_argument("--batch", type=int, default=32,
                    help="analytics: max requests per micro-batch "
                         "(padded to the engine's bucket size)")
    ap.add_argument("--docs", type=int, default=20_000,
                    help="analytics: synthetic database scale")
    args = ap.parse_args()

    if args.workload == "analytics":
        if args.requests is None:
            args.requests = 256
        _serve_analytics(args)
        return
    if args.requests is None:
        args.requests = 60

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.models.transformer import decode_step, init_params, prefill

    arch = get_arch("qwen2.5-3b")
    cfg = arch.smoke_cfg
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    logits, cache, pos = prefill(params, toks, cfg, 128)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    out = [cur]
    for i in range(args.requests):
        logits, cache = step(params, cache, cur, jnp.int32(32 + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {args.requests} decode steps × batch 4: "
          f"{dt/args.requests*1e3:.1f} ms/step, {4*args.requests/dt:.1f} tok/s")
    print("sample tokens:", np.asarray(jnp.stack(out))[:10, 0].tolist())


if __name__ == "__main__":
    main()
