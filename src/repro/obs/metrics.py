"""Counters / gauges / fixed-bucket histograms with a JSON-exportable registry.

Zero-dependency (numpy only) metrics for the serve path and the engine:

  * :class:`Counter` — monotone float adds.
  * :class:`Gauge`   — last-write-wins value.
  * :class:`Histogram` — fixed exponential buckets, numpy-backed counts, exact
    count/sum/min/max, and percentile estimates by linear interpolation inside
    the containing bucket (error bounded by that bucket's width — the
    tradeoff that keeps ``observe`` O(log n_buckets) and the export tiny).
  * :class:`MetricsRegistry` — name → metric, get-or-create, ``snapshot()``
    dict export and a lossless JSON round-trip (``to_json`` / ``from_json``).

The module-level :data:`REGISTRY` is the default sink (engine mispredict
counters); servers that want isolation construct their own registry.

Thread safety: mutation (``inc``/``set``/``observe``) and registry
get-or-create are lock-protected — the serve loop's worker threads, the
background scrubber (robust/scrub.py), and the hot-swap reloader all write
the same registry. Reads (``snapshot``/``value``) are deliberately
lock-free: a torn multi-field histogram snapshot under concurrent observes
is a monitoring-grade approximation, never a crash.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left
from typing import Any

import numpy as np

#: Default latency buckets (milliseconds): 1 µs … ~100 s, ×2 per bucket.
#: The +1th count is the overflow bucket.
DEFAULT_BUCKETS = tuple(float(2.0**k) * 1e-3 for k in range(28))


class Counter:
    __slots__ = ("value", "_lock")

    def __init__(self, value: float = 0.0):
        self.value = float(value)
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:  # += on a float is read-modify-write, not atomic
            self.value += n

    def snapshot(self) -> float:
        return self.value


class Gauge:
    __slots__ = ("value", "_lock")

    def __init__(self, value: float = 0.0):
        self.value = float(value)
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram. ``bounds`` are the inclusive upper edges of the
    first ``len(bounds)`` buckets; values above ``bounds[-1]`` land in the
    overflow bucket (whose upper edge is the observed max)."""

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max", "_lock")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect_left(self.bounds, v)] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def observe_many(self, vs) -> None:
        for v in np.asarray(vs, np.float64).ravel():
            self.observe(float(v))

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) by linear interpolation
        inside the containing bucket; exact at the observed min/max."""
        if self.count == 0:
            return float("nan")
        rank = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo, hi = max(lo, self.min), min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = min(max((rank - cum) / c, 0.0), 1.0)
                return lo + frac * (hi - lo)
            cum += c
        return self.max

    def snapshot(self) -> dict[str, Any]:
        s: dict[str, Any] = {
            "count": self.count,
            "sum": round(self.sum, 6),
            "bounds": list(self.bounds),
            "counts": self.counts.tolist(),
        }
        if self.count:
            s.update(
                min=self.min, max=self.max,
                p50=self.percentile(50), p95=self.percentile(95),
                p99=self.percentile(99),
            )
        return s


class MetricsRegistry:
    """Named metrics with get-or-create accessors and a JSON round-trip."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str, bounds=DEFAULT_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.get(name)
                if h is None:
                    h = self._histograms[name] = Histogram(bounds)
        return h

    def counters_with_prefix(self, prefix: str) -> dict[str, float]:
        """Every counter under a dotted namespace (e.g. ``robust.errors.``) —
        the rollup view serve summaries and chaos assertions read."""
        return {
            n: c.snapshot()
            for n, c in sorted(self._counters.items())
            if n.startswith(prefix)
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> dict[str, Any]:
        return {
            "counters": {n: c.snapshot() for n, c in sorted(self._counters.items())},
            "gauges": {n: g.snapshot() for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: h.snapshot() for n, h in sorted(self._histograms.items())
            },
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "MetricsRegistry":
        """Rebuild a registry from :meth:`to_json` output. Percentile
        estimates are recomputed from the bucket counts, so
        ``from_json(r.to_json()).snapshot() == r.snapshot()``."""
        data = json.loads(s)
        reg = cls()
        for n, v in data.get("counters", {}).items():
            reg.counter(n).value = float(v)
        for n, v in data.get("gauges", {}).items():
            reg.gauge(n).set(v)
        for n, h in data.get("histograms", {}).items():
            hist = reg.histogram(n, bounds=h["bounds"])
            hist.counts = np.asarray(h["counts"], np.int64)
            hist.count = int(h["count"])
            hist.sum = float(h["sum"])
            hist.min = float(h.get("min", float("inf")))
            hist.max = float(h.get("max", float("-inf")))
        return reg


#: Default process-wide registry (engine-internal counters land here).
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
