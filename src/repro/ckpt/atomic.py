"""Crash-safe directory publication shared by checkpoints and snapshots.

Both durable artifact writers in the repo — the training checkpoint manager
(``ckpt/manager.py``, ``step_<n>`` dirs) and the database snapshot writer
(``storage/snapshot.py``, ``gen_<n>`` dirs) — publish a fully-written
directory with one atomic ``os.rename``. This module is the single home of
that pattern plus the two details the original checkpoint code missed:

  * **File durability before publish** — every file written into the tmp dir
    is fsynced before the rename, so a crash immediately after publication
    cannot leave a visible directory with zero-length files.
  * **Parent-directory fsync after rename/unlink** — the rename (and any
    retention deletes) are themselves directory-entry mutations; without
    fsyncing the parent, a crash can leave a *half-visible* entry: the old
    dir gone but the new name not yet durable, or a retention victim
    lingering as a ghost. ``fsync_dir`` closes that window.

Retention for ``<prefix><n>`` stamped directories (zero-padded monotone
integers) also lives here so both writers age out old artifacts identically.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Callable

#: Zero-pad width for stamped directory names (``step_0000000042``).
STAMP_WIDTH = 10


def fsync_dir(path: str) -> None:
    """Flush directory-entry mutations (rename/unlink) under ``path`` to
    stable storage. Best-effort on platforms whose directories cannot be
    opened for fsync (e.g. Windows) — durability there is OS-defined."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _fsync_tree(root: str) -> None:
    """fsync every regular file under ``root``, then the dirs themselves."""
    for dirpath, _dirnames, filenames in os.walk(root, topdown=False):
        for name in filenames:
            fd = os.open(os.path.join(dirpath, name), os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        fsync_dir(dirpath)


def publish_dir(final: str, write: Callable[[str], None],
                tmp_prefix: str = ".tmp_publish_") -> str:
    """Atomically publish a directory at ``final``.

    ``write(tmp_path)`` populates a temp dir created next to ``final`` (same
    filesystem, so the rename is atomic). On any exception the temp dir is
    removed and nothing becomes visible; on success the tree is fsynced,
    renamed into place, and the parent directory entry is made durable.
    An existing ``final`` is replaced."""
    parent = os.path.dirname(final) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=tmp_prefix)
    try:
        write(tmp)
        _fsync_tree(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        fsync_dir(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def stamped_name(prefix: str, n: int) -> str:
    return f"{prefix}{n:0{STAMP_WIDTH}d}"


def list_stamped(parent: str, prefix: str) -> list[int]:
    """Sorted stamps of every ``<prefix><n>`` directory under ``parent``
    (missing parent → empty; non-integer suffixes are ignored)."""
    try:
        names = os.listdir(parent)
    except FileNotFoundError:
        return []
    out = []
    for name in names:
        if name.startswith(prefix):
            try:
                out.append(int(name[len(prefix):]))
            except ValueError:
                pass
    return sorted(out)


def retain_stamped(parent: str, prefix: str, keep: int) -> list[int]:
    """Delete all but the newest ``keep`` stamped directories, then fsync the
    parent so the unlinks are durable (a crash mid-retention cannot leave a
    half-visible victim). Returns the stamps that were removed."""
    stamps = list_stamped(parent, prefix)
    victims = stamps[:-keep] if keep > 0 else stamps
    for n in victims:
        shutil.rmtree(os.path.join(parent, stamped_name(prefix, n)),
                      ignore_errors=True)
    if victims:
        fsync_dir(parent)
    return victims
