"""Training launcher: --arch <id> at smoke/CPU scale with the fault-tolerant
loop, or --dry-run to lower the full config on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 50
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.configs.registry import get_arch
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import TrainLoopConfig, train

    arch = get_arch(args.arch)
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
        ckpt_dir=f"{args.ckpt_dir}/{args.arch}",
    )

    if arch.kind == "lm":
        from repro.data.lm_data import lm_batch
        from repro.models.transformer import init_params, loss_fn

        cfg = arch.smoke_cfg
        params = init_params(cfg, jax.random.key(0))
        params, res = train(
            params, lambda p, b: loss_fn(p, b, cfg),
            lambda s: lm_batch(s, 8, 64, cfg.vocab, seed=0),
            loop_cfg, AdamWConfig(lr=1e-3), resume=args.resume,
        )
    elif arch.kind == "gnn":
        from repro.data.graphs import make_molecule_batch
        from repro.models.gnn.models import gnn_init, gnn_loss

        cfg = arch.smoke_cfg
        params = gnn_init(cfg, jax.random.key(0))
        batches = [make_molecule_batch(8, 10, 24, seed=s).as_inputs() for s in range(4)]
        params, res = train(
            params, lambda p, b: gnn_loss(p, b, cfg, 8),
            lambda s: batches[s % 4], loop_cfg, AdamWConfig(lr=1e-3),
            resume=args.resume,
        )
    elif arch.kind == "recsys":
        from repro.data.recsys import make_din_batch
        from repro.models.din import din_init, din_loss

        cfg = arch.smoke_cfg
        params = din_init(cfg, jax.random.key(0))
        params, res = train(
            params, lambda p, b: din_loss(p, b, cfg),
            lambda s: make_din_batch(64, seq_len=cfg.seq_len, n_items=cfg.n_items,
                                     n_users=cfg.n_users, seed=s % 8),
            loop_cfg, AdamWConfig(lr=1e-3), resume=args.resume,
        )
    else:
        raise SystemExit(f"{args.arch} is a serving workload; use repro.launch.serve")

    h = res.history
    print(f"[train] {args.arch}: {len(h)} steps, "
          f"loss {h[0]['loss']:.4f} → {h[-1]['loss']:.4f}"
          f"{' (resumed from %d)' % res.resumed_from if res.resumed_from else ''}")


if __name__ == "__main__":
    main()
