"""Plan execution — the JAX analogue of the paper's code generator (§6.2).

Every strategy is a *thin interpreter* over the lowered physical IR built by
:mod:`repro.core.lower` (DESIGN.md §2): one shared continuation-passing walker
(:func:`walk_ir`) folds the op sequence, and the strategies differ only in the
primitive each op maps to:

  * ``frontier`` — bottom-up fully pipelined execution, TPU-native: each HopOp
    dispatches through :func:`repro.kernels.ops.fragment_spmv` (Pallas on TPU,
    interpret/XLA fallback on CPU) over dense per-entity-domain vectors, or —
    when the index's columns are stored bit-packed by the device column store
    (:mod:`repro.storage`) — through the decode-fused
    :func:`repro.kernels.ops.fragment_spmv_packed`, which unpacks dst ids and
    measures block-at-a-time in VMEM (the paper's compression-inside-the-
    operator design). JAX tracing fuses the whole plan into one XLA
    executable; intermediates are vectors, never materialized join tables.
  * ``fragment_loop`` — paper-faithful port of the generated C++ (Fig. 3):
    nested ``lax.fori_loop``s walk one fragment at a time, scalar accumulator
    updates. The §Perf baseline demonstrating why the vectorized rewrite is
    needed on TPU.
  * distributed variant — edge-sharded shard_map with one collective per hop
    (the paper's multi-thread shared-accumulator design, contention-free).

Aggregation semantics are pluggable (DESIGN.md §3): the walker is parameterized
by a :class:`repro.core.semiring.Semiring`, so SUM/COUNT, MIN/MAX, EXISTS and
the fused AVG pair all execute through the same code path in every strategy.
All strategies return the dense γ accumulator ℛ over the group-by entity domain
(the paper's aggregation array; size = domain of the group key).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import active as active_meta
from ..obs import trace as obs_trace
from ..robust.errors import ExecutionError, ValidationError
from ..robust.runner import check_deadline
from ..storage import (
    DenseColumn,
    DeviceColumn,
    DictPackedColumn,
    PackedColumn,
    build_device_column,
    column_uniques,
    resolve_device_encoding,
)
from .algebra import ChainPlan, EntityStep, Param, RelHop, SeedIds
from .fragments import FragmentIndex
from .lower import (
    DegreeFilterOp,
    EntityFilterOp,
    FusedHopOp,
    GroupOp,
    HopOp,
    LBin,
    LCall,
    LCol,
    LParam,
    PhysicalPlan,
    SeedOp,
    eval_lexpr,
    iter_flat_ops,
    lower,
)
from .schema import Schema
from .semiring import BOOL_OR_AND, Semiring, semiring_for


@dataclass
class DeviceIndex:
    """Device-resident form of one FragmentIndex: CSR structure arrays plus
    the co-stored columns as :class:`repro.storage.DeviceColumn`s, so whether
    a column lives decoded (int32/float32 CSR) or bit-packed (BCA words /
    dictionary-packed) is a per-column physical property. ``dst_ids`` /
    ``measures`` decode on demand — the compatibility surface for consumers
    without a packed path (free when the column is dense)."""

    indptr: jnp.ndarray  # int32[h+1]
    src_ids: jnp.ndarray  # int32[E]  (CSR row ids expanded; sorted)
    dst_col: DeviceColumn  # int32[E] decoded view
    degrees: jnp.ndarray | None = None
    measure_cols: dict[str, DeviceColumn] = field(default_factory=dict)
    # per-EDGE_BLOCK [src_min, src_max] over the CSR-ordered edge arrays
    # (kernels/active.py) — the frontier-sparsity block-skipping metadata;
    # None (e.g. shard-built indexes) disables skipping for this index
    block_src_min: np.ndarray | None = None
    block_src_max: np.ndarray | None = None

    @property
    def dst_ids(self) -> jnp.ndarray:
        return self.dst_col.materialize()

    @property
    def measures(self) -> dict[str, jnp.ndarray]:
        return {m: c.materialize() for m, c in self.measure_cols.items()}


@dataclass
class DeviceDB:
    schema: Schema
    indexes: dict[tuple[str, str], DeviceIndex]
    entity_attrs: dict[tuple[str, str], jnp.ndarray]
    host_indexes: dict[tuple[str, str], FragmentIndex]

    def index(self, table: str, key: str) -> DeviceIndex:
        return self.indexes[(table, key)]


def build_device_db(
    schema: Schema,
    host_indexes: dict[tuple[str, str], FragmentIndex],
    device_encodings: str | dict | None = "auto",
) -> DeviceDB:
    """Ship every fragment index to device under the storage policy.

    ``device_encodings``: ``"auto"`` (§5-style chooser, the default) |
    ``"dense"`` (decoded-CSR baseline) | ``"packed"`` (force BCA wherever it
    fits) | a per-column dict ``{(table, key, column): encoding}`` with
    ``"auto"`` filling unspecified columns. Every key of a per-column dict
    must name a real (table, key, column) address — a typo'd override would
    otherwise be silently ignored."""
    dev: dict[tuple[str, str], DeviceIndex] = {}
    seen_addrs: set[tuple[str, str, str]] = set()
    for (table, key), idx in host_indexes.items():
        other = next(c for c in idx.columns if c != key and _is_fk(schema, table, c))
        cf = idx.columns[other]
        seen_addrs.add((table, key, other))
        enc = resolve_device_encoding(
            device_encodings, (table, key, other), cf.values, cf.domain, is_key=True
        )
        src = idx.src_ids()
        bmin, bmax = active_meta.block_ranges(src)
        di = DeviceIndex(
            indptr=jnp.asarray(idx.indptr, dtype=jnp.int32),
            src_ids=jnp.asarray(src, dtype=jnp.int32),
            dst_col=build_device_column(cf, enc, jnp.int32),
            degrees=jnp.asarray(np.diff(idx.indptr), dtype=jnp.int32),
            block_src_min=bmin,
            block_src_max=bmax,
        )
        for m, cf in idx.columns.items():
            if m == other:
                continue
            seen_addrs.add((table, key, m))
            uq = column_uniques(cf.values)  # one scan shared by chooser+builder
            enc = resolve_device_encoding(
                device_encodings, (table, key, m), cf.values, cf.domain,
                is_key=False, uniques=uq,
            )
            di.measure_cols[m] = build_device_column(cf, enc, jnp.float32, uniques=uq)
        dev[(table, key)] = di
    if isinstance(device_encodings, dict):
        unknown = set(device_encodings) - seen_addrs
        if unknown:
            raise ValidationError(
                f"device_encodings keys match no index column: {sorted(unknown)}; "
                f"valid addresses: {sorted(seen_addrs)}",
                unknown=sorted(unknown),
            )
    attrs = {
        (e.name, a): jnp.asarray(col, dtype=jnp.float32)
        for e in schema.entities.values()
        for a, col in e.attributes.items()
    }
    return DeviceDB(schema, dev, attrs, host_indexes)


def _is_fk(schema: Schema, table: str, attr: str) -> bool:
    rel = schema.relationships[table]
    return attr in (rel.fk1, rel.fk2)


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------


def collect_params(plan: ChainPlan) -> list[str]:
    names: list[str] = []

    def add(v):
        if isinstance(v, Param) and v.name not in names:
            names.append(v.name)

    def walk(p: ChainPlan):
        if isinstance(p.seed, SeedIds):
            ids = p.seed.ids if isinstance(p.seed.ids, list) else [p.seed.ids]
            for i in ids:
                add(i)
        else:
            for c in p.seed.chains:
                walk(c)
            for cc in p.seed.entity_conds:
                add(cc.value)
        for s in p.steps:
            if isinstance(s, EntityStep):
                for cc in s.conds:
                    add(cc.value)

    walk(plan)
    return names


def ensure_lowered(db: DeviceDB, plan: ChainPlan | PhysicalPlan) -> PhysicalPlan:
    return plan if isinstance(plan, PhysicalPlan) else lower(db, plan)


def densify_plan(phys: PhysicalPlan) -> PhysicalPlan:
    """Materialize every packed column bound in the IR, once, producing an
    all-dense twin of the plan. The correctness fallback for strategies with
    no packed execution path (DESIGN.md §Storage): fragment_loop's scalar
    loops index columns element-wise, so they pay one whole-column decode per
    prepare here instead of a decode per loop iteration inside the trace."""

    def dcol(col: DeviceColumn) -> DeviceColumn:
        return col if isinstance(col, DenseColumn) else DenseColumn(col.materialize())

    def dexpr(e):
        if isinstance(e, LCol) and not isinstance(e.col, DenseColumn):
            return LCol(e.key, dcol(e.col))
        if isinstance(e, LBin):
            return LBin(e.op, dexpr(e.left), dexpr(e.right))
        if isinstance(e, LCall):
            return LCall(e.fn, tuple(dexpr(a) for a in e.args))
        return e

    def dop(op):
        if isinstance(op, HopOp):
            return dataclasses.replace(
                op, dst_col=dcol(op.dst_col),
                measure=dexpr(op.measure) if op.measure is not None else None,
            )
        if isinstance(op, SeedOp) and op.programs:
            return dataclasses.replace(
                op, programs=tuple(densify_plan(p) for p in op.programs)
            )
        if isinstance(op, EntityFilterOp) and op.factor is not None:
            return dataclasses.replace(op, factor=dexpr(op.factor))
        if isinstance(op, FusedHopOp):
            return dataclasses.replace(
                op, members=tuple(dop(m) for m in op.members)
            )
        return op

    new_ops = [dop(op) for op in phys.ops]
    return PhysicalPlan(
        tuple(new_ops), phys.param_names, phys.agg, phys.out_dom, phys.source
    )


# ---------------------------------------------------------------------------
# The shared lowered-IR walker
# ---------------------------------------------------------------------------


def _trace_clean() -> bool:
    """True outside any jax trace — the guard that keeps span recording and
    ``block_until_ready`` fencing strictly on the host side of jit."""
    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # pragma: no cover - very old jax
        return True


def walk_ir(phys: PhysicalPlan, interp: "_Interp", stop: int | None = None):
    """Fold the op sequence through ``interp``. Continuation-passing so the
    scalar strategy can emit its nested fragment loops from the same walk.

    ``stop`` truncates the walk to the first ``stop`` ops and returns the raw
    interpreter state (no finalize) — the profiling prefix entry.

    When an observability tracer is recording (``obs.trace``) *and* the walk
    runs eagerly (outside any jit trace), every op is wrapped in a nested span
    carrying its label, fenced own-time, and hop metadata — the per-op
    breakdown behind ``PreparedQuery.profile()``. Under a trace (the normal
    compiled path) the walk is the plain fold: spans record around traced
    calls, never inside them."""
    ops = phys.ops if stop is None else phys.ops[:stop]
    if obs_trace.current() is not None:
        return _walk_ir_recorded(phys, ops, interp)

    def go(i: int, state):
        if i == len(ops):
            return state
        return interp.apply(ops[i], state, lambda st: go(i + 1, st))

    return go(0, None)


def _annotate_op_span(sp, op, state, interp) -> None:
    """Static + observed metadata for one op span: shapes, strategy knobs, and
    — for a HopOp with a concrete incoming frontier — the observed support and
    surviving-block count (kernels/active.py metadata, computed on host). A
    FusedHopOp region reports ONE span annotated with its member ops (the
    region executes as one kernel pass), carrying the first hop's frontier
    metadata — the analogue of fragment_loop's "(fused into enclosing op)"
    convention."""
    if isinstance(op, FusedHopOp):
        sp.annotate(
            fused=True,
            members=[
                f"Hop({m.table}.{m.src_key}->{m.dst_entity})"
                if isinstance(m, HopOp) else type(m).__name__
                for m in op.members
            ],
        )
        _annotate_op_span(sp, op.hops[0], state, interp)
        return
    if not isinstance(op, HopOp):
        return
    sp.annotate(
        table=op.table, src_key=op.src_key,
        E=int(op.src_ids.shape[0]), dom_dst=int(op.dom_dst),
        block_skipping=getattr(interp, "block_skipping", None),
    )
    w = state
    if w is None or not hasattr(w, "shape") or isinstance(w, jax.core.Tracer):
        return
    try:
        zero = interp.sr.zero
        sup = np.asarray(w != zero)
        if sup.ndim == 2:
            sup = sup.any(axis=0)
        h = int(op.indptr.shape[0]) - 1
        if sup.ndim != 1 or sup.shape[0] != h:
            return
        degrees = np.diff(np.asarray(op.indptr))
        touched = int(degrees[sup].sum())
        E = max(int(op.src_ids.shape[0]), 1)
        sp.annotate(
            frontier_nnz=int(sup.sum()),
            observed_active_fraction=round(touched / E, 6),
        )
        if op.block_src_min is not None:
            _, na, bf = active_meta.active_block_list_np(
                sup, op.block_src_min, op.block_src_max
            )
            sp.annotate(
                active_blocks=int(na[0]),
                n_blocks=int(np.asarray(op.block_src_min).shape[0]),
                active_block_fraction=round(float(bf), 6),
            )
    except Exception:  # annotation must never break execution
        pass


def _walk_ir_recorded(phys: PhysicalPlan, ops, interp: "_Interp"):
    """The instrumented fold: one span per op, nested along the continuation
    chain (op k's span contains ops k+1..n — self time = wall − children).
    The span's ``kernel_ms`` is the ``block_until_ready``-fenced time from op
    entry to the op's own output being device-ready (captured the first time
    the continuation runs eagerly). Ops whose continuation only ever fires
    under a trace (the scalar strategy's fori_loop bodies) are closed after
    ``apply`` returns and flagged ``fused_tail`` — their time includes the
    traced downstream ops, which get no spans of their own."""
    labels = phys.op_signature()
    plan_key = id(phys.ops)

    def go(i: int, state):
        if i == len(ops):
            return state
        op = ops[i]
        if not _trace_clean():
            return interp.apply(op, state, lambda st: go(i + 1, st))
        check_deadline(labels[i])
        with obs_trace.span(labels[i], op_index=i, plan=plan_key) as sp:
            if state is not None:
                jax.block_until_ready(state)
            _annotate_op_span(sp, op, state, interp)
            t0 = time.perf_counter()
            seen = [0]

            def cont(st):
                if _trace_clean():
                    seen[0] += 1
                    if seen[0] == 1:
                        sp.annotate(
                            dispatch_ms=round((time.perf_counter() - t0) * 1e3, 4)
                        )
                        sp.fence(st)
                return go(i + 1, st)

            out = interp.apply(op, state, cont)
            if seen[0] == 0:  # continuation only ran inside a trace
                sp.annotate(
                    dispatch_ms=round((time.perf_counter() - t0) * 1e3, 4),
                    fused_tail=True,
                )
                sp.fence(out)
            sp.annotate(calls=max(seen[0], 1))
        return out

    return go(0, None)


def execute_ir(phys: PhysicalPlan, make_interp) -> jnp.ndarray:
    """Strategy-independent top level: pick the semiring for the plan's
    aggregate, run the walker (twice for AVG's fused SUM+COUNT pair), and
    apply the output convention."""
    sr = semiring_for(phys.agg)
    if phys.agg == "avg":
        # two walks in one traced program; XLA CSE merges everything the
        # weighted and count passes share (all hops up to the first measure)
        s = walk_ir(phys, make_interp(sr, True))
        c = walk_ir(phys, make_interp(sr, False))
        return jnp.where(c > 0, s / c, 0.0)
    return sr.finalize(walk_ir(phys, make_interp(sr, True)))


class _Interp:
    """Op dispatch + parameter/seed-scalar environment shared by strategies."""

    def __init__(self, params: dict[str, Any], sr: Semiring, use_measures: bool = True):
        self.params = params
        self.sr = sr
        self.use_measures = use_measures
        self.scalars: dict[tuple, Any] = {}

    def apply(self, op, state, cont):
        if isinstance(op, SeedOp):
            return self.seed(op, state, cont)
        if isinstance(op, HopOp):
            return self.hop(op, state, cont)
        if isinstance(op, DegreeFilterOp):
            return self.degree_filter(op, state, cont)
        if isinstance(op, EntityFilterOp):
            return self.entity_filter(op, state, cont)
        if isinstance(op, GroupOp):
            return self.group(op, state, cont)
        if isinstance(op, FusedHopOp):
            return self.fused_hop(op, state, cont)
        raise ExecutionError(
            f"no interpreter rule for op {type(op).__name__}",
            retryable=False, op=type(op).__name__,
            strategy=type(self).__name__,
        )

    def fused_hop(self, op: "FusedHopOp", state, cont):
        """Default semantics of a fused region: replay its member ops through
        the ordinary per-op rules (CPS, so the scalar strategy's nested loops
        come out identical to the unfused plan). Strategies with a true
        single-pass kernel (frontier) override this."""
        members = op.members

        def go(i: int, st):
            if i == len(members):
                return cont(st)
            return self.apply(members[i], st, lambda s2: go(i + 1, s2))

        return go(0, state)

    def resolve(self, v):
        return self.params[v.name] if isinstance(v, LParam) else v

    def capture_scalars(self, op: SeedOp, sid):
        self.scalars = {
            s.key: self.attr_col(s)[sid] for s in op.scalars.values()
        }

    # column access — overridden by the distributed interpreter
    def col(self, c):
        return c.array

    def attr_col(self, c):
        return c.array


# ---------------------------------------------------------------------------
# Frontier strategy (and its edge-sharded distributed variant)
# ---------------------------------------------------------------------------


class _FrontierInterp(_Interp):
    """Dense frontier vectors; each hop is one fused gather⊗measure→scatter-⊕
    kernel call.

    Frontier sparsity (DESIGN.md §Sparsity): every hop first short-circuits an
    all-zero frontier inside the trace (``lax.cond`` on the support count — a
    died-early chain stops paying per-hop scan cost), then passes the index's
    per-block src-range metadata to the kernel dispatch so blocks the support
    cannot reach are never streamed. ``block_skipping`` ('auto' | 'on' |
    'off') is threaded through from prepare time."""

    # Subclasses whose hops run collectives (the edge-sharded distributed
    # interp) must not branch per-hop: lax.cond with a psum inside one branch
    # deadlocks when shards disagree on the frontier. They opt out here.
    early_exit = True
    # The edge-sharded interp also opts out of the single-pass fused-region
    # kernel (its hops are shard-local segment reduces, no VMEM pipeline) and
    # replays fused regions op-by-op via the generic rule instead.
    fuse_kernels = True

    def __init__(self, params: dict[str, Any], sr: Semiring,
                 use_measures: bool = True, block_skipping: str = "auto",
                 use_pallas: bool = True, fusion: str = "auto"):
        super().__init__(params, sr, use_measures)
        self.block_skipping = block_skipping
        self.use_pallas = use_pallas
        self.fusion = fusion

    def spawn(self) -> "_FrontierInterp":
        """Interpreter for a mask sub-program (always the boolean semiring)."""
        return _FrontierInterp(
            self.params, BOOL_OR_AND, block_skipping=self.block_skipping,
            use_pallas=self.use_pallas, fusion=self.fusion,
        )

    def blocks_for(self, op: HopOp):
        """The hop's (src_min, src_max) skip metadata, or None when absent or
        skipping is off — kernel dispatch treats both as 'full scan'."""
        if self.block_skipping == "off" or op.block_src_min is None:
            return None
        return (op.block_src_min, op.block_src_max)

    def seed(self, op: SeedOp, state, cont):
        sr = self.sr
        if op.ids is not None:
            idx = jnp.asarray([self.resolve(i) for i in op.ids], dtype=jnp.int32)
            # scatter-⊕, not set: duplicate seed ids must accumulate
            # multiplicity under the sum semiring (matches the oracle and the
            # per-seed unrolling of the fragment_loop strategy)
            w = sr.scatter(jnp.full(op.dom, sr.zero, jnp.float32), idx, sr.one)
            if op.scalars:
                self.capture_scalars(op, self.resolve(op.ids[0]))
            return cont(w)
        m = jnp.ones(op.dom, jnp.float32)
        for prog in op.programs:
            m = m * walk_ir(prog, self.spawn())
        if op.const_mask is not None:
            m = m * op.const_mask
        for c in op.param_conds:
            m = m * c.mask(self.params, self.attr_col).astype(jnp.float32)
        return cont(sr.from_mask(m))

    def hop(self, op: HopOp, state, cont):
        sr, w = self.sr, state
        if op.semijoin:
            w = sr.binarize(w)
        if not self.early_exit:
            return cont(self._hop_body(w, op))
        # all-zero frontier short-circuit: the hop's result is the ⊕-identity
        # accumulator whatever the index holds, so skip the kernel entirely —
        # in-trace, so multi-hop chains that die early stop scanning
        out_shape = w.shape[:-1] + (op.dom_dst,)
        return cont(jax.lax.cond(
            jnp.count_nonzero(w != sr.zero) == 0,
            lambda w: jnp.full(out_shape, sr.zero, jnp.float32),
            lambda w: self._hop_body(w, op),
            w,
        ))

    def _hop_body(self, w, op: HopOp):
        fused = self.spmv_fused(w, op)
        if fused is not None:
            return fused
        src, dst, valid = self.edge_arrays(op)
        E = src.shape[0]
        if op.measure is not None and self.use_measures:
            m = eval_lexpr(op.measure, self.params, self.scalars, self.col)
            m = jnp.broadcast_to(jnp.asarray(m, jnp.float32), (E,))
        else:
            m = jnp.ones(E, jnp.float32)
        return self.spmv(w, src, dst, m, valid, op)

    def edge_arrays(self, op: HopOp):
        return op.src_ids, op.dst_ids, None

    def _packed_layout(self, op: HopOp):
        """Classify the hop's physical layout for the decode-fused kernels:
        returns None when there is nothing packed to fuse (all-dense hop),
        else ``(dst_packed, m_mode, m_operand, m_width, mdict)``. A dense
        ``m_mode`` leaves ``m_operand`` None — the caller evaluates the
        measure expression and broadcasts it to its own frontier shape.
        Single classification shared by the SpMV and SpMM fused paths so the
        mode dispatch cannot drift between them."""
        dst_packed = isinstance(op.dst_col, PackedColumn)
        m = op.measure if self.use_measures else None
        if m is None:
            m_mode, m_operand, m_width, mdict = "none", None, 0, None
        elif isinstance(m, LCol) and isinstance(m.col, PackedColumn):
            m_mode, m_operand, m_width, mdict = "packed", m.col.words, m.col.width, None
        elif isinstance(m, LCol) and isinstance(m.col, DictPackedColumn):
            m_mode, m_operand, m_width, mdict = (
                "dict", m.col.words, m.col.width, m.col.dictionary,
            )
        else:
            m_mode, m_operand, m_width, mdict = "dense", None, 0, None
        if not (dst_packed or m_mode in ("packed", "dict")):
            return None
        return dst_packed, m_mode, m_operand, m_width, mdict

    def spmv_fused(self, w, op: HopOp):
        """Decode-fused hop: stream packed columns straight into the kernel
        (the paper's compression-inside-the-operator design). Engaged when the
        dst column is bit-packed and/or the measure is a single packed column;
        returns None when there is nothing to fuse (all-dense hop) and the
        plain kernel path runs instead."""
        from ..kernels import ops as K

        layout = self._packed_layout(op)
        if layout is None:
            return None
        dst_packed, m_mode, m_operand, m_width, mdict = layout
        if m_mode == "dense":
            # complex measure expression over a packed index: evaluate it
            # (decoding any packed LCols it references) and stream it dense;
            # dst still decodes in VMEM
            mv = eval_lexpr(op.measure, self.params, self.scalars, self.col)
            m_operand = jnp.broadcast_to(
                jnp.asarray(mv, jnp.float32), (op.src_ids.shape[0],)
            )
        return K.fragment_spmv_packed(
            w, op.src_ids,
            op.dst_col.words if dst_packed else op.dst_col.materialize(),
            m_operand, mdict,
            n_dst=op.dom_dst,
            dst_width=op.dst_col.width if dst_packed else 0,
            m_mode=m_mode, m_width=m_width, op=self.sr.name,
            use_pallas=self.use_pallas,
            blocks=self.blocks_for(op), block_skipping=self.block_skipping,
        )

    def spmv(self, w, src, dst, m, valid, op: HopOp):
        from ..kernels import ops as K

        return K.fragment_spmv(
            w, src, dst, m, n_dst=op.dom_dst, op=self.sr.name,
            use_pallas=self.use_pallas,
            blocks=self.blocks_for(op), block_skipping=self.block_skipping,
        )

    # -- pipelined fused regions (DESIGN.md §Pipelined fusion) --------------

    def _hop_operands(self, op: HopOp, reach=None):
        """One HopOp → the fused entry's :class:`FusedHopOperands` bundle, or
        None when the hop has a shape the single-pass kernel cannot express
        (batch-dependent measure expression) — the caller then replays the
        region unfused."""
        from ..kernels import ops as K

        layout = self._packed_layout(op)
        if layout is None:
            dst_packed, m_operand, m_width, mdict = False, None, 0, None
            m_mode = (
                "dense"
                if op.measure is not None and self.use_measures else "none"
            )
        else:
            dst_packed, m_mode, m_operand, m_width, mdict = layout
        if m_mode == "dense" and m_operand is None:
            mv = jnp.asarray(
                eval_lexpr(op.measure, self.params, self.scalars, self.col),
                jnp.float32,
            )
            if mv.ndim >= 2:  # batch-dependent measure: no shared edge stream
                return None
            m_operand = jnp.broadcast_to(mv, (op.src_ids.shape[0],))
        return K.FusedHopOperands(
            src_ids=op.src_ids,
            dst=op.dst_col.words if dst_packed else op.dst_col.materialize(),
            measure=m_operand, mdict=mdict,
            n_dst=op.dom_dst,
            dst_width=op.dst_col.width if dst_packed else 0,
            m_mode=m_mode, m_width=m_width,
            blocks=self.blocks_for(op), reach=reach,
        )

    def _fused_region_args(self, op: FusedHopOp):
        """Collect the region's kernel arguments: the two hop bundles, the
        product of the member filters' constant masks, and whether hop2's
        semijoin entry binarizes the intermediate. None ⇒ fall back to the
        generic member-replay rule."""
        hops = op.hops
        h1_op = hops[0]
        h2_op = hops[1] if len(hops) > 1 else None
        hop1 = self._hop_operands(h1_op)
        if hop1 is None:
            return None
        hop2 = None
        if h2_op is not None:
            hop2 = self._hop_operands(h2_op, reach=op.reach)
            if hop2 is None:
                return None
        mid_mask = None
        for f in op.mid_filters:
            if f.const_mask is None:
                continue
            m = jnp.asarray(f.const_mask, jnp.float32)
            mid_mask = m if mid_mask is None else mid_mask * m
        mid_binarize = bool(h2_op.semijoin) if h2_op is not None else False
        return h1_op, h2_op, hop1, hop2, mid_mask, mid_binarize

    def _fused_call(self, w, hop1, hop2, mid_mask, mid_binarize):
        from ..kernels import ops as K

        return K.fragment_spmv_fused(
            w, hop1, hop2, mid_mask, op=self.sr.name,
            mid_binarize=mid_binarize, use_pallas=self.use_pallas,
            fusion=self.fusion, block_skipping=self.block_skipping,
        )

    def fused_hop(self, op: FusedHopOp, state, cont):
        """Single-pass execution of a fused region: hop1 accumulates into a
        VMEM scratch frontier, the member filters' constant mask and hop2's
        semijoin binarize apply in-register at the phase boundary, hop2
        streams against the resident intermediate. The all-zero-frontier
        short circuit wraps the whole region (one cond instead of two)."""
        if not self.fuse_kernels or self.fusion == "off":
            return super().fused_hop(op, state, cont)
        args = self._fused_region_args(op)
        if args is None:
            return super().fused_hop(op, state, cont)
        h1_op, h2_op, hop1, hop2, mid_mask, mid_binarize = args
        sr, w = self.sr, state
        if h1_op.semijoin:
            w = sr.binarize(w)
        n_out = hop2.n_dst if hop2 is not None else hop1.n_dst

        def body(w):
            return self._fused_call(w, hop1, hop2, mid_mask, mid_binarize)

        if not self.early_exit:
            out = body(w)
        else:
            out_shape = w.shape[:-1] + (n_out,)
            out = jax.lax.cond(
                jnp.count_nonzero(w != sr.zero) == 0,
                lambda w: jnp.full(out_shape, sr.zero, jnp.float32),
                body, w,
            )
        g = op.group
        if g is not None and g.entity is None:
            out = sr.to_mask(out)
        return cont(out)

    def degree_filter(self, op: DegreeFilterOp, state, cont):
        return cont(self.sr.mask(state, self.degrees(op) > 0))

    def degrees(self, op: DegreeFilterOp):
        return op.degrees

    def entity_filter(self, op: EntityFilterOp, state, cont):
        w = state
        if op.factor is not None and self.use_measures:
            f = eval_lexpr(op.factor, self.params, self.scalars, self.col)
            w = self.sr.extend(w, jnp.asarray(f, jnp.float32))
        if op.const_mask is not None:
            w = self.sr.mask(w, op.const_mask)
        for c in op.param_conds:
            w = self.sr.mask(w, c.mask(self.params, self.attr_col))
        return cont(w)

    def group(self, op: GroupOp, state, cont):
        if op.entity is None:
            return cont(self.sr.to_mask(state))
        return cont(state)


def compile_frontier(
    db: DeviceDB, plan: ChainPlan | PhysicalPlan,
    block_skipping: str = "auto", use_pallas: bool = True,
    fusion: str = "auto",
) -> Callable[..., jnp.ndarray]:
    phys = ensure_lowered(db, plan)
    names = list(phys.param_names)

    @jax.jit
    def run(*args):
        params = dict(zip(names, args))
        return execute_ir(
            phys,
            lambda sr, um: _FrontierInterp(
                params, sr, um, block_skipping=block_skipping,
                use_pallas=use_pallas, fusion=fusion,
            ),
        )

    return run


# ---------------------------------------------------------------------------
# Batched frontier strategy (multi-query SpMM serving path)
# ---------------------------------------------------------------------------


class _BatchedFrontierInterp(_FrontierInterp):
    """Frontier semantics with a leading batch axis threaded through the
    walker state: frontiers are [B, dom] matrices and each HopOp is one fused
    SpMM pass (kernels/fragment_spmm.py) that streams the edge arrays once
    for all B queries — not a vmap of the whole plan, so the kernel sees the
    batch as a unit. Parameters arrive as [B, 1] columns (broadcast against
    per-entity [dom] and per-edge [E] arrays yields [B, ·]); seed ids reshape
    back to [B] for indexing. Per-op batching rules:

      * SeedOp        — scatter B seed ids at once (one 2-D scatter-⊕);
                        mask seeds run their sub-programs batched.
      * EntityFilter/ — masks and degree vectors are [dom] (or [B, dom] when
        DegreeFilter    parameter-dependent) and broadcast against [B, dom].
      * GroupOp       — returns the [B, dom] accumulator (or mask) as-is.
    """

    def __init__(self, params: dict[str, Any], sr: Semiring,
                 use_measures: bool = True, *, batch: int,
                 block_skipping: str = "auto", use_pallas: bool = True,
                 fusion: str = "auto"):
        super().__init__(params, sr, use_measures,
                         block_skipping=block_skipping, use_pallas=use_pallas,
                         fusion=fusion)
        self.batch = batch

    def spawn(self) -> "_BatchedFrontierInterp":
        return _BatchedFrontierInterp(
            self.params, BOOL_OR_AND, batch=self.batch,
            block_skipping=self.block_skipping, use_pallas=self.use_pallas,
            fusion=self.fusion,
        )

    def _fused_call(self, w, hop1, hop2, mid_mask, mid_binarize):
        from ..kernels import ops as K

        return K.fragment_spmm_fused(
            w, hop1, hop2, mid_mask, op=self.sr.name,
            mid_binarize=mid_binarize, use_pallas=self.use_pallas,
            fusion=self.fusion, block_skipping=self.block_skipping,
        )

    def _seed_ids(self, i) -> jnp.ndarray:
        """One seed slot → [B] int32 (constants broadcast across the batch)."""
        v = self.resolve(i)
        if isinstance(v, (int, float)):
            return jnp.full((self.batch,), int(v), jnp.int32)
        return jnp.asarray(v).reshape(-1).astype(jnp.int32)

    def capture_scalars(self, op: SeedOp, sid):
        # sid is [B]; keep scalars as [B, 1] columns so downstream expression
        # broadcasting against [dom]/[E] arrays lands on [B, ·]
        self.scalars = {
            s.key: self.attr_col(s)[sid][:, None] for s in op.scalars.values()
        }

    def seed(self, op: SeedOp, state, cont):
        sr, B = self.sr, self.batch
        if op.ids is not None:
            cols = [self._seed_ids(i) for i in op.ids]
            idx = jnp.stack(cols, axis=1)  # [B, n_ids]
            w = jnp.full((B, op.dom), sr.zero, jnp.float32)
            # scatter-⊕ per row (duplicate ids accumulate multiplicity, as in
            # the single-query path); sr.scatter takes any advanced index
            w = sr.scatter(w, (jnp.arange(B)[:, None], idx), sr.one)
            if op.scalars:
                self.capture_scalars(op, cols[0])
            return cont(w)
        m = jnp.ones((B, op.dom), jnp.float32)
        for prog in op.programs:
            m = m * walk_ir(prog, self.spawn())
        if op.const_mask is not None:
            m = m * op.const_mask
        for c in op.param_conds:
            m = m * c.mask(self.params, self.attr_col).astype(jnp.float32)
        return cont(sr.from_mask(m))

    def _hop_body(self, w, op: HopOp):
        # the [B, n_src] frontier reaches the kernel dispatch whole: the block
        # list is computed from the union of per-row supports (support_mask),
        # so one SMEM list serves the entire batch
        from ..kernels import ops as K

        fused = self.spmm_fused(w, op)
        if fused is not None:
            return fused
        src, dst = op.src_ids, op.dst_ids
        E = src.shape[0]
        if op.measure is not None and self.use_measures:
            m = jnp.asarray(
                eval_lexpr(op.measure, self.params, self.scalars, self.col),
                jnp.float32,
            )
        else:
            m = jnp.ones((), jnp.float32)
        if m.ndim <= 1:  # scalar or shared per-edge stream → SpMM kernel
            m = jnp.broadcast_to(m, (E,))
        else:  # per-row measure (seed scalars / params) → [B, E], XLA fallback
            m = jnp.broadcast_to(m, (w.shape[0], E))
        return K.fragment_spmm(
            w, src, dst, m, n_dst=op.dom_dst, op=self.sr.name,
            use_pallas=self.use_pallas,
            blocks=self.blocks_for(op), block_skipping=self.block_skipping,
        )

    def spmm_fused(self, w, op: HopOp):
        """Batched decode-fused hop: packed dst/measure columns stream into
        the SpMM kernel and decode once per block for all B rows. Same layout
        classification as ``spmv_fused`` (`_packed_layout`); additionally
        bails (→ dense path) when a measure expression is batch-dependent —
        a per-row [B, E] dense stream has no fused single-pass formulation."""
        from ..kernels import ops as K

        layout = self._packed_layout(op)
        if layout is None:
            return None
        dst_packed, m_mode, m_operand, m_width, mdict = layout
        if m_mode == "dense":
            mv = jnp.asarray(
                eval_lexpr(op.measure, self.params, self.scalars, self.col),
                jnp.float32,
            )
            if mv.ndim >= 2:  # batch-dependent measure: no shared edge stream
                return None
            m_operand = jnp.broadcast_to(mv, (op.src_ids.shape[0],))
        return K.fragment_spmm_packed(
            w, op.src_ids,
            op.dst_col.words if dst_packed else op.dst_col.materialize(),
            m_operand, mdict,
            n_dst=op.dom_dst,
            dst_width=op.dst_col.width if dst_packed else 0,
            m_mode=m_mode, m_width=m_width, op=self.sr.name,
            use_pallas=self.use_pallas,
            blocks=self.blocks_for(op), block_skipping=self.block_skipping,
        )


def compile_frontier_batched(
    db: DeviceDB, plan: ChainPlan | PhysicalPlan,
    block_skipping: str = "auto", use_pallas: bool = True,
    fusion: str = "auto",
) -> Callable[..., jnp.ndarray]:
    """Batched serving entry: takes one [B] array per query parameter and
    returns the [B, out_dom] result block in one traced pass — every HopOp
    runs as a fused SpMM streaming the edge arrays once for the whole batch.
    Each distinct B compiles once; callers bound recompiles by padding ragged
    batches to bucket sizes (engine.PreparedQuery.execute_batch)."""
    phys = ensure_lowered(db, plan)
    names = list(phys.param_names)
    if not names:
        raise ValidationError(
            "batched execution needs at least one query parameter"
        )

    @jax.jit
    def run(*args):
        B = args[0].shape[0]
        params = {n: jnp.asarray(a)[:, None] for n, a in zip(names, args)}
        return execute_ir(
            phys,
            lambda sr, um: _BatchedFrontierInterp(
                params, sr, um, batch=B, block_skipping=block_skipping,
                use_pallas=use_pallas, fusion=fusion,
            ),
        )

    return run


# ---------------------------------------------------------------------------
# Paper-faithful fragment-at-a-time strategy (Fig. 3 port)
# ---------------------------------------------------------------------------


class _FragmentLoopInterp(_Interp):
    """Scalar state (cur_id, weight, ℛ): HopOps emit nested fori_loops over
    one fragment at a time; GroupOp is a single scalar ⊕-update per completed
    path — a direct port of the generated C++."""

    def __init__(self, params, sr, use_measures=True, out_dom: int = 0):
        super().__init__(params, sr, use_measures)
        self.out_dom = out_dom

    def seed(self, op: SeedOp, state, cont):
        sr = self.sr
        R = jnp.full(self.out_dom, sr.zero, jnp.float32)
        if op.scalars:
            self.capture_scalars(op, self.resolve(op.ids[0]))
        for i in op.ids:  # static seed count: unrolled chain per seed id
            sid = jnp.asarray(self.resolve(i), dtype=jnp.int32)
            R = cont((sid, jnp.float32(sr.one), R))
        return R

    def hop(self, op: HopOp, state, cont):
        cur, wgt, R = state
        start = op.indptr[cur]
        n = op.indptr[cur + 1] - start

        def body(k, Rc):
            e = start + k
            w2 = wgt
            if op.measure is not None and self.use_measures:
                mval = eval_lexpr(
                    op.measure, self.params, self.scalars, lambda c: c.array[e]
                )
                w2 = self.sr.extend(w2, mval)
            return cont((op.dst_ids[e], w2, Rc))

        return jax.lax.fori_loop(0, n, body, R)

    def degree_filter(self, op: DegreeFilterOp, state, cont):
        cur, wgt, R = state
        return cont((cur, self.sr.select(op.degrees[cur] > 0, wgt), R))

    def entity_filter(self, op: EntityFilterOp, state, cont):
        cur, wgt, R = state
        if op.factor is not None and self.use_measures:
            f = eval_lexpr(
                op.factor, self.params, self.scalars, lambda c: c.array[cur]
            )
            wgt = self.sr.extend(wgt, f)
        keep = None
        if op.const_mask is not None:
            keep = op.const_mask[cur] > 0
        for c in op.param_conds:
            k = c.mask(self.params, lambda cc: cc.array[cur])
            keep = k if keep is None else keep & k
        if keep is not None:
            wgt = self.sr.select(keep, wgt)
        return cont((cur, wgt, R))

    def group(self, op: GroupOp, state, cont):
        cur, wgt, R = state
        return cont(self.sr.scatter(R, cur, wgt))


def compile_fragment_loop(
    db: DeviceDB, plan: ChainPlan | PhysicalPlan,
    block_skipping: str = "auto", use_pallas: bool = True,
) -> Callable[..., jnp.ndarray]:
    """Nested fori_loops over fragments, scalar per-edge accumulator updates.
    Only id-seeded chains (SD/FSD/AS shapes); mask seeds and semijoins fall
    back to the frontier strategy. ``block_skipping`` only applies to that
    fallback — the scalar loop already touches only reached fragments."""
    phys = ensure_lowered(db, plan)
    seed_op = phys.ops[0]
    if seed_op.ids is None or any(
        isinstance(op, HopOp) and op.semijoin for op in iter_flat_ops(phys)
    ):
        return compile_frontier(db, phys, block_skipping=block_skipping,
                                use_pallas=use_pallas)
    phys = densify_plan(phys)  # scalar loops have no packed path (§Storage)
    names = list(phys.param_names)

    @jax.jit
    def run(*args):
        params = dict(zip(names, args))
        return execute_ir(
            phys,
            lambda sr, um: _FragmentLoopInterp(params, sr, um, out_dom=phys.out_dom),
        )

    return run


# ---------------------------------------------------------------------------
# Distributed (edge-sharded shard_map, one collective per hop)
# ---------------------------------------------------------------------------


def shard_edges(db: DeviceDB, mesh: Mesh, axes: tuple[str, ...]) -> DeviceDB:
    """Pad every index's edge arrays to a multiple of the shard count and place
    them edge-sharded on ``axes``; padding edges carry ``__valid__`` 0 and are
    masked to the semiring zero inside every hop."""
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    out: dict[tuple[str, str], DeviceIndex] = {}
    for key, di in db.indexes.items():
        E = di.src_ids.shape[0]
        pad = (-E) % nshards
        ew = jnp.concatenate([jnp.ones(E, jnp.float32), jnp.zeros(pad, jnp.float32)])
        pd = lambda a, fill: jnp.concatenate([a, jnp.full(pad, fill, a.dtype)])
        sharding = NamedSharding(mesh, P(axes))
        # materialize per shard: packed columns decode once here (eagerly, at
        # shard-placement time) — the distributed strategy's documented
        # fallback; its shard trees are always dense
        nd = DeviceIndex(
            indptr=di.indptr,
            src_ids=jax.device_put(pd(di.src_ids, 0), sharding),
            dst_col=DenseColumn(jax.device_put(pd(di.dst_ids, 0), sharding)),
            degrees=di.degrees,
        )
        nd.measure_cols = {
            m: DenseColumn(jax.device_put(pd(v, 0), sharding))
            for m, v in di.measures.items()
        }
        nd.measure_cols["__valid__"] = DenseColumn(jax.device_put(ew, sharding))
        out[key] = nd
    return DeviceDB(db.schema, out, db.entity_attrs, db.host_indexes)


class _DistributedInterp(_FrontierInterp):
    """Frontier semantics with edge arrays drawn from the shard_map argument
    trees and one ⊕-collective per hop (psum/pmin/pmax by semiring).

    No per-hop lax.cond early exit (``early_exit = False``): each hop ends in
    a psum/pmin/pmax and a collective inside one cond branch deadlocks when
    shards disagree about the frontier. Block skipping is likewise off — the
    sharded hop is an XLA segment-reduce over shard-local padded edge arrays,
    not a Pallas block stream, so there are no blocks to skip."""

    early_exit = False
    fuse_kernels = False

    def __init__(self, params, sr, use_measures=True, *, edges=None, side=None,
                 axes=("data",), frontier_dtype=jnp.float32):
        super().__init__(params, sr, use_measures, block_skipping="off")
        self.edges = edges
        self.side = side
        self.axes = axes
        self.frontier_dtype = frontier_dtype

    def spawn(self) -> "_DistributedInterp":
        return _DistributedInterp(
            self.params, BOOL_OR_AND, edges=self.edges, side=self.side,
            axes=self.axes, frontier_dtype=self.frontier_dtype,
        )

    # column routing: shard_map arguments instead of lower-time closures
    def col(self, c):
        kind = c.key[0]
        if kind == "edge":
            _, table, key, attr = c.key
            return self.edges[f"{table}::{key}"][f"m::{attr}"]
        _, entity, attr = c.key
        return self.side[f"attr::{entity}::{attr}"]

    attr_col = col

    def spmv_fused(self, w, op: HopOp):
        # edge data comes from the shard_map argument trees (always dense, see
        # shard_edges), never from the lower-time column bindings
        return None

    def edge_arrays(self, op: HopOp):
        e = self.edges[f"{op.table}::{op.src_key}"]
        return e["src"], e["dst"], e["m::__valid__"]

    def degrees(self, op: DegreeFilterOp):
        return self.side[f"deg::{op.table}::{op.src_key}"]

    def spmv(self, w, src, dst, m, valid, op: HopOp):
        sr = self.sr
        ew = sr.mask(sr.extend(jnp.take(w, src), m), valid)
        part = sr.segment(ew, dst, op.dom_dst)
        # frontier_dtype=bf16 halves every per-hop all-reduce
        return sr.preduce(part.astype(self.frontier_dtype), self.axes).astype(
            jnp.float32
        )


def _shard_map_fn():
    try:
        return jax.shard_map  # jax >= 0.5 style
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

        return shard_map


def _shard_map_compat(fn, mesh, in_specs, out_specs):
    shard_map = _shard_map_fn()
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)
    except TypeError:  # older jax spells the kwarg check_rep
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=False)


def compile_frontier_distributed(
    db: DeviceDB, plan: ChainPlan | PhysicalPlan, mesh: Mesh,
    axes: tuple[str, ...] = ("data",),
    batched: bool = False, frontier_dtype=jnp.float32,
    sharded_db: DeviceDB | None = None,
    prefix: int | None = None,
) -> Callable[..., jnp.ndarray]:
    """shard_map execution: frontier vectors replicated, edges sharded; each hop
    computes a local partial accumulator and ⊕-reduces it — the paper's parallel
    design (§6 "Parallel Computing") with the collective replacing spinlocks.

    Edge arrays flow through shard_map *arguments* (in_specs=P(axes)) so each
    device sees only its shard; small arrays (indptr, degrees, entity attrs,
    frontier vectors) are closure constants, i.e. replicated.

    ``sharded_db`` lets callers compiling several entries against one mesh
    (e.g. the engine's single + batched pair) share one ``shard_edges``
    placement instead of device-putting every edge array per compile.

    ``prefix=k`` compiles only the plan's first k ops and returns the raw
    interpreter state (no finalize; AVG runs its weighted pass only) — the
    profiling entry behind ``PreparedQuery.profile()``'s prefix-delta per-op
    timings. Every intermediate state is replicated (each hop ends in its
    ⊕-collective), so the ``P()`` out-spec holds for any prefix.
    """
    phys = ensure_lowered(db, plan)
    names = list(phys.param_names)
    sdb = sharded_db if sharded_db is not None else shard_edges(db, mesh, axes)

    edge_tree = {
        f"{t}::{k}": {
            "src": di.src_ids,
            "dst": di.dst_ids,
            **{f"m::{m}": v for m, v in di.measures.items()},
        }
        for (t, k), di in sdb.indexes.items()
    }
    edge_specs = jax.tree.map(lambda _: P(axes), edge_tree)
    # replicated side tables: entity attributes + per-index degrees — arguments
    # (not closures) so the dry-run can substitute full-scale ShapeDtypeStructs
    side_tree = {
        **{f"attr::{e}::{a}": v for (e, a), v in sdb.entity_attrs.items()},
        **{f"deg::{t}::{k}": di.degrees for (t, k), di in sdb.indexes.items()},
    }
    side_specs = jax.tree.map(lambda _: P(), side_tree)

    def run(edges, side, *args):
        def eval_once(*scalar_args):
            params = dict(zip(names, scalar_args))
            mk = lambda sr, um: _DistributedInterp(
                params, sr, um, edges=edges, side=side, axes=axes,
                frontier_dtype=frontier_dtype,
            )
            if prefix is not None:
                return walk_ir(phys, mk(semiring_for(phys.agg), True), stop=prefix)
            return execute_ir(phys, mk)

        if batched:
            # batched OLAP serving: vmap over parameter vectors inside the
            # shard_map body — frontier becomes [B, dom], hops become SpMM
            return jax.vmap(eval_once)(*args)
        return eval_once(*args)

    smapped = _shard_map_compat(
        run, mesh,
        in_specs=(edge_specs, side_specs) + tuple(P() for _ in names),
        out_specs=P(),
    )
    jitted = jax.jit(smapped)

    def call(*args):
        return jitted(edge_tree, side_tree, *args)

    call.lowerable = (jitted, edge_tree, side_tree, edge_specs, side_specs)  # dry-run hook
    return call


STRATEGIES = {
    "frontier": compile_frontier,
    "fragment_loop": compile_fragment_loop,
}
