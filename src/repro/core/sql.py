"""SQL subset parser for relationship queries (paper §4).

Supports exactly the relationship-query surface: SELECT with plain key columns
and COUNT(*)/EXISTS(*)/SUM(expr)/MIN(expr)/MAX(expr)/AVG(expr) aggregates
(arithmetic over measure/entity attributes, abs), FROM with JOIN..ON chains
(arbitrarily parenthesized) or comma lists, WHERE conjunctions of key-equality
join conditions / constant predicates / IN (sub-relationship-query) with
INTERSECT chains, GROUP BY on a single key. Parameters are written ``:name``
(prepare once, execute many — paper §3).

The aggregate chooses the execution semiring (DESIGN.md §3): SUM/COUNT run the
classic (+, ×) accumulator, MIN/MAX the (min/max, ×) lattices, EXISTS(*) pure
boolean reachability, and AVG a fused SUM+COUNT pair. Like the paper's
``SUM(e1)/e2 ≡ SUM(e1/e2)`` per-path convention (Fig. 3), arithmetic around an
aggregate call distributes into it — exact for SUM/AVG, and for MIN/MAX under
the engine's non-negative-factor contract.
"""
from __future__ import annotations

import re

from ..robust.errors import ParseError
from .algebra import (
    BinOp,
    Call,
    Const,
    ConstCond,
    Expr,
    JoinCond,
    Param,
    Query,
    Ref,
    SelectItem,
    Subquery,
    TableRef,
)

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.\d+|\d+)|(?P<param>:[A-Za-z_]\w*)|(?P<name>[A-Za-z_]\w*)"
    r"|(?P<op>>=|<=|<>|!=|[(),.*/+\-=<>]))"
)

_KEYWORDS = {
    "select", "from", "where", "join", "on", "group", "by", "in",
    "intersect", "and", "count", "sum", "min", "max", "avg", "exists",
    "abs", "as",
}


def tokenize(sql: str) -> tuple[list[tuple[str, str]], list[int]]:
    """Token stream plus the character offset of each token in the (stripped)
    query text — the offsets feed :class:`ParseError` position context."""
    toks: list[tuple[str, str]] = []
    starts: list[int] = []
    pos = 0
    sql = sql.strip().rstrip(";")
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise ParseError(
                f"unrecognized token at character {pos}",
                position=pos, near=sql[pos:pos + 30], query=sql,
            )
        starts.append(m.start(m.lastgroup))
        pos = m.end()
        if m.lastgroup == "num":
            toks.append(("num", m.group("num")))
        elif m.lastgroup == "param":
            toks.append(("param", m.group("param")[1:]))
        elif m.lastgroup == "name":
            w = m.group("name")
            toks.append(("kw", w.lower()) if w.lower() in _KEYWORDS else ("name", w))
        else:
            toks.append(("op", m.group("op")))
    return toks, starts


class _Parser:
    def __init__(self, toks: list[tuple[str, str]], starts: list[int] | None = None,
                 sql: str = ""):
        self.toks = toks
        self.starts = starts or []
        self.sql = sql
        self.i = 0

    def error(self, message: str, at: int | None = None) -> ParseError:
        """A :class:`ParseError` anchored at token index ``at`` (default: the
        current token), carrying the character position and nearby text."""
        j = min(at if at is not None else self.i, len(self.toks))
        pos = self.starts[j] if j < len(self.starts) else len(self.sql)
        return ParseError(
            message, position=pos, token_index=j,
            near=self.sql[pos:pos + 30] if self.sql else None, query=self.sql,
        )

    # -- token helpers ------------------------------------------------------
    def peek(self, k: int = 0):
        j = self.i + k
        return self.toks[j] if j < len(self.toks) else ("eof", "")

    def next(self):
        t = self.peek()
        self.i += 1
        return t

    def accept(self, kind: str, val: str | None = None) -> bool:
        t = self.peek()
        if t[0] == kind and (val is None or t[1] == val):
            self.i += 1
            return True
        return False

    def expect(self, kind: str, val: str | None = None) -> str:
        t = self.next()
        if t[0] != kind or (val is not None and t[1] != val):
            raise self.error(
                f"expected {kind} {val or ''}, got {t[0]} {t[1]!r}", at=self.i - 1
            )
        return t[1]

    # -- grammar ------------------------------------------------------------
    def parse_query(self) -> Query:
        self.expect("kw", "select")
        select = [self.parse_select_item()]
        while self.accept("op", ","):
            select.append(self.parse_select_item())
        self.expect("kw", "from")
        tables, join_conds = self.parse_from()
        const_conds: list[ConstCond] = []
        if self.accept("kw", "where"):
            jc, cc = self.parse_conds()
            join_conds += jc
            const_conds += cc
        group_by = None
        if self.accept("kw", "group"):
            self.expect("kw", "by")
            group_by = self.parse_ref(allow_unqualified=True)
        return Query(select, tables, join_conds, const_conds, group_by)

    def parse_select_item(self) -> SelectItem:
        # COUNT(*) / EXISTS(*) | plain ref | expression containing an
        # aggregate call SUM/MIN/MAX/AVG(...)
        for star_agg in ("count", "exists"):
            if self.peek() == ("kw", star_agg):
                self.next()
                self.expect("op", "(")
                self.expect("op", "*")
                self.expect("op", ")")
                return SelectItem(expr=None, ref=None, agg=star_agg)
        start = self.i
        expr = self.parse_expr()
        if isinstance(expr, Ref) and self._expr_agg is None:
            return SelectItem(expr=None, ref=expr, agg=None)
        if self._expr_agg is not None:
            return SelectItem(expr=expr, ref=None, agg=self._expr_agg)
        self.i = start
        raise self.error(
            f"unsupported select item (expected a key column, COUNT(*)/EXISTS(*),"
            f" or an aggregate expression), at token {self.toks[start]}", at=start
        )

    def parse_from(self) -> tuple[list[TableRef], list[JoinCond]]:
        tables: list[TableRef] = []
        joins: list[JoinCond] = []

        def parse_source():
            if self.accept("op", "("):
                parse_source()
                self.expect("op", ")")
            else:
                tname = self.expect("name")
                var = self.expect("name") if self.peek()[0] == "name" else tname
                tables.append(TableRef(tname, var))
            while self.accept("kw", "join"):
                if self.accept("op", "("):
                    parse_source()
                    self.expect("op", ")")
                else:
                    tname2 = self.expect("name")
                    var2 = self.expect("name") if self.peek()[0] == "name" else tname2
                    tables.append(TableRef(tname2, var2))
                self.expect("kw", "on")
                l = self.parse_ref()
                self.expect("op", "=")
                r = self.parse_ref()
                joins.append(JoinCond(l, r))

        parse_source()
        while self.accept("op", ","):
            parse_source()
        return tables, joins

    def parse_conds(self) -> tuple[list[JoinCond], list[ConstCond]]:
        joins: list[JoinCond] = []
        consts: list[ConstCond] = []
        while True:
            ref = self.parse_ref()
            if self.accept("kw", "in"):
                consts.append(ConstCond(ref, "in", self.parse_in_rhs()))
            else:
                op = self.expect("op")
                if op not in ("=", ">", "<", ">=", "<="):
                    raise self.error(
                        f"unsupported predicate operator {op!r}", at=self.i - 1
                    )
                t = self.peek()
                if t[0] == "name":
                    joins.append(JoinCond(ref, self.parse_ref()))
                elif t[0] == "num":
                    self.next()
                    consts.append(ConstCond(ref, op, _num(t[1])))
                elif t[0] == "param":
                    self.next()
                    consts.append(ConstCond(ref, op, Param(t[1])))
                else:
                    raise self.error(
                        f"predicate right-hand side must be a column, number,"
                        f" or :parameter, got {t[0]} {t[1]!r}"
                    )
            if not self.accept("kw", "and"):
                break
        return joins, consts

    def parse_in_rhs(self) -> Subquery:
        """Both of the paper's forms:
        A: IN (SELECT …) INTERSECT (SELECT …) …   (IN parens = first subquery's)
        B: IN ( (SELECT …) INTERSECT (SELECT …) … )   (outer parens wrap chain)
        """
        self.expect("op", "(")
        queries: list[Query] = []
        if self.peek() == ("kw", "select"):
            queries.append(self.parse_query())
            self.expect("op", ")")
        else:
            self.expect("op", "(")
            queries.append(self.parse_query())
            self.expect("op", ")")
            while self.accept("kw", "intersect"):
                self.expect("op", "(")
                queries.append(self.parse_query())
                self.expect("op", ")")
            self.expect("op", ")")
        while self.accept("kw", "intersect"):
            self.expect("op", "(")
            queries.append(self.parse_query())
            self.expect("op", ")")
        return Subquery(queries[0], queries[1:])

    def parse_ref(self, allow_unqualified: bool = False) -> Ref:
        name = self.expect("name")
        if self.accept("op", "."):
            return Ref(name, self.expect("name"))
        if allow_unqualified:
            return Ref("", name)
        raise self.error(
            f"expected a qualified column reference (var.Attr), got bare {name!r}",
            at=self.i - 1,
        )

    # -- expressions --------------------------------------------------------
    _expr_agg: str | None = None  # aggregate kind seen inside the expression

    def parse_expr(self) -> Expr:
        self._expr_agg = None
        return self._add()

    def _add(self) -> Expr:
        e = self._mul()
        while True:
            if self.accept("op", "+"):
                e = BinOp("+", e, self._mul())
            elif self.accept("op", "-"):
                e = BinOp("-", e, self._mul())
            else:
                return e

    def _mul(self) -> Expr:
        e = self._atom()
        while True:
            if self.accept("op", "*"):
                e = BinOp("*", e, self._atom())
            elif self.accept("op", "/"):
                e = BinOp("/", e, self._atom())
            else:
                return e

    def _atom(self) -> Expr:
        t = self.peek()
        if t[0] == "kw" and t[1] in ("sum", "min", "max", "avg"):
            self.next()
            self.expect("op", "(")
            inner = self._add()
            self.expect("op", ")")
            if self._expr_agg is not None:
                # AGG(a)+AGG(b) would silently merge into AGG(a+b); that
                # identity holds for SUM only, not MIN/MAX/AVG — reject all
                raise self.error(
                    f"multiple aggregate calls ({self._expr_agg}, {t[1]}) "
                    "in one select item"
                )
            self._expr_agg = t[1]
            return inner  # AGG(e1)/e2 ≡ AGG(e1/e2): per-path accumulation (Fig. 3)
        if t == ("kw", "abs"):
            self.next()
            self.expect("op", "(")
            inner = self._add()
            self.expect("op", ")")
            return Call("abs", (inner,))
        if t[0] == "num":
            self.next()
            return Const(_num(t[1]))
        if t[0] == "param":
            self.next()
            return Param(t[1])
        if t[0] == "name":
            return self.parse_ref()
        if self.accept("op", "("):
            e = self._add()
            self.expect("op", ")")
            return e
        raise self.error(f"unexpected token in expression: {t[0]} {t[1]!r}")


def _num(s: str):
    return float(s) if "." in s else int(s)


def parse(sql: str) -> Query:
    toks, starts = tokenize(sql)
    p = _Parser(toks, starts, sql.strip().rstrip(";"))
    q = p.parse_query()
    if p.peek()[0] != "eof":
        raise p.error(f"trailing tokens after a complete query: {p.toks[p.i:]}")
    return q
