"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute via ``interpret=True`` (Pallas body run
as Python/XLA — the correctness validation mode mandated for this environment);
on TPU they run compiled. ``use_pallas=False`` selects the pure-XLA fallback
(identical math from :mod:`repro.kernels.ref`).

Frontier-sparsity dispatch (kernels/active.py): the four hop entries accept
``blocks=(src_min, src_max)`` per-block metadata and a ``block_skipping`` mode
('off' | 'on' | 'auto'). With metadata present and skipping engaged, the call
routes to the scalar-prefetch ``*_active`` kernel so only blocks whose src
range intersects the frontier's support are streamed. Two tiers:

  * **eager** (concrete frontier — kernel-level callers, benchmarks): the
    active list is computed in numpy, the capacity bucketed to a power of two,
    and the grid *really* shrinks; 'auto' bails back to the scan when the
    surviving fraction exceeds ``SKIP_BLOCK_FRACTION``.
  * **traced** (frontier is a jit tracer — the executor's compiled hop chain):
    the list is computed in-graph at full capacity (static shapes), inactive
    grid steps are ``pl.when``-guarded no-DMA no-ops; 'auto' wraps the choice
    in a runtime ``lax.cond`` on the surviving-block count.

Skipping is bit-identical to the scan for every op (skipped contributions are
the ⊕-identity); the XLA fallback always full-scans, which is the same result.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import active as _active
from . import ref
from ..obs import trace as _obs_trace
from ..robust import faults as _faults
from ..robust.errors import ValidationError
from .bitmap_ops import bitmap_and as _bitmap_and
from .bitmap_ops import bitmap_and_popcount as _bitmap_and_popcount
from .bitunpack import bitunpack as _bitunpack
from .fragment_spmm import fragment_spmm as _fragment_spmm
from .fragment_spmm import fragment_spmm_active as _fragment_spmm_active
from .fragment_spmm import fragment_spmm_packed as _fragment_spmm_packed
from .fragment_spmm import fragment_spmm_packed_active as _fragment_spmm_packed_active
from .fragment_spmv import IDENTITY as _IDENTITY
from .fragment_spmv import fragment_spmv as _fragment_spmv
from .fragment_spmv import fragment_spmv_active as _fragment_spmv_active
from .fragment_spmv_fused import _apply_mask as _fused_apply_mask
from .fragment_spmv_fused import _binarize as _fused_binarize
from .fragment_spmv_fused import fragment_spmm_fused as _fragment_spmm_fused
from .fragment_spmv_fused import fragment_spmv_fused as _fragment_spmv_fused
from .fragment_spmv_packed import fragment_spmv_packed as _fragment_spmv_packed
from .fragment_spmv_packed import (
    fragment_spmv_packed_active as _fragment_spmv_packed_active,
)
from .params import FUSED_VMEM_BUDGET_BYTES

BLOCK_SKIPPING_MODES = ("off", "on", "auto")

#: Pipelined-region dispatch: 'off' always composes the member hops through
#: the unfused kernels, 'on' forces the fused kernel, 'auto' fuses unless the
#: VMEM-resident intermediate (4·n_mid·B bytes) exceeds FUSED_VMEM_BUDGET_BYTES.
FUSION_MODES = ("off", "on", "auto")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _plan_skip(w, op: str, E: int, blocks, block_skipping: str):
    """Decide scan vs skip for one hop. ``None`` → full scan; otherwise
    ``(block_idx, n_active, mode)`` with mode 'static' (commit to the active
    kernel now) or 'cond' (traced 'auto': pick at runtime via lax.cond)."""
    if block_skipping not in BLOCK_SKIPPING_MODES:
        raise ValidationError(
            f"unknown block_skipping mode {block_skipping!r}",
            block_skipping=block_skipping, valid=BLOCK_SKIPPING_MODES,
        )
    if block_skipping == "off" or blocks is None or E == 0:
        return None
    nb = _active.n_edge_blocks(E)
    if nb <= 1 and block_skipping != "on":
        # nothing to skip on a 1-block index; 'on' still engages the active
        # kernel so small shapes exercise the real code path
        return None
    src_min, src_max = blocks
    zero = _IDENTITY[op]
    if isinstance(w, jax.core.Tracer):
        bi, na = _active.active_block_list(
            w, zero, jnp.asarray(src_min), jnp.asarray(src_max)
        )
        _obs_trace.annotate(skip_tier="traced", n_blocks=nb)
        return bi, na, ("cond" if block_skipping == "auto" else "static")
    support = np.asarray(w != zero)
    if support.ndim == 2:
        support = support.any(axis=0)
    bi, na, frac = _active.active_block_list_np(support, src_min, src_max)
    if block_skipping == "auto" and frac > _active.SKIP_BLOCK_FRACTION:
        _obs_trace.annotate(
            skip_tier="eager", skip_decision="scan", n_blocks=nb,
            active_blocks=int(na[0]), active_block_fraction=float(frac),
        )
        return None
    _obs_trace.annotate(
        skip_tier="eager", skip_decision="skip", n_blocks=nb,
        active_blocks=int(na[0]), active_block_fraction=float(frac),
    )
    return jnp.asarray(bi), jnp.asarray(na), "static"


def _skip_or_cond(plan, E: int, skip_fn, scan_fn):
    """Commit to the active kernel ('static') or build the runtime choice
    (traced 'auto'): lax.cond on the surviving-block count vs the
    SKIP_BLOCK_FRACTION threshold — both branches return identical values."""
    bi, na, mode = plan
    if mode == "static":
        return skip_fn(bi, na)
    thresh = max(1, int(_active.SKIP_BLOCK_FRACTION * _active.n_edge_blocks(E)))
    return jax.lax.cond(
        na[0] <= thresh, lambda: skip_fn(bi, na), scan_fn
    )


def bitunpack(packed, width: int, count: int, use_pallas: bool = True):
    if not use_pallas:
        return ref.bitunpack_ref(jnp.asarray(packed, jnp.uint32), width, count)
    return _bitunpack(jnp.asarray(packed, jnp.uint32), width, count, interpret=_interpret())


def fragment_spmv(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True,
                  blocks=None, block_skipping: str = "off"):
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if not use_pallas:
        return ref.fragment_spmv_ref(w, s, d, m, n_dst, op=op)
    _faults.fire("ops.fragment_spmv", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmv(w, s, d, m, n_dst, op=op, interpret=_interpret())
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmv_active(
            w, s, d, m, bi, na, n_dst, op=op, interpret=_interpret()
        ),
        scan,
    )


def fragment_spmm(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True,
                  blocks=None, block_skipping: str = "off"):
    """Batched multi-query hop: ``Y[b, dst] ⊕= W[b, src] ⊗ m`` with one edge
    stream serving all B frontier rows (see fragment_spmm.py). ``measures``
    may be [E] (shared — the fused-kernel case) or [B, E] (per-row, e.g. a
    seed-scalar-dependent measure expression): per-row streams have no
    single-pass formulation and always take the XLA fallback, a vmap'd
    segment-combine."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if m.ndim == 2 or not use_pallas:
        return ref.fragment_spmm_ref(w, s, d, m, n_dst, op=op)
    _faults.fire("ops.fragment_spmm", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmm(w, s, d, m, n_dst, op=op, interpret=_interpret())
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmm_active(
            w, s, d, m, bi, na, n_dst, op=op, interpret=_interpret()
        ),
        scan,
    )


def fragment_spmm_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True,
                         blocks=None, block_skipping: str = "off"):
    """Decode-fused batched hop: packed dst/measure word streams decode once
    per 4096-edge block in VMEM and serve all B frontier rows."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmm_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    _faults.fire("ops.fragment_spmm_packed", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmm_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmm_packed_active(
            w, s, d, m, md, bi, na, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
        ),
        scan,
    )


def fragment_spmv_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True,
                         blocks=None, block_skipping: str = "off"):
    """Decode-fused hop: ``dst``/``measure`` may be BCA word streams that are
    unpacked block-at-a-time inside the SpMV (see fragment_spmv_packed.py)."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmv_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    _faults.fire("ops.fragment_spmv_packed", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmv_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmv_packed_active(
            w, s, d, m, md, bi, na, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
        ),
        scan,
    )


# ---------------------------------------------------------------------------
# Pipelined 2-hop fused dispatch (kernels/fragment_spmv_fused.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class FusedHopOperands:
    """One hop's streams for the fused entries. The frontier is *not* here:
    hop1 reads the caller's ``weights``, hop2 reads the VMEM scratch. ``reach``
    (hop2 only) is the fuse-time block reachability matrix ``bool[nb1, nb2]``
    that derives hop2's active block list from hop1's."""

    src_ids: Any
    dst: Any
    measure: Any = None
    mdict: Any = None
    n_dst: int = 0
    dst_width: int = 0
    m_mode: str = "none"
    m_width: int = 0
    blocks: Any = None  # (src_min, src_max) | None
    reach: Any = None


def _coerce_hop(h: FusedHopOperands):
    s = jnp.asarray(h.src_ids, jnp.int32)
    d = jnp.asarray(h.dst, jnp.uint32 if h.dst_width else jnp.int32)
    m = h.measure
    if h.m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif h.m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    elif h.m_mode != "none":
        raise ValidationError(
            f"unknown measure mode {h.m_mode!r}", m_mode=h.m_mode,
        )
    md = jnp.asarray(h.mdict, jnp.float32) if h.m_mode == "dict" else None
    return s, d, m, md


def _fusion_unfusable(fusion: str, n_mid: int, batch: int) -> bool:
    if fusion not in FUSION_MODES:
        raise ValidationError(
            f"unknown fusion mode {fusion!r}", fusion=fusion, valid=FUSION_MODES,
        )
    if fusion == "off":
        return True
    if fusion == "on":
        return False
    return 4 * n_mid * max(batch, 1) > FUSED_VMEM_BUDGET_BYTES


def _full_blocks(nb: int):
    return jnp.arange(nb, dtype=jnp.int32), jnp.asarray([nb], jnp.int32)


def _np_block_list(flags: np.ndarray):
    """Bucketed fixed-capacity list from eager flags (active.py layout)."""
    act = np.flatnonzero(flags).astype(np.int32)
    nb = int(flags.shape[0])
    C = _active.bucket_capacity(int(act.shape[0]), nb)
    idx = np.full(C, act[-1] if act.size else 0, np.int32)
    idx[: act.shape[0]] = act
    return jnp.asarray(idx), jnp.asarray([act.shape[0]], dtype=jnp.int32)


def _fused_block_lists(w, op: str, h1, h2, E1: int, E2: int,
                       block_skipping: str):
    """The fused grid's two prefetched block lists. hop1's comes from the
    incoming frontier's support exactly as in the unfused active kernels;
    hop2's is derived WITHOUT reading the intermediate frontier, by OR-ing the
    reach-matrix rows of hop1's active blocks (conservative superset → results
    stay bit-identical). Skipping off/unavailable passes full arange lists —
    one kernel body serves every mode."""
    if block_skipping not in BLOCK_SKIPPING_MODES:
        raise ValidationError(
            f"unknown block_skipping mode {block_skipping!r}",
            block_skipping=block_skipping, valid=BLOCK_SKIPPING_MODES,
        )
    nb1 = _active.n_edge_blocks(E1)
    zero = _IDENTITY[op]
    skip1 = (
        block_skipping != "off" and h1.blocks is not None
        and not (nb1 <= 1 and block_skipping != "on")
    )
    traced = isinstance(w, jax.core.Tracer)
    flags1_t = flags1_np = None
    if not skip1:
        bi1, na1 = _full_blocks(nb1)
    elif traced:
        smin1, smax1 = h1.blocks
        flags1_t = _active.active_flags(
            _active.support_mask(w, zero), jnp.asarray(smin1), jnp.asarray(smax1)
        )
        bi1, na1 = _active.compact_blocks(flags1_t)
        _obs_trace.annotate(skip_tier="traced", n_blocks=nb1)
    else:
        smin1, smax1 = h1.blocks
        sup = np.asarray(_active.support_mask(w, zero)).astype(np.int64)
        cs = np.concatenate([np.zeros(1, np.int64), np.cumsum(sup)])
        flags1_np = cs[np.asarray(smax1) + 1] > cs[np.asarray(smin1)]
        frac = flags1_np.sum() / nb1
        if block_skipping == "auto" and frac > _active.SKIP_BLOCK_FRACTION:
            bi1, na1 = _full_blocks(nb1)
            flags1_np = None
            _obs_trace.annotate(skip_tier="eager", skip_decision="scan",
                                n_blocks=nb1)
        else:
            bi1, na1 = _np_block_list(flags1_np)
            _obs_trace.annotate(
                skip_tier="eager", skip_decision="skip", n_blocks=nb1,
                active_blocks=int(flags1_np.sum()),
                active_block_fraction=float(frac),
            )
    if h2 is None:
        return bi1, na1, None, None
    nb2 = _active.n_edge_blocks(E2)
    reach = h2.reach
    ok_reach = (
        reach is not None
        and tuple(np.asarray(reach).shape) == (nb1, nb2)
    )
    if ok_reach and flags1_t is not None:
        flags2 = jnp.any(jnp.asarray(reach, bool) & flags1_t[:, None], axis=0)
        bi2, na2 = _active.compact_blocks(flags2)
    elif ok_reach and flags1_np is not None:
        flags2 = np.asarray(reach, bool)[flags1_np].any(axis=0)
        bi2, na2 = _np_block_list(flags2)
    else:
        bi2, na2 = _full_blocks(nb2)
    return bi1, na1, bi2, na2


def _compose_unfused(packed_fn, weights, hop1, hop2, mid_mask,
                     mid_binarize: bool, op: str, use_pallas: bool,
                     block_skipping: str):
    """The member hops through the unfused kernels (fusion off / VMEM budget
    exceeded / empty relation) — the reference semantics the fused kernel must
    match bit-for-bit."""
    u = packed_fn(
        weights, hop1.src_ids, hop1.dst, hop1.measure, hop1.mdict,
        n_dst=hop1.n_dst, dst_width=hop1.dst_width, m_mode=hop1.m_mode,
        m_width=hop1.m_width, op=op, use_pallas=use_pallas,
        blocks=hop1.blocks, block_skipping=block_skipping,
    )
    if mid_mask is not None:
        keep = mid_mask[None, :] if u.ndim == 2 else mid_mask
        u = _fused_apply_mask(u, keep, op)
    if hop2 is None:
        return u
    if mid_binarize:
        u = _fused_binarize(u, op)
    return packed_fn(
        u, hop2.src_ids, hop2.dst, hop2.measure, hop2.mdict,
        n_dst=hop2.n_dst, dst_width=hop2.dst_width, m_mode=hop2.m_mode,
        m_width=hop2.m_width, op=op, use_pallas=use_pallas,
        blocks=hop2.blocks, block_skipping=block_skipping,
    )


def _fused_dispatch(batched: bool, weights, hop1, hop2, mid_mask, *, op,
                    mid_binarize, use_pallas, fusion, block_skipping):
    w = jnp.asarray(weights, jnp.float32)
    mm = jnp.asarray(mid_mask, jnp.float32) if mid_mask is not None else None
    E1 = hop1.src_ids.shape[0]
    E2 = hop2.src_ids.shape[0] if hop2 is not None else 0
    n_mid = hop1.n_dst
    n_dst = hop2.n_dst if hop2 is not None else hop1.n_dst
    batch = w.shape[0] if batched else 1
    packed_fn = fragment_spmm_packed if batched else fragment_spmv_packed
    if (
        not use_pallas
        or _fusion_unfusable(fusion, n_mid, batch)
        or E1 == 0
        or (hop2 is not None and E2 == 0)
    ):
        return _compose_unfused(
            packed_fn, w, hop1, hop2, mm, mid_binarize, op,
            use_pallas, block_skipping,
        )
    site = "ops.fragment_spmm_fused" if batched else "ops.fragment_spmv_fused"
    _faults.fire(site, op=op, n_dst=n_dst)
    s1, d1, m1, md1 = _coerce_hop(hop1)
    s2, d2, m2, md2 = _coerce_hop(hop2) if hop2 is not None else (None,) * 4
    # plan from the caller's original frontier: a numpy frontier then plans
    # entirely on the host, with no device round-trip for the support scan
    bi1, na1, bi2, na2 = _fused_block_lists(
        w if isinstance(w, jax.core.Tracer) else weights,
        op, hop1, hop2, E1, E2, block_skipping
    )
    _obs_trace.annotate(fused=True, fused_hops=2 if hop2 is not None else 1)
    kern = _fragment_spmm_fused if batched else _fragment_spmv_fused
    return kern(
        w, s1, d1, m1, md1, s2, d2, m2, md2, mm, bi1, na1, bi2, na2,
        n_mid=n_mid, n_dst=n_dst,
        dst1_width=hop1.dst_width, m1_mode=hop1.m_mode, m1_width=hop1.m_width,
        dst2_width=hop2.dst_width if hop2 is not None else 0,
        m2_mode=hop2.m_mode if hop2 is not None else "none",
        m2_width=hop2.m_width if hop2 is not None else 0,
        op=op, mid_binarize=mid_binarize and hop2 is not None,
        interpret=_interpret(),
    )


def fragment_spmv_fused(weights, hop1: FusedHopOperands,
                        hop2: FusedHopOperands | None = None, mid_mask=None,
                        *, op: str = "sum", mid_binarize: bool = False,
                        use_pallas: bool = True, fusion: str = "auto",
                        block_skipping: str = "off"):
    """Pipelined fused region: hop1 → in-register mask/binarize → hop2 in one
    kernel pass, the intermediate frontier resident in VMEM scratch
    (``hop2=None`` ⇒ degenerate 1-hop+filter region). Bit-identical to the
    unfused two-call composition on every op × encoding × skip mode."""
    return _fused_dispatch(
        False, weights, hop1, hop2, mid_mask, op=op,
        mid_binarize=mid_binarize, use_pallas=use_pallas, fusion=fusion,
        block_skipping=block_skipping,
    )


def fragment_spmm_fused(weights, hop1: FusedHopOperands,
                        hop2: FusedHopOperands | None = None, mid_mask=None,
                        *, op: str = "sum", mid_binarize: bool = False,
                        use_pallas: bool = True, fusion: str = "auto",
                        block_skipping: str = "off"):
    """Batched pipelined region: B queries share the single fused pass, the
    ``[B, n_mid]`` intermediate resident in VMEM scratch."""
    return _fused_dispatch(
        True, weights, hop1, hop2, mid_mask, op=op,
        mid_binarize=mid_binarize, use_pallas=use_pallas, fusion=fusion,
        block_skipping=block_skipping,
    )


def bitmap_and(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_ref(a, b)
    return _bitmap_and(a, b, interpret=_interpret())


def bitmap_and_popcount(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_popcount_ref(a, b)
    return _bitmap_and_popcount(a, b, interpret=_interpret())
