"""Fault-tolerant query lifecycle (DESIGN.md §Robustness).

``errors``    — the typed :class:`QueryError` taxonomy every layer raises.
``admission`` — pre-execute memory budgeting + the prepared-query LRU.
``runner``    — deadlines, retry/backoff, and the degradation ladder.
``faults``    — deterministic, seedable fault injection for chaos tests.
``scrub``     — background integrity scrubbing + heal-from-snapshot.
"""
from .admission import (  # noqa: F401
    AdmissionController,
    AdmissionDecision,
    MemoryBudget,
    PreparedCache,
    estimate_query_bytes,
)
from .errors import (  # noqa: F401
    DeadlineExceeded,
    ExecutionError,
    IntegrityError,
    ParseError,
    PlanError,
    QueryError,
    ResourceError,
    ValidationError,
    wrap_execution_error,
)
from .scrub import Scrubber  # noqa: F401
from .runner import (  # noqa: F401
    LADDER,
    Deadline,
    QueryOutcome,
    RetryPolicy,
    RobustPolicy,
    check_deadline,
    deadline_scope,
    run_batch_with_policy,
    run_with_policy,
    rung_fn,
)
