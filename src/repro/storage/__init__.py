"""Compressed device-resident column store (paper §5-6; DESIGN.md §Storage)."""
from .columns import (  # noqa: F401
    DenseColumn,
    DeviceColumn,
    DictPackedColumn,
    PackedColumn,
)
from .policy import (  # noqa: F401
    build_device_column,
    choose_device_encoding,
    column_uniques,
    device_space_report,
    resolve_device_encoding,
)
