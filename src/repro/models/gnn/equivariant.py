"""Equivariant machinery: real spherical harmonics, real Clebsch-Gordan
couplings, and real Wigner rotation matrices (Ivanic–Ruedenberg recurrence).

All coefficient tables are precomputed in numpy (complex arithmetic allowed at
build time); runtime work is pure-jnp einsums/vector ops over edges.

Conventions: real SH basis indexed m = -l..l with
  Y_{l,-|m|} ∝ sin(|m|φ), Y_{l,0}, Y_{l,|m|} ∝ cos(|m|φ),
Condon–Shortley included in the associated Legendre recurrence and cancelled in
the real combination (standard "real SH" normalization, orthonormal on S²).
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Real spherical harmonics (vectorized associated-Legendre recurrence)
# ---------------------------------------------------------------------------


def real_sph_harm(l_max: int, vecs, eps: float = 1e-12, xp=jnp):
    """Y[e, i] for unit(ish) vectors vecs [E, 3]; i enumerates (l, m) pairs with
    l = 0..l_max, m = -l..l (size (l_max+1)²). Orthonormal real SH."""
    r = xp.sqrt(xp.sum(vecs**2, axis=-1) + eps)
    x, y, z = vecs[:, 0] / r, vecs[:, 1] / r, vecs[:, 2] / r
    ct = z  # cosθ
    st = xp.sqrt(xp.clip(1.0 - ct**2, 0.0, 1.0))
    phi = xp.arctan2(y, x)

    # associated Legendre P_l^m(cosθ) with Condon-Shortley, m >= 0
    P: dict[tuple[int, int], object] = {(0, 0): xp.ones_like(ct)}
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * st * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * ct * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = (
                (2 * l - 1) * ct * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]
            ) / (l - m)

    cols = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = math.sqrt(
                (2 * l + 1) / (4 * math.pi)
                * math.factorial(l - am) / math.factorial(l + am)
            )
            if m == 0:
                cols.append(norm * P[(l, 0)])
            elif m > 0:
                cols.append(math.sqrt(2) * norm * P[(l, m)] * xp.cos(m * phi))
            else:
                cols.append(math.sqrt(2) * norm * P[(l, am)] * xp.sin(am * phi))
    return xp.stack(cols, axis=-1)


def irreps_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def l_slices(l_max: int) -> list[slice]:
    out, o = [], 0
    for l in range(l_max + 1):
        out.append(slice(o, o + 2 * l + 1))
        o += 2 * l + 1
    return out


# ---------------------------------------------------------------------------
# Clebsch-Gordan in the real basis
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """⟨l1 m1 l2 m2 | l3 m3⟩ via the Racah formula (exact Python ints)."""
    f = math.factorial
    C = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return C
    pref = (2 * l3 + 1) * f(l3 + l1 - l2) * f(l3 - l1 + l2) * f(l1 + l2 - l3) / f(l1 + l2 + l3 + 1)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) > l3:
                continue
            pre = math.sqrt(
                pref
                * f(l3 + m3) * f(l3 - m3)
                * f(l1 + m1) * f(l1 - m1)
                * f(l2 + m2) * f(l2 - m2)
            )
            s = 0.0
            kmin = max(0, l2 - l3 - m1, l1 - l3 + m2)
            kmax = min(l1 + l2 - l3, l1 - m1, l2 + m2)
            for k in range(kmin, kmax + 1):
                s += (-1) ** k / (
                    f(k) * f(l1 + l2 - l3 - k) * f(l1 - m1 - k)
                    * f(l2 + m2 - k) * f(l3 - l2 + m1 + k) * f(l3 - l1 - m2 + k)
                )
            C[m1 + l1, m2 + l2, m3 + l3] = pre * s
    return C


@lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """U with Y^complex_{l,m} = Σ_{m'} U[m, m'] Y^real_{l,m'} (both −l..l)."""
    U = np.zeros((2 * l + 1, 2 * l + 1), dtype=complex)
    s2 = 1 / math.sqrt(2)
    # m>0: Y_m = (-1)^m (Y^r_{|m|} + i Y^r_{-|m|})/√2 ; m<0: (Y^r_{|m|} − i Y^r_{-|m|})/√2
    for m in range(-l, l + 1):
        i = m + l
        if m == 0:
            U[i, l] = 1.0
        elif m > 0:
            U[i, l + m] = (-1) ** m * s2
            U[i, l - m] = 1j * (-1) ** m * s2
        else:
            U[i, l + abs(m)] = s2
            U[i, l - abs(m)] = -1j * s2
    return U


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Real-basis coupling C[m1, m2, m3]: (x ⊗ y)_{l3} = C · x_{l1} y_{l2} is
    equivariant for real-SH-basis irreps. None when the triangle rule fails."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    cg = _cg_complex(l1, l2, l3)
    U1, U2, U3 = _real_to_complex(l1), _real_to_complex(l2), _real_to_complex(l3)
    # C_real = U1† U2† CG U3 contracted appropriately (einsum over complex bases)
    C = np.einsum("abe,ai,bj,ek->ijk", cg.astype(complex), U1, U2, U3.conj())
    # result is purely real or purely imaginary depending on parity; take the
    # nonzero part and keep it real
    if np.abs(C.imag).max() > np.abs(C.real).max():
        C = C.imag
    else:
        C = C.real
    return np.ascontiguousarray(C)


# ---------------------------------------------------------------------------
# Real Wigner rotation matrices — exact sampling construction
# ---------------------------------------------------------------------------
#
# D^l(R) is defined by Y_l(R v) = D^l(R) · Y_l(v). With a fixed generic sample
# set {v_i} (precomputed, with the pseudo-inverse of A_l[i, m] = Y_l(v_i)_m),
# evaluating Y at the rotated samples gives D^l = (A_l⁺ B_l)ᵀ exactly, fully
# vectorized over edges — no fragile recurrences, validated by the
# rotation-equivariance property tests.


@lru_cache(maxsize=None)
def _wigner_samples(l_max: int) -> tuple[np.ndarray, list[np.ndarray]]:
    rng = np.random.default_rng(12345)
    n = 2 * (l_max + 1) ** 2  # oversample ×2 for conditioning
    v = rng.normal(size=(n, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = real_sph_harm(l_max, v, xp=np)  # [n, dim] (pure numpy: safe inside traces)
    pinvs = []
    for l, sl in enumerate(l_slices(l_max)):
        A = Y[:, sl]  # [n, 2l+1]
        pinvs.append(np.linalg.pinv(A))  # [2l+1, n]
    return v, pinvs


def wigner_d_real(l_max: int, rot: jnp.ndarray) -> list[jnp.ndarray]:
    """Real-SH rotation matrices D^l[..., 2l+1, 2l+1], l = 0..l_max, for
    rotations ``rot`` [..., 3, 3] acting on column vectors."""
    v, pinvs = _wigner_samples(l_max)
    vj = jnp.asarray(v, rot.dtype)  # [n, 3]
    rv = jnp.einsum("...ij,nj->...ni", rot, vj)  # rotated samples
    B = real_sph_harm(l_max, rv.reshape(-1, 3)).reshape(rot.shape[:-2] + (v.shape[0], -1))
    out = []
    for l, sl in enumerate(l_slices(l_max)):
        Bl = B[..., sl]  # [..., n, 2l+1]
        Dt = jnp.einsum("mn,...nk->...mk", jnp.asarray(pinvs[l], rot.dtype), Bl)
        out.append(jnp.swapaxes(Dt, -1, -2))  # D^l = (A⁺B)ᵀ
    return out


def rotation_to_edge_frame(vecs: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Rotation matrices [E,3,3] mapping each edge direction to +z (the eSCN
    edge-aligned frame)."""
    r = jnp.sqrt(jnp.sum(vecs**2, axis=-1, keepdims=True) + eps)
    n = vecs / r
    z = n
    # pick a helper axis not parallel to n
    helper = jnp.where(
        (jnp.abs(n[:, 2:3]) < 0.9), jnp.asarray([0.0, 0.0, 1.0]), jnp.asarray([1.0, 0.0, 0.0])
    )
    xaxis = jnp.cross(helper, z)
    xaxis = xaxis / jnp.sqrt(jnp.sum(xaxis**2, -1, keepdims=True) + eps)
    yaxis = jnp.cross(z, xaxis)
    # rows = new basis vectors → R @ n = e_z
    return jnp.stack([xaxis, yaxis, z], axis=-2)
