"""GQ-Fast core: fragment storage, codecs, RQNA algebra, SQL, query execution."""
from .engine import GQFastDatabase, GQFastEngine, PreparedQuery  # noqa: F401
from .schema import EntityTable, RelationshipTable, Schema  # noqa: F401
