"""Checkpoint manager: atomic, retained, mesh-elastic.

Layout:  <dir>/step_<n>/arrays.npz + meta.json — published through the shared
crash-safe writer (``ckpt/atomic.py``: tmp-dir + fsync + os.rename + parent
fsync, the same pattern the database snapshotter uses).

Restore resharding: checkpoints store *logical* arrays; ``restore`` device_puts
them under whatever mesh/shardings the restarted job passes — a job restarted
on a different mesh shape (elastic scaling, failed-node replacement) resumes
from the same logical state. Retention keeps the newest k checkpoints.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

import jax
import numpy as np

from .atomic import list_stamped, publish_dir, retain_stamped, stamped_name

SEP = "/"
STEP_PREFIX = "step_"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None) -> str:
        flat = _flatten(tree)
        treedef = jax.tree_util.tree_structure(tree)
        final = os.path.join(self.dir, stamped_name(STEP_PREFIX, step))

        def write(tmp: str) -> None:
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            meta = {
                "step": step,
                "treedef": str(treedef),
                "keys": sorted(flat),
                "time": time.time(),
                **(extra_meta or {}),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)

        publish_dir(final, write, tmp_prefix=".tmp_ckpt_")
        self._retain()
        return final

    def _retain(self) -> None:
        retain_stamped(self.dir, STEP_PREFIX, self.keep)

    def list_steps(self) -> list[int]:
        return list_stamped(self.dir, STEP_PREFIX)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of NamedShardings
        for elastic placement on the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, stamped_name(STEP_PREFIX, step))
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys = [SEP.join(_path_str(p) for p in path_) for path_, _ in leaves_t]
        arrays = [data[k] for k in keys]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: x is None or hasattr(x, "spec")
            )
            arrays = [
                jax.device_put(a, s) if s is not None else jax.device_put(a)
                for a, s in zip(arrays, sh_leaves)
            ]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), arrays
        )
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return tree, meta
