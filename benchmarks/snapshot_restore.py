"""Snapshot/restore durability costs (BENCH_snapshot.json).

Measures the three legs of the durable-lifecycle story (DESIGN.md
§Durability): cold build from the raw schema, checksummed snapshot to disk,
and verified restore from that snapshot — for both dense and packed device
storage. The headline metric is ``restore_speedup`` (build_ms / restore_ms):
restore skips the entire encode pipeline (columns round-trip as stored
encoded bytes) and should beat a cold build despite paying full CRC
verification on every array. Also times one synchronous scrub pass over the
restored database — the pre-serving integrity gate's cost.

Acceptance gate (CI fast lane): restore must be bit-identical to the built
database on a reference query for every encoding, and verified restore must
not be slower than the cold build — the suite raises (→ red CI) otherwise.
"""
from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG
from repro.robust.scrub import Scrubber
from repro.storage import restore_db, snapshot_db

from .common import emit, timeit

SQL = SG.QUERY_SD


def run() -> None:
    schema = SG.make_pubmed(n_docs=8_000, n_terms=400, n_authors=2_000, seed=21)
    failures = []
    for enc in ("dense", "packed"):
        t_build = timeit(
            lambda: GQFastDatabase(schema, account_space=False,
                                   device_encodings=enc), iters=1)
        db = GQFastDatabase(schema, account_space=False, device_encodings=enc)
        ref = np.asarray(GQFastEngine(db).prepare(SQL)(d0=17))

        tmp = tempfile.mkdtemp(prefix=f"bench_snap_{enc}_")
        try:
            t_snap = timeit(lambda: snapshot_db(db, tmp), iters=1)
            snap_bytes = sum(
                os.path.getsize(os.path.join(r, f))
                for r, _, fs in os.walk(tmp) for f in fs
            )
            t_restore = timeit(lambda: restore_db(tmp, generation=1), iters=1)
            db2 = restore_db(tmp, generation=1)
            got = np.asarray(GQFastEngine(db2).prepare(SQL)(d0=17))
            identical = bool(np.array_equal(got, ref))
            t_scrub = timeit(
                lambda: Scrubber(db2, snapshot_dir=tmp).scrub_full(), iters=1)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

        speedup = t_build / t_restore
        emit(f"snapshot/{enc}/build", t_build * 1e6, f"build_ms={t_build*1e3:.0f}")
        emit(
            f"snapshot/{enc}/snapshot", t_snap * 1e6,
            f"snapshot_ms={t_snap*1e3:.0f} mb={snap_bytes/1e6:.1f}",
            snapshot_bytes=snap_bytes,
        )
        emit(
            f"snapshot/{enc}/restore", t_restore * 1e6,
            f"restore_ms={t_restore*1e3:.0f} speedup={speedup:.2f} "
            f"bit_identical={identical}",
            restore_speedup=round(speedup, 2), bit_identical=identical,
        )
        emit(f"snapshot/{enc}/scrub_pass", t_scrub * 1e6,
             f"scrub_ms={t_scrub*1e3:.0f}")
        if not identical:
            failures.append(f"{enc}: restored db not bit-identical")
        if speedup < 1.0:
            failures.append(
                f"{enc}: verified restore slower than cold build "
                f"({t_restore*1e3:.0f}ms vs {t_build*1e3:.0f}ms)"
            )
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    run()
