"""Frontier-selectivity sweep: block-skipping speedup vs full scan.

GQ-Fast's core claim (paper §4-5) is that selective relationship queries touch
only the reachable index fragments. This suite measures how well the
active-block machinery (kernels/active.py + the scalar-prefetch kernels)
restores that property for the streaming SpMV/SpMM formulation: one hop over a
fixed CSR index, seed selectivity swept 10⁻⁴ … 1, ``block_skipping='auto'``
timed against the always-scan baseline. The frontier support is a contiguous
source range — the shape real seed-reachable fragments have in CSR order
(sorted by src), where block-granular skipping pays off; a support scattered
uniformly over the whole domain touches every block and 'auto' correctly
falls back to the scan (that regime is the s=1.0 row).

Emitted per selectivity: both times, the speedup, the surviving-block
fraction, and ``bit_identical`` (skip vs scan must agree exactly — skipped
blocks contribute the ⊕-identity). Hard gates (CI fast lane goes red on
violation): bit_identical everywhere, ≥``MIN_SPEEDUP_AT_1PCT``× at 1%
selectivity, and ≤``MAX_OVERHEAD_AT_FULL``× at 100% (the heuristic must cost
~nothing when it decides not to skip).
"""
from __future__ import annotations

import numpy as np

from .common import emit, timeit

SELECTIVITIES = (1e-4, 1e-3, 1e-2, 1e-1, 1.0)
#: CI gate on the smoke shape — the acceptance target (≥5×) is what the full
#: shape actually delivers (~30× here); the gate stays loose so a slow runner
#: doesn't flake the lane.
MIN_SPEEDUP_AT_1PCT = 2.0
MAX_OVERHEAD_AT_FULL = 1.1

N_SRC, DEG, N_DST = 65_536, 16, 8_192  # E = 1,048,576 → 256 edge blocks
BATCH = 8


def _dataset(seed: int = 7):
    rng = np.random.default_rng(seed)
    E = N_SRC * DEG
    src = np.repeat(np.arange(N_SRC, dtype=np.int32), DEG)  # CSR order
    dst = rng.integers(0, N_DST, E).astype(np.int32)
    m = rng.random(E).astype(np.float32)
    return src, dst, m


def _frontier(selectivity: float) -> np.ndarray:
    """Contiguous support of ⌈s·n_src⌉ sources (seed-reachable fragments are
    contiguous runs of the src-sorted edge arrays)."""
    k = max(1, round(selectivity * N_SRC))
    w = np.zeros(N_SRC, np.float32)
    w[:k] = 1.0
    return w


def run() -> None:
    from repro.kernels import active, ops

    src, dst, m = _dataset()
    blocks = active.block_ranges(src)
    failures: list[str] = []

    def check(tag: str, scan_fn, skip_fn, selectivity: float, frac: float):
        ref = np.asarray(scan_fn())
        got = np.asarray(skip_fn())
        bit = bool(np.array_equal(ref, got))
        t_scan = timeit(lambda: scan_fn().block_until_ready())
        t_skip = timeit(lambda: skip_fn().block_until_ready())
        speedup = t_scan / t_skip
        emit(
            f"selectivity/{tag}/s={selectivity:g}",
            t_skip * 1e6,
            f"speedup={speedup:.2f}x",
            selectivity=selectivity,
            scan_us=round(t_scan * 1e6, 1),
            skip_us=round(t_skip * 1e6, 1),
            speedup=round(speedup, 2),
            active_fraction=round(frac, 4),
            bit_identical=bit,
        )
        if not bit:
            failures.append(f"{tag} s={selectivity:g}: skip != scan")
        return speedup

    for s in SELECTIVITIES:
        w = _frontier(s)
        _, _, frac = active.active_block_list_np(w != 0, *blocks)
        sp = check(
            "spmv",
            lambda: ops.fragment_spmv(w, src, dst, m, N_DST, op="sum",
                                      block_skipping="off"),
            lambda: ops.fragment_spmv(w, src, dst, m, N_DST, op="sum",
                                      blocks=blocks, block_skipping="auto"),
            s, frac,
        )
        if s == 1e-2 and sp < MIN_SPEEDUP_AT_1PCT:
            failures.append(
                f"spmv speedup {sp:.2f}x at 1% selectivity "
                f"(gate {MIN_SPEEDUP_AT_1PCT}x)"
            )
        if s == 1.0 and sp < 1.0 / MAX_OVERHEAD_AT_FULL:
            failures.append(
                f"spmv 'auto' overhead {1.0 / sp:.2f}x at full selectivity "
                f"(gate {MAX_OVERHEAD_AT_FULL}x)"
            )

    # decode-fused path: packed dst (13-bit) + dict-packed measure
    from repro.core.fragments import _pack_words

    dw = max(1, int(N_DST - 1).bit_length())
    words_dst = _pack_words(dst, dw)
    n_uniq = 64
    rng = np.random.default_rng(17)
    midx = rng.integers(0, n_uniq, src.shape[0]).astype(np.int32)
    mw = max(1, int(n_uniq - 1).bit_length())
    words_m = _pack_words(midx, mw)
    mdict = rng.random(n_uniq).astype(np.float32)
    w = _frontier(1e-2)
    _, _, frac = active.active_block_list_np(w != 0, *blocks)
    check(
        "spmv_packed",
        lambda: ops.fragment_spmv_packed(
            w, src, words_dst, words_m, mdict, n_dst=N_DST, dst_width=dw,
            m_mode="dict", m_width=mw, op="sum", block_skipping="off"),
        lambda: ops.fragment_spmv_packed(
            w, src, words_dst, words_m, mdict, n_dst=N_DST, dst_width=dw,
            m_mode="dict", m_width=mw, op="sum",
            blocks=blocks, block_skipping="auto"),
        1e-2, frac,
    )

    # batched SpMM: B queries, block list = union of per-query supports
    W = np.stack([np.roll(_frontier(1e-2), i * N_SRC // 64) for i in range(BATCH)])
    sup = (W != 0).any(axis=0)
    _, _, frac = active.active_block_list_np(sup, *blocks)
    check(
        "spmm",
        lambda: ops.fragment_spmm(W, src, dst, m, N_DST, op="sum",
                                  block_skipping="off"),
        lambda: ops.fragment_spmm(W, src, dst, m, N_DST, op="sum",
                                  blocks=blocks, block_skipping="auto"),
        1e-2, frac,
    )

    if failures:
        raise RuntimeError("selectivity gates failed: " + "; ".join(failures))
