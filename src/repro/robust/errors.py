"""Typed error taxonomy for the query lifecycle (DESIGN.md §Robustness).

Every failure the engine can produce surfaces as a :class:`QueryError`
subclass carrying a machine-readable ``code``, a ``retryable`` flag (may a
caller expect a different outcome from simply trying again?), and a free-form
``context`` dict (query text / token position / op id / strategy / byte
estimates — whatever the raise site knows). ``to_dict()`` is the wire form
the serve loop returns for failed requests.

Compatibility contract: each subclass *also* inherits the builtin exception
class the pre-taxonomy code raised (``ParseError`` is a ``SyntaxError``,
``PlanError``/``ValidationError`` are ``ValueError``s, …) so callers written
against the old surface — including the existing test suite — keep working.
The hierarchy:

    QueryError
    ├── ParseError         (SyntaxError)   code=PARSE        retryable=False
    ├── PlanError          (ValueError)    code=PLAN         retryable=False
    ├── ValidationError    (ValueError,
    │                       TypeError)     code=VALIDATION   retryable=False
    ├── ResourceError      (RuntimeError)  code=RESOURCE     retryable=False
    ├── DeadlineExceeded   (TimeoutError)  code=DEADLINE     retryable=True
    ├── ExecutionError     (RuntimeError)  code=EXECUTION    retryable=True
    └── IntegrityError     (RuntimeError)  code=INTEGRITY    retryable=False

``retryable`` defaults are per-class but overridable per-raise (e.g. an
injected transient kernel fault is a retryable ExecutionError, a shape
mismatch inside the same class is not). This module is dependency-free —
anything in the repo may import it without cycles.
"""
from __future__ import annotations

from typing import Any


class QueryError(Exception):
    """Base of the taxonomy. ``code`` is stable and machine-readable;
    ``context`` carries raise-site details; ``retryable`` drives the runner's
    backoff policy (robust/runner.py)."""

    code: str = "QUERY"
    default_retryable: bool = False

    def __init__(self, message: str, *, code: str | None = None,
                 retryable: bool | None = None, **context: Any):
        super().__init__(message)
        self.message = message
        if code is not None:
            self.code = code
        self.retryable = (
            self.default_retryable if retryable is None else bool(retryable)
        )
        self.context: dict[str, Any] = dict(context)

    def with_context(self, **kv: Any) -> "QueryError":
        """Attach context discovered above the raise site (e.g. the engine
        adds the query text to a planner error) without clobbering what the
        raise site already recorded. Returns self for re-raise chaining."""
        for k, v in kv.items():
            self.context.setdefault(k, v)
        return self

    def to_dict(self) -> dict[str, Any]:
        """Wire form for structured error responses (launch/serve.py)."""
        return {
            "error": type(self).__name__,
            "code": self.code,
            "message": self.message,
            "retryable": self.retryable,
            "context": {
                k: (v if isinstance(v, (int, float, str, bool, type(None))) else str(v))
                for k, v in self.context.items()
            },
        }

    def __str__(self) -> str:
        if not self.context:
            return self.message
        ctx = ", ".join(f"{k}={v!r}" for k, v in self.context.items())
        return f"{self.message} [{ctx}]"


class ParseError(QueryError, SyntaxError):
    """SQL text rejected by the tokenizer/parser. Context: ``position``
    (character offset), ``near`` (the offending text), ``query``."""

    code = "PARSE"


class PlanError(QueryError, ValueError):
    """Query parsed but the normalizer/lowering rejected it: outside the
    relationship-query class, unknown table/column, unresolvable ref."""

    code = "PLAN"


class ValidationError(QueryError, ValueError, TypeError):
    """Bad execution-time inputs: missing/extra/ragged parameters, unknown
    knob values. Inherits both ValueError and TypeError because the
    pre-taxonomy surface raised either depending on the site."""

    code = "VALIDATION"


class ResourceError(QueryError, RuntimeError):
    """Admission control rejection or resource exhaustion: the query's
    predicted (or actual) footprint exceeds the configured budget. Context:
    ``predicted_bytes``, ``limit_bytes``, ``batch``."""

    code = "RESOURCE"


class DeadlineExceeded(QueryError, TimeoutError):
    """The per-query deadline expired. Context: ``deadline_ms``,
    ``elapsed_ms``, ``where`` (which lifecycle checkpoint tripped).
    Retryable by default: the same query may finish under a fresh deadline
    on a less loaded system or a cheaper ladder rung."""

    code = "DEADLINE"
    default_retryable = True


class ExecutionError(QueryError, RuntimeError):
    """Failure inside compiled execution or kernel dispatch. Context:
    ``op``, ``strategy``, ``site``. Retryable by default — transient device
    failures are this class's main production occupant; wrap-sites that know
    the failure is deterministic pass ``retryable=False``."""

    code = "EXECUTION"
    default_retryable = True


class IntegrityError(QueryError, RuntimeError):
    """Checksum mismatch on durable or device-resident data: a snapshot file
    whose bytes no longer hash to the manifest entry, a device column whose
    decoded view disagrees with its recorded digest, or a read of a
    quarantined column. Never retryable — retrying a read of corrupted data
    cannot yield a different answer; the remedy is restore/heal (the
    scrubber's quarantine → reload-from-snapshot → re-verify cycle), not
    another attempt. Context: ``table``/``key``/``column`` naming the
    offending column (or ``path``/``array`` for snapshot files),
    ``expected_crc``, ``actual_crc``, ``generation``."""

    code = "INTEGRITY"


def wrap_execution_error(exc: BaseException, **context: Any) -> QueryError:
    """Normalize an arbitrary exception escaping the execute path: QueryErrors
    pass through (context merged), anything else becomes a non-retryable
    ExecutionError chained to the original."""
    if isinstance(exc, QueryError):
        return exc.with_context(**context)
    err = ExecutionError(
        f"{type(exc).__name__}: {exc}", retryable=False, **context
    )
    err.__cause__ = exc
    return err
