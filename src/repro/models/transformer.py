"""Decoder-only LM family: dense (CodeQwen/Qwen2.5/Llama-3) and MoE
(Arctic-style dense+MoE parallel residual, OLMoE top-k) in one config space.

Production posture:
  * scan-over-layers with stacked weights (+ optional remat) — small HLO, fast
    compiles at 512 devices, per-layer grain for XLA collective overlap;
  * chunked online-softmax attention (flash-style in pure JAX) bounds activation
    memory for 32k prefill;
  * GQA without materializing repeated KV heads;
  * MoE dispatch is scatter-based (positions from a cumsum over the token→expert
    one-hot [T,E]) — never materializes a [T,E,C] mask. The dispatch itself is a
    relationship-query γ over token→expert edges (DESIGN.md §5);
  * every major activation carries a ``shard_hint`` so the same code lowers on
    1 device and on the (pod, data, model) production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .common import apply_rope, cross_entropy_loss, dense_init, rms_norm, shard_hint

BATCH = ("pod", "data")  # logical batch sharding axes


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 2048
    tie_embeddings: bool = False
    seq_shard: bool = False  # sequence-parallel residual stream (Megatron-SP)

    def pad_heads(self, tp: int) -> "TransformerConfig":
        """Pad q-head count up to a multiple of tp (production TP divisibility;
        padded heads have zero-init output rows — a no-op at init)."""
        h = -(-self.n_heads // tp) * tp
        return dataclasses.replace(self, n_heads=h) if h != self.n_heads else self

    @property
    def n_rep(self) -> int:
        return self.n_heads // self.n_kv_heads if self.n_heads % self.n_kv_heads == 0 else 0

    def param_count(self) -> int:
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = 3 * d * f if self.moe is None or self.moe.dense_residual else 0
        if self.moe is not None:
            ffn += self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k experts only)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = 3 * d * f if self.moe is None or self.moe.dense_residual else 0
        if self.moe is not None:
            ffn += self.moe.top_k * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    L, d, hd = cfg.n_layers, cfg.d_model, cfg.d_head
    H, Hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ks = jax.random.split(key, 16)
    pt = cfg.param_dtype

    def di(k, shape, in_axis=-2):
        return dense_init(k, shape, in_axis, pt)

    layer = {
        "ln1": jnp.ones((L, d), pt),
        "ln2": jnp.ones((L, d), pt),
        "wq": di(ks[0], (L, d, H, hd), -3),
        "wk": di(ks[1], (L, d, Hkv, hd), -3),
        "wv": di(ks[2], (L, d, Hkv, hd), -3),
        "wo": di(ks[3], (L, H, hd, d), -2),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((L, H, hd), pt)
        layer["bk"] = jnp.zeros((L, Hkv, hd), pt)
        layer["bv"] = jnp.zeros((L, Hkv, hd), pt)
    if cfg.moe is None or cfg.moe.dense_residual:
        layer["w_gate"] = di(ks[4], (L, d, f))
        layer["w_up"] = di(ks[5], (L, d, f))
        layer["w_down"] = di(ks[6], (L, f, d))
    if cfg.moe is not None:
        m = cfg.moe
        layer["router"] = di(ks[7], (L, d, m.n_experts))
        layer["e_gate"] = di(ks[8], (L, m.n_experts, d, m.d_ff_expert))
        layer["e_up"] = di(ks[9], (L, m.n_experts, d, m.d_ff_expert))
        layer["e_down"] = di(ks[10], (L, m.n_experts, m.d_ff_expert, d))
    params = {
        "embed": di(ks[11], (cfg.vocab, d), -1),
        "layers": layer,
        "ln_f": jnp.ones((d,), pt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = di(ks[12], (d, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q [B,Sq,H,hd] × k [B,Sk,Hkv,hd] → [B,Hkv,G,Sq,Sk] without repeating K."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    return jnp.einsum("bsKgh,btKh->bKgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def chunked_attention(
    q: jnp.ndarray,  # [B,Sq,H,hd]
    k: jnp.ndarray,  # [B,Sk,Hkv,hd]
    v: jnp.ndarray,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,  # absolute position of q[0] (decode/prefill)
    kv_valid: jnp.ndarray | int | None = None,  # number of valid kv positions
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (flash-style, pure JAX): memory
    O(Sq · kv_chunk) instead of O(Sq · Sk)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kv_chunk = min(kv_chunk, Sk)
    n_chunks = -(-Sk // kv_chunk)
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)

    def step(carry, chunk):
        m, l, acc, ci = carry
        kch, vch = chunk
        s = _gqa_scores(q, kch).astype(jnp.float32)  # [B,Hkv,G,Sq,C]
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((Sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_valid is not None:
            mask &= kv_pos[None, :] < jnp.asarray(kv_valid)
        mask &= kv_pos[None, :] < Sk  # chunk padding
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bKgsc,bcKh->bKgsh", p.astype(q.dtype), vch).astype(jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new, ci + 1), None

    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_ffn(lp: dict, x: jnp.ndarray, cfg: TransformerConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [T, d] flattened tokens → (y [T, d], aux_loss scalar)."""
    m = cfg.moe
    T, d = x.shape
    E, K = m.n_experts, m.top_k
    logits = (x.astype(jnp.float32) @ lp["router"].astype(jnp.float32))  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)  # [T,K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss
    me = probs.mean(0)
    ce = jnp.zeros(E).at[topi.reshape(-1)].add(1.0) / (T * K)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    C = max(8, int(-(-T * K * m.capacity_factor // E)))  # capacity per expert
    flat_e = topi.reshape(-1)  # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*K,E]
    pos = (jnp.cumsum(onehot, axis=0) - onehot)  # positions before this entry
    pos_flat = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*K]
    keep = pos_flat < C
    slot = jnp.where(keep, pos_flat, C)  # dropped tokens → overflow slot C

    xk = shard_hint(jnp.repeat(x, K, axis=0), BATCH, None)  # per-(t,k) tokens
    zbuf = shard_hint(jnp.zeros((E, C + 1, d), x.dtype), "model", None, None)
    buf = zbuf.at[flat_e, slot].set(xk)
    buf = shard_hint(buf[:, :C], "model", None, None)  # [E,C,d]

    # compute follows the weight sharding: E on 'model', ffn width on 'data' —
    # gate/up are local; down contracts the sharded width (psum over 'data')
    g = jnp.einsum("ecd,edf->ecf", buf, lp["e_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, lp["e_up"].astype(x.dtype))
    h = shard_hint(jax.nn.silu(g) * u, "model", None, "data")
    y_e = jnp.einsum("ecf,efd->ecd", h, lp["e_down"].astype(x.dtype))
    y_e = shard_hint(y_e, "model", None, None)
    y_e = jnp.concatenate([y_e, jnp.zeros((E, 1, d), x.dtype)], axis=1)  # overflow→0

    gathered = shard_hint(y_e[flat_e, slot], BATCH, None)  # [T*K, d]
    wts = (topw.reshape(-1) * keep).astype(x.dtype)
    y = (gathered * wts[:, None]).reshape(T, K, d).sum(axis=1)
    return y, aux


# ---------------------------------------------------------------------------
# Blocks / forward
# ---------------------------------------------------------------------------


def _attn(lp, x, cfg: TransformerConfig, positions, kv_cache=None, kv_valid=None):
    B, S, d = x.shape
    cd = cfg.compute_dtype
    xn = rms_norm(x, lp["ln1"], cfg.norm_eps).astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", xn, lp["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xn, lp["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xn, lp["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cd)
        k = k + lp["bk"].astype(cd)
        v = v + lp["bv"].astype(cd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, BATCH, None, "model", None)
    k = shard_hint(k, BATCH, None, None, None)

    if kv_cache is not None:
        ck, cv, pos0 = kv_cache  # [B,Smax,Hkv,hd] ×2, scalar write offset
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos0, 0, 0))
        attn_out = chunked_attention(
            q, ck, cv, causal=True, q_offset=pos0,
            kv_valid=kv_valid, kv_chunk=cfg.attn_kv_chunk,
        )
        new_cache = (ck, cv)
    else:
        attn_out = chunked_attention(q, k, v, causal=True, kv_chunk=cfg.attn_kv_chunk)
        new_cache = (k, v)
    out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["wo"].astype(cd))
    return shard_hint(out, BATCH, None, None), new_cache


def _ffn(lp, x, cfg: TransformerConfig):
    cd = cfg.compute_dtype
    xn = rms_norm(x, lp["ln2"], cfg.norm_eps).astype(cd)
    B, S, d = xn.shape
    aux = jnp.float32(0)
    y = jnp.zeros_like(xn)
    if cfg.moe is None or cfg.moe.dense_residual:
        g = jnp.einsum("bsd,df->bsf", xn, lp["w_gate"].astype(cd))
        u = jnp.einsum("bsd,df->bsf", xn, lp["w_up"].astype(cd))
        h = jax.nn.silu(g) * u
        h = shard_hint(h, BATCH, None, "model")
        y = y + jnp.einsum("bsf,fd->bsd", h, lp["w_down"].astype(cd))
    if cfg.moe is not None:
        ym, aux = moe_ffn(lp, xn.reshape(B * S, d), cfg)
        y = y + ym.reshape(B, S, d)
    return shard_hint(y, BATCH, None, None), aux


def _layer(cfg: TransformerConfig, x, lp, positions, kv_cache=None, kv_valid=None):
    a, cache = _attn(lp, x, cfg, positions, kv_cache, kv_valid)
    x = x + a.astype(x.dtype)
    f, aux = _ffn(lp, x, cfg)
    x = x + f.astype(x.dtype)
    return x, cache, aux


def forward(params: dict, tokens: jnp.ndarray, cfg: TransformerConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward (no cache); returns (logits, moe_aux)."""
    B, S = tokens.shape
    cd = cfg.compute_dtype
    seq_ax = "model" if cfg.seq_shard else None
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = shard_hint(x, BATCH, seq_ax, None)
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        out, _, aux = _layer(cfg, x, lp, positions)
        # sequence-parallel residual stream: the scan carry (= the tensor remat
        # saves per layer) is sharded over 'model' on the sequence dim, cutting
        # saved-activation HBM by tp× (Megatron-SP); attention/FFN internals
        # re-gather as needed (XLA inserts ag/rs — counted in the roofline).
        out = shard_hint(out, BATCH, seq_ax, None)
        return out, aux

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps).astype(cd)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cd))
    return shard_hint(logits, BATCH, None, "model"), auxs.sum()


def loss_fn(params, batch, cfg: TransformerConfig):
    logits, aux = forward(params, batch["tokens"], cfg)
    loss = cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:])
    return loss + aux, {"loss": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, cfg.compute_dtype),
        "v": jnp.zeros(shape, cfg.compute_dtype),
    }


def prefill(params, tokens, cfg: TransformerConfig, max_seq: int):
    """Run the prompt; returns (last-position logits, filled cache, length)."""
    B, S = tokens.shape
    cd = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    positions = jnp.arange(S)[None, :]
    cache = init_kv_cache(cfg, B, max_seq)

    def body(x, inp):
        lp, ck, cv = inp
        out, (k, v), _ = _layer(cfg, x, lp, positions)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
        return out, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps).astype(cd)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cd))
    return logits, {"k": ck, "v": cv}, S


def decode_step(params, cache: dict, tokens: jnp.ndarray, pos: jnp.ndarray, cfg: TransformerConfig):
    """One decode step: tokens [B] at absolute position ``pos`` (scalar int32);
    attends over cache[:pos+1]. Returns (logits [B,V], updated cache)."""
    B = tokens.shape[0]
    cd = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(cd)
    positions = jnp.full((B, 1), pos, jnp.int32)

    def body(x, inp):
        lp, ck, cv = inp
        out, (nk, nv), _ = _layer(
            cfg, x, lp, positions, kv_cache=(ck, cv, pos), kv_valid=pos + 1
        )
        return out, (nk, nv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps).astype(cd)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], head.astype(cd))
    return logits, {"k": ck, "v": cv}
