"""Shared block geometry for the edge-streaming kernels (single source of truth).

Every fragment kernel — the SpMV pair (:mod:`.fragment_spmv`,
:mod:`.fragment_spmv_packed`) and the batched SpMM pair
(:mod:`.fragment_spmm`) — streams the edge arrays through VMEM in blocks of
``EDGE_BLOCK`` edges per grid step. The value is load-bearing for the packed
variants: EDGE_BLOCK = 4096 = 4·1024 values, and 1024·width ≡ 0 (mod 32) for
every width 1–32, so each block starts and ends word-aligned in the BCA uint32
word stream and the packed input block is exactly
``(EDGE_BLOCK/GROUP, width)`` words — a static BlockSpec, no halo. Changing it
to anything that is not a multiple of 1024 breaks that alignment, which is why
the constant lives here and nowhere else.
"""
from __future__ import annotations

EDGE_BLOCK = 4096  # edges per grid step; must stay a multiple of 1024

#: VMEM budget for the fused 2-hop kernels' resident intermediate frontier
#: (:mod:`.fragment_spmv_fused`). The fused kernel keeps the full ``[n_mid]``
#: (or ``[B, n_mid]``) f32 accumulator in a VMEM scratch buffer for the whole
#: grid; ``fusion="auto"`` falls back to the unfused two-kernel path when
#: ``4 · n_mid · B`` exceeds this. 8 MiB leaves headroom for the edge-block
#: operands and the output block on a 16 MiB-VMEM TPU core.
FUSED_VMEM_BUDGET_BYTES = 8 * 2**20
