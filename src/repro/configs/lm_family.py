"""LM-family arch configs: one class covers the five assigned transformers.

Shapes (per assignment): train_4k (train_step), prefill_32k (prefill),
decode_32k (serve_step: one token against a 32k KV cache), long_500k (skipped:
all five assigned LM archs are pure full attention — DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..dist.sharding import (
    kv_cache_shardings,
    lm_batch_shardings,
    lm_state_shardings,
    named,
)
from ..models import transformer as T
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .base import ArchConfig, Cell

LM_SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train", micro=8),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


class LMArch(ArchConfig):
    kind = "lm"
    shape_ids = list(LM_SHAPES)

    def __init__(self, arch_id: str, full: T.TransformerConfig,
                 smoke_cfg: T.TransformerConfig, opt: AdamWConfig | None = None):
        self.arch_id = arch_id
        self.full = full
        self.smoke_cfg = smoke_cfg
        self.opt = opt or AdamWConfig(lr=1e-4)

    def skip_reason(self, shape_id: str) -> str | None:
        if shape_id == "long_500k":
            return ("pure full-attention architecture: 500k-token decode requires "
                    "sub-quadratic attention; skipped per shape directive (DESIGN.md §5)")
        return None

    # ------------------------------------------------------------------
    def make_cell(self, shape_id: str, mesh, variant: str = "") -> Cell:
        sh = LM_SHAPES[shape_id]
        tp = mesh.shape.get("model", 1)
        naive = variant == "naive"
        cfg = dataclasses.replace(self.full.pad_heads(tp), seq_shard=not naive)
        S, B, kind = sh["seq"], sh["batch"], sh["kind"]
        micro = 1 if naive else sh.get("micro", 8)  # grad-accum microbatches
        if variant == "micro16":
            micro = 16

        params_abs = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))

        if kind == "train":
            opt_abs = jax.eval_shape(
                functools.partial(adamw_init, cfg=self.opt), params_abs
            )
            state_abs = (params_abs, opt_abs)
            batch_abs = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
            param_sh = lm_state_shardings(params_abs, mesh, cfg.n_kv_heads)

            def constrain_like_params(tree):
                # keep fp32 grad accumulators in the FSDP layout — without this
                # the scan carry is free to replicate (dry-run: arctic 3.9TB/dev)
                return jax.tree.map(
                    lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                    tree, param_sh,
                )

            def fn(state, batch):
                params, opt_state = state
                tb = batch["tokens"].reshape(micro, B // micro, S)
                lb = batch["labels"].reshape(micro, B // micro, S)

                def one(p, t, l):
                    return jax.value_and_grad(
                        lambda pp: T.loss_fn(pp, {"tokens": t, "labels": l}, cfg),
                        has_aux=True,
                    )(p)

                if micro == 1:
                    (loss, metrics), grads = one(params, tb[0], lb[0])
                    grads = constrain_like_params(grads)
                else:
                    # gradient accumulation: bounds activation memory to one
                    # microbatch; grads accumulate fp32 in the FSDP layout
                    def mstep(carry, tl):
                        gacc, lacc, aacc = carry
                        (loss, metrics), g = one(params, *tl)
                        # constrain at production: the MoE 2-axis expert layout
                        # otherwise materializes full fp32 grads (3.9 TB/dev)
                        g = constrain_like_params(g)
                        gacc = jax.tree.map(
                            lambda a, b: a + b.astype(jnp.float32), gacc, g
                        )
                        gacc = constrain_like_params(gacc)
                        return (gacc, lacc + metrics["loss"], aacc + metrics["moe_aux"]), None

                    g0 = constrain_like_params(jax.tree.map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), params
                    ))
                    (grads, lsum, asum), _ = jax.lax.scan(
                        mstep, (g0, jnp.float32(0), jnp.float32(0)), (tb, lb)
                    )
                    grads = jax.tree.map(lambda g: g / micro, grads)
                    metrics = {"loss": lsum / micro, "moe_aux": asum / micro}
                params, opt_state, om = adamw_update(
                    grads, opt_state, params, self.opt, param_shardings=param_sh
                )
                return (params, opt_state), {**metrics, **om}

            state_sh = lm_state_shardings(state_abs, mesh, cfg.n_kv_heads)
            batch_sh = lm_batch_shardings(batch_abs, mesh)
            from jax.sharding import PartitionSpec as P

            metrics_abs = {"loss": 0, "moe_aux": 0, "grad_norm": 0}
            out_sh = (state_sh, jax.tree.map(lambda _: named(mesh, P()), metrics_abs))
            tokens = B * S
            return Cell(self.arch_id, shape_id, fn, (state_abs, batch_abs),
                        (state_sh, batch_sh), out_sh, "train",
                        6.0 * cfg.active_param_count() * tokens,
                        notes=f"micro={micro} seq_shard={cfg.seq_shard}")

        if kind == "prefill":
            batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

            def fn(params, batch):
                logits, cache, _ = T.prefill(params, batch["tokens"], cfg, S)
                return logits, cache

            state_sh = lm_state_shardings(params_abs, mesh, cfg.n_kv_heads)
            batch_sh = lm_batch_shardings(batch_abs, mesh)
            cache_abs = jax.eval_shape(lambda: T.init_kv_cache(cfg, B, S))
            from jax.sharding import PartitionSpec as P

            out_sh = (named(mesh, P(("pod", "data"), "model")),
                      kv_cache_shardings(cache_abs, mesh, cfg.n_kv_heads))
            return Cell(self.arch_id, shape_id, fn, (params_abs, batch_abs),
                        (state_sh, batch_sh), out_sh, "prefill",
                        2.0 * cfg.active_param_count() * B * S)

        # decode: one token, KV cache of length S
        cache_abs = jax.eval_shape(lambda: T.init_kv_cache(cfg, B, S))
        tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(params, cache, tokens, pos):
            return T.decode_step(params, cache, tokens, pos, cfg)

        state_sh = lm_state_shardings(params_abs, mesh, cfg.n_kv_heads)
        cache_sh = kv_cache_shardings(cache_abs, mesh, cfg.n_kv_heads)
        batch_sh = lm_batch_shardings({"t": tok_abs}, mesh)["t"]
        from jax.sharding import PartitionSpec as P

        return Cell(self.arch_id, shape_id, fn,
                    (params_abs, cache_abs, tok_abs, pos_abs),
                    (state_sh, cache_sh, batch_sh, named(mesh, P())),
                    None, "decode", 2.0 * cfg.active_param_count() * B)

    # ------------------------------------------------------------------
    def smoke(self) -> dict:
        cfg = self.smoke_cfg
        key = jax.random.key(0)
        params = T.init_params(cfg, key)
        toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        opt = adamw_init(params, self.opt)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg), has_aux=True
        )(params)
        params2, _, om = adamw_update(grads, opt, params, self.opt)
        logits, cache, _ = T.prefill(params, toks, cfg, 96)
        dl, cache2 = T.decode_step(params, cache, jnp.argmax(logits, -1).astype(jnp.int32),
                                   jnp.int32(64), cfg)
        checks = {
            "loss": float(loss),
            "grad_norm": float(om["grad_norm"]),
            "logits_shape": tuple(dl.shape),
            "finite": bool(jnp.isfinite(loss))
            and bool(jnp.isfinite(dl).all())
            and all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(params2)),
        }
        return checks


def _smoke_of(full: T.TransformerConfig) -> T.TransformerConfig:
    moe = full.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=8, top_k=min(moe.top_k, 2), d_ff_expert=64)
    return dataclasses.replace(
        full, n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=max(1, min(4, 4 * full.n_kv_heads // max(full.n_heads, 1)) or 1),
        d_ff=256, vocab=512, d_head=32, moe=moe, remat=False,
        attn_q_chunk=32, attn_kv_chunk=32,
    )


def make_lm_arch(arch_id: str, full: T.TransformerConfig, **kw) -> LMArch:
    return LMArch(arch_id, full, _smoke_of(full), **kw)
