"""Distribution utilities: sharding-spec derivation for the config families
(:mod:`repro.dist.sharding`) and compressed collectives
(:mod:`repro.dist.compression`)."""
