"""Frontier-sparsity block skipping: skipped == full-scan, bit-identically.

Covers the active-block machinery (kernels/active.py), the scalar-prefetch
kernel variants (fragment_spmv{,_packed}_active, fragment_spmm{,_packed}_active)
through the ops dispatch, and the engine surface (prepare(block_skipping=...),
explain()). Bit-identity — np.array_equal, not allclose — is the contract:
a skipped block's contribution is the ⊕-identity, so the skip and scan paths
must produce the same floats for every semiring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fragments import _pack_words
from repro.kernels import active, ops
from repro.kernels.params import EDGE_BLOCK

N_DST = 256
OPS = ["sum", "min", "max", "bool"]  # SUM/COUNT, MIN, MAX, EXISTS semirings
ZERO = {"sum": 0.0, "min": np.inf, "max": -np.inf, "bool": 0.0}


@pytest.fixture(scope="module")
def edges():
    """4-block CSR edge set with a degree-0 gap: sources 3000..3999 have no
    edges, so some block's [src_min, src_max] range straddles ids that never
    occur — a support landing only in the gap activates the block but must
    contribute exactly the ⊕-identity."""
    rng = np.random.default_rng(42)
    n_src = 8192
    deg = np.full(n_src, 2, np.int64)
    deg[3000:4000] = 0  # the gap
    deg[:100] = 40  # head-heavy: first block is mostly sources 0..100
    E = int(deg.sum())
    pad = (-E) % EDGE_BLOCK
    deg[n_src - 1] += pad  # make E a block multiple so boundaries are exact
    src = np.repeat(np.arange(n_src, dtype=np.int32), deg)
    E = src.shape[0]
    dst = rng.integers(0, N_DST, E).astype(np.int32)
    m = (rng.random(E) * 9 + 1).astype(np.float32)  # measures > 0
    return n_src, src, dst, m


@pytest.fixture(scope="module")
def blocks(edges):
    _, src, _, _ = edges
    return active.block_ranges(src)


def frontier(n_src, sl, op="sum"):
    w = np.full(n_src, ZERO[op], np.float32)
    w[sl] = 1.5
    return w


def scan_vs_skip(w, edges, blocks, op, mode):
    _, src, dst, m = edges
    ref = np.asarray(ops.fragment_spmv(w, src, dst, m, N_DST, op=op))
    got = np.asarray(ops.fragment_spmv(
        w, src, dst, m, N_DST, op=op, blocks=blocks, block_skipping=mode
    ))
    np.testing.assert_array_equal(ref, got)
    return ref


# ---------------------------------------------------------------------------
# metadata + compaction unit behaviour
# ---------------------------------------------------------------------------


def test_block_ranges_partition(edges, blocks):
    _, src, _, _ = edges
    src_min, src_max = blocks
    nb = active.n_edge_blocks(src.shape[0])
    assert src_min.shape == (nb,) == src_max.shape
    assert (src_min <= src_max).all()
    assert (src_min[1:] >= src_max[:-1]).all()  # CSR order ⇒ monotone ranges
    assert src_min[0] == src[0] and src_max[-1] == src[-1]


def test_block_ranges_empty_relation():
    src_min, src_max = active.block_ranges(np.zeros(0, np.int64))
    # sentinel range intersects no support
    assert src_max[0] < src_min[0]


def test_compact_blocks_fixed_capacity():
    flags = jnp.asarray([False, True, False, True, True, False])
    idx, n = active.compact_blocks(flags)
    assert int(n[0]) == 3
    np.testing.assert_array_equal(np.asarray(idx), [1, 3, 4, 4, 4, 4])
    # empty: count 0, tail points at a valid block (0)
    idx0, n0 = active.compact_blocks(jnp.zeros(4, bool))
    assert int(n0[0]) == 0 and set(np.asarray(idx0)) == {0}


def test_bucket_capacity_powers_of_two():
    assert active.bucket_capacity(0, 256) == 1
    assert active.bucket_capacity(1, 256) == 1
    assert active.bucket_capacity(3, 256) == 4
    assert active.bucket_capacity(5, 256) == 8
    assert active.bucket_capacity(300, 256) == 256


# ---------------------------------------------------------------------------
# dense SpMV: frontier patterns × semirings, eager and traced
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("mode", ["on", "auto"])
def test_spmv_patterns_bit_identical(edges, blocks, op, mode):
    n_src, src, _, _ = edges
    patterns = {
        "empty": slice(0, 0),
        "first_block": slice(0, 3),  # heads live in block 0
        "last_block": slice(n_src - 2, n_src),
        "gap_only": slice(3200, 3400),  # degree-0 sources inside a block range
        "middle": slice(5000, 5200),
        "all_active": slice(0, n_src),
    }
    for name, sl in patterns.items():
        ref = scan_vs_skip(frontier(n_src, sl, op), edges, blocks, op, mode)
        if name == "empty" or name == "gap_only":
            assert (ref == ZERO[op]).all(), name


@pytest.mark.parametrize("op", OPS)
def test_spmv_traced_tier(edges, blocks, op):
    """Same bit-identity when the frontier is a jit tracer (the executor's
    compiled-chain tier: fixed-capacity list, pl.when-guarded grid)."""
    n_src, src, dst, m = edges
    w = frontier(n_src, slice(100, 130), op)
    ref = np.asarray(ops.fragment_spmv(w, src, dst, m, N_DST, op=op))
    for mode in ("on", "auto"):
        f = jax.jit(
            lambda w: ops.fragment_spmv(
                w, src, dst, m, N_DST, op=op, blocks=blocks, block_skipping=mode
            )
        )
        np.testing.assert_array_equal(ref, np.asarray(f(jnp.asarray(w))))


def test_spmv_off_and_missing_blocks_scan(edges, blocks):
    n_src, src, dst, m = edges
    w = frontier(n_src, slice(0, 10))
    ref = np.asarray(ops.fragment_spmv(w, src, dst, m, N_DST, op="sum"))
    off = np.asarray(ops.fragment_spmv(
        w, src, dst, m, N_DST, op="sum", blocks=blocks, block_skipping="off"
    ))
    none = np.asarray(ops.fragment_spmv(
        w, src, dst, m, N_DST, op="sum", blocks=None, block_skipping="auto"
    ))
    np.testing.assert_array_equal(ref, off)
    np.testing.assert_array_equal(ref, none)


def test_spmv_rejects_unknown_mode(edges, blocks):
    n_src, src, dst, m = edges
    with pytest.raises(ValueError, match="block_skipping"):
        ops.fragment_spmv(
            frontier(n_src, slice(0, 4)), src, dst, m, N_DST,
            blocks=blocks, block_skipping="maybe",
        )


# ---------------------------------------------------------------------------
# decode-fused (packed / dict) SpMV
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_mode", ["none", "dense", "packed", "dict"])
@pytest.mark.parametrize("op", ["sum", "min"])
def test_spmv_packed_bit_identical(edges, blocks, m_mode, op):
    n_src, src, dst, m = edges
    rng = np.random.default_rng(7)
    dw = int(N_DST - 1).bit_length()
    words_dst = _pack_words(dst, dw)
    midx = rng.integers(0, 32, src.shape[0]).astype(np.int32)
    mdict = (rng.random(32) * 5 + 1).astype(np.float32)
    words_m = _pack_words(midx, 5)
    meas = {"none": None, "dense": m, "packed": words_m, "dict": words_m}[m_mode]
    mw = 5 if m_mode in ("packed", "dict") else 0
    md = mdict if m_mode == "dict" else None
    kw = dict(n_dst=N_DST, dst_width=dw, m_mode=m_mode, m_width=mw, op=op)
    for sl in (slice(0, 5), slice(3200, 3300), slice(n_src - 3, n_src)):
        w = frontier(n_src, sl, op)
        ref = np.asarray(ops.fragment_spmv_packed(w, src, words_dst, meas, md, **kw))
        got = np.asarray(ops.fragment_spmv_packed(
            w, src, words_dst, meas, md, blocks=blocks, block_skipping="on", **kw
        ))
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# batched SpMM: union-of-supports block list
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", OPS)
def test_spmm_union_bit_identical(edges, blocks, op):
    n_src, src, dst, m = edges
    W = np.stack([
        frontier(n_src, slice(0, 4), op),  # block 0
        frontier(n_src, slice(n_src - 4, n_src), op),  # last block
        frontier(n_src, slice(0, 0), op),  # dead row
        frontier(n_src, slice(3200, 3300), op),  # gap-only row
    ])
    ref = np.asarray(ops.fragment_spmm(W, src, dst, m, N_DST, op=op))
    for mode in ("on", "auto"):
        got = np.asarray(ops.fragment_spmm(
            W, src, dst, m, N_DST, op=op, blocks=blocks, block_skipping=mode
        ))
        np.testing.assert_array_equal(ref, got)
    assert (ref[2] == ZERO[op]).all()  # dead row stays at the identity


def test_spmm_packed_bit_identical(edges, blocks):
    n_src, src, dst, m = edges
    dw = int(N_DST - 1).bit_length()
    words_dst = _pack_words(dst, dw)
    W = np.stack([frontier(n_src, slice(i * 16, i * 16 + 8)) for i in range(4)])
    kw = dict(n_dst=N_DST, dst_width=dw, m_mode="dense", op="sum")
    ref = np.asarray(ops.fragment_spmm_packed(W, src, words_dst, m, None, **kw))
    got = np.asarray(ops.fragment_spmm_packed(
        W, src, words_dst, m, None, blocks=blocks, block_skipping="on", **kw
    ))
    np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# engine surface: modes agree end-to-end across aggregates + explain()
# ---------------------------------------------------------------------------

Q_SCORE = """
SELECT dt2.Doc, {agg}(dt1.Fre * dt2.Fre)
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.ID
"""


@pytest.fixture(scope="module")
def engine():
    from repro.core.engine import GQFastDatabase, GQFastEngine
    from repro.data import synth_graph as SG

    pm = SG.make_pubmed(n_docs=1500, n_terms=80, n_authors=400, seed=5)
    return GQFastEngine(GQFastDatabase(pm, account_space=False))


@pytest.mark.parametrize(
    "agg", ["SUM", "COUNT", "MIN", "MAX", "AVG", "EXISTS"]
)
def test_engine_modes_bit_identical(engine, agg):
    call = "COUNT(*)" if agg == "COUNT" else (
        "EXISTS(*)" if agg == "EXISTS" else f"{agg}(dt1.Fre * dt2.Fre)"
    )
    q = Q_SCORE.format(agg="SUM").replace("SUM(dt1.Fre * dt2.Fre)", call)
    res = {
        mode: engine.prepare(q, block_skipping=mode)(d0=7)
        for mode in ("off", "on", "auto")
    }
    np.testing.assert_array_equal(res["off"], res["on"])
    np.testing.assert_array_equal(res["off"], res["auto"])
    assert (res["off"] != 0).any(), "degenerate test: empty result"


def test_engine_batched_modes_bit_identical(engine):
    q = Q_SCORE.format(agg="SUM")
    d0 = np.arange(6)
    off = engine.prepare(q, block_skipping="off").execute_batch(d0=d0)
    on = engine.prepare(q, block_skipping="on").execute_batch(d0=d0)
    np.testing.assert_array_equal(off, on)


def test_explain_reports_strategy_and_fractions(engine):
    pq = engine.prepare(Q_SCORE.format(agg="SUM"))
    text = pq.explain()
    assert "strategy: frontier" in text
    assert "block_skipping: auto" in text
    assert "est_active_fraction=" in text
    assert "HopOp" in text
    # distinct modes are distinct cache entries, not silently shared
    assert engine.prepare(Q_SCORE.format(agg="SUM"), block_skipping="off") is not pq
    assert engine.prepare(Q_SCORE.format(agg="SUM")) is pq


def test_prepare_rejects_unknown_block_skipping(engine):
    with pytest.raises(ValueError, match="block_skipping"):
        engine.prepare(Q_SCORE.format(agg="SUM"), block_skipping="bogus")


def test_device_db_carries_block_metadata(engine):
    for di in engine.db.device.indexes.values():
        E = int(di.src_ids.shape[0])
        assert di.block_src_min is not None
        assert di.block_src_min.shape[0] == active.n_edge_blocks(E)
        assert (np.asarray(di.block_src_min) <= np.asarray(di.block_src_max)).all()
