"""GQ-Fast engine facade (paper Fig. 4 architecture).

``GQFastDatabase`` = Loader: builds both fragment indices per relationship table
(+ metadata: encodings, space). ``GQFastEngine`` = Query Processor: SQL → RQNA
(parse + normalize/verify) → physical chain plan → compiled executable
(prepare once / execute many, as JDBC-style prepared statements)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from . import executor as X
from .algebra import ChainPlan
from .fragments import FragmentIndex, build_index
from .lower import PhysicalPlan, lower
from .planner import plan_query
from .schema import RelationshipTable, Schema
from .sql import parse


class GQFastDatabase:
    """In-memory GQ-Fast database: both directions of every relationship table.

    ``keep_packed`` (default True, matching ``fragments.build_index``) keeps
    the host-side bit-packed words on each ``ColumnFragments`` — the kernel
    wire layout the device column store reuses. Setting it False only trades
    host memory for a re-pack when a packed device encoding is chosen; the
    device representation is governed solely by ``device_encodings``
    (``"auto"`` | ``"dense"`` | ``"packed"`` | per-column dict keyed by
    ``(table, key, column)`` — see ``executor.build_device_db``). Deployments
    that only run the fallback strategies (``fragment_loop`` / a mesh) should
    pass ``device_encodings="dense"``: their prepares materialize every packed
    column anyway, so packed storage would cost packed *plus* dense bytes
    (visible as ``space_report()["device"]["materialized_bytes"]``)."""

    def __init__(
        self,
        schema: Schema,
        encodings: dict[tuple[str, str, str], str] | None = None,
        account_space: bool = True,
        keep_packed: bool = True,
        device_encodings: str | dict | None = "auto",
    ):
        schema.validate()
        self.schema = schema
        self.host_indexes: dict[tuple[str, str], FragmentIndex] = {}
        for rel in schema.relationships.values():
            for key in (rel.fk1, rel.fk2):
                enc = {
                    col: e
                    for (t, k, col), e in (encodings or {}).items()
                    if t == rel.name and k == key
                }
                self.host_indexes[(rel.name, key)] = build_index(
                    schema, rel, key, enc or None,
                    keep_packed=keep_packed, account_space=account_space,
                )
        self.device = X.build_device_db(schema, self.host_indexes, device_encodings)

    def space_report(self) -> dict[str, Any]:
        """Host byte-array accounting (paper §5 analytic model) plus the
        ``device`` section: real bytes the device column store holds, per
        column, with the decoded-CSR baseline for the compression ratio."""
        from ..storage import device_space_report

        rep: dict[str, Any] = {"indexes": {}, "total_bytes": 0}
        for (t, k), idx in self.host_indexes.items():
            cols = {
                c: {"encoding": cf.encoding, "bytes": cf.encoded_bytes}
                for c, cf in idx.columns.items()
            }
            b = idx.total_bytes()
            rep["indexes"][f"I_{t}.{k}"] = {"columns": cols, "lookup_bytes": idx.lookup_bytes(), "bytes": b}
            rep["total_bytes"] += b
        rep["device"] = device_space_report(self.device)
        return rep


@dataclass
class PreparedQuery:
    sql: str
    plan: ChainPlan
    fn: Callable[..., Any]
    param_names: list[str]
    group_entity: str | None
    phys: PhysicalPlan | None = None  # lowered IR (None only for legacy callers)

    def __call__(self, **params) -> np.ndarray:
        args = [params[n] for n in self.param_names]
        return np.asarray(self.fn(*args))

    def execute_batch(self, **param_arrays) -> np.ndarray:
        """vmap over parameter vectors (batched OLAP serving)."""
        import jax

        args = [np.asarray(param_arrays[n]) for n in self.param_names]
        return np.asarray(jax.vmap(self.fn)(*args))


class GQFastEngine:
    def __init__(self, db: GQFastDatabase, strategy: str = "frontier",
                 mesh=None, shard_axes: tuple[str, ...] = ("data",)):
        self.db = db
        self.strategy = strategy
        self.mesh = mesh
        self.shard_axes = shard_axes
        self._cache: dict[tuple[str, str], PreparedQuery] = {}

    def prepare(self, sql: str) -> PreparedQuery:
        key = (sql, self.strategy)
        if key in self._cache:
            return self._cache[key]
        plan = plan_query(self.db.schema, parse(sql))
        # lower once: every strategy interprets the same physical IR, and the
        # per-execute mask/ref-resolution work is hoisted out of the hot path
        phys = lower(self.db.device, plan)
        names = list(phys.param_names)
        if self.mesh is not None:
            fn = X.compile_frontier_distributed(
                self.db.device, phys, self.mesh, self.shard_axes
            )
        else:
            strategy = self.strategy
            if strategy == "auto":
                strategy = self._pick_strategy(plan)
            fn = X.STRATEGIES[strategy](self.db.device, phys)
        pq = PreparedQuery(sql, plan, fn, names, plan.group_entity, phys)
        self._cache[key] = pq
        return pq

    def _pick_strategy(self, plan: ChainPlan) -> str:
        """Beyond-paper: cost-based strategy choice. The paper's fragment-at-a-
        time execution is *work-efficient* (touches only reachable fragments);
        the vectorized frontier pass is *throughput-efficient* (whole-relation
        SpMV). Estimate the touched fraction from average degrees: sparse seeds
        → fragment_loop, dense traversals → frontier (EXPERIMENTS.md §Perf)."""
        from .algebra import RelHop, SeedIds

        if not isinstance(plan.seed, SeedIds):
            return "frontier"  # mask seeds are whole-domain already
        frontier_est = 1.0
        worst_fraction = 0.0
        first = True
        for s in plan.steps:
            if not isinstance(s, RelHop) or s.degree_filter:
                continue
            idx = self.db.host_indexes[(s.table, s.src_key)]
            edges = max(idx.num_edges, 1)
            h = idx.indptr.shape[0] - 1
            deg = np.diff(idx.indptr)
            # first hop: plan for the worst (max-degree) seed — the prepared
            # query serves arbitrary parameters and Zipf heads dominate cost;
            # later hops mix many fragments, so the average is representative
            est_deg = float(deg.max()) if first else edges / max(h, 1)
            first = False
            touched_edges = frontier_est * est_deg
            worst_fraction = max(worst_fraction, min(touched_edges / edges, 1.0))
            frontier_est = min(touched_edges, self.db.schema.domain_size(s.dst_entity))
        # crossover measured on this host (benchmarks/perf_baseline): the scalar
        # loop wins while < ~15% of the relation is touched; on TPU the vector
        # path's advantage is larger, so deployments should retune this knob
        return "fragment_loop" if worst_fraction < 0.15 else "frontier"

    def query(self, sql: str, **params) -> np.ndarray:
        return self.prepare(sql)(**params)

    def query_topk(self, sql: str, k: int = 10, **params) -> list[tuple[int, float]]:
        scores = self.query(sql, **params)
        idx = np.argsort(-scores)[:k]
        return [(int(i), float(scores[i])) for i in idx if scores[i] != 0]
