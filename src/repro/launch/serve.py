"""Serving launcher: GQ-Fast analytics (the paper's workload) or LM decode.

  PYTHONPATH=src python -m repro.launch.serve --workload analytics
  PYTHONPATH=src python -m repro.launch.serve --workload lm
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["analytics", "lm"], default="analytics")
    ap.add_argument("--requests", type=int, default=60)
    args = ap.parse_args()

    if args.workload == "analytics":
        import runpy
        import sys
        from pathlib import Path

        # resolve against the repo root (this file is src/repro/launch/serve.py)
        # so `python -m repro.launch.serve` works from any working directory
        script = Path(__file__).resolve().parents[3] / "examples" / "serve_analytics.py"
        if not script.is_file():  # e.g. non-editable install: no examples/ tree
            raise SystemExit(
                f"analytics workload needs the repo checkout: {script} not found "
                "(run from a source tree or `pip install -e .`)"
            )
        sys.argv = [str(script), "--requests", str(args.requests)]
        runpy.run_path(str(script), run_name="__main__")
        return

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.models.transformer import decode_step, init_params, prefill

    arch = get_arch("qwen2.5-3b")
    cfg = arch.smoke_cfg
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    logits, cache, pos = prefill(params, toks, cfg, 128)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    out = [cur]
    for i in range(args.requests):
        logits, cache = step(params, cache, cur, jnp.int32(32 + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {args.requests} decode steps × batch 4: "
          f"{dt/args.requests*1e3:.1f} ms/step, {4*args.requests/dt:.1f} tok/s")
    print("sample tokens:", np.asarray(jnp.stack(out))[:10, 0].tolist())


if __name__ == "__main__":
    main()
