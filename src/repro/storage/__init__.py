"""Compressed device-resident column store (paper §5-6; DESIGN.md §Storage)
plus its durability layer: CRC32C integrity manifests, verified reads, and
checksummed generation-stamped snapshots (§Durability)."""
from .columns import (  # noqa: F401
    DenseColumn,
    DeviceColumn,
    DictPackedColumn,
    PackedColumn,
)
from .integrity import (  # noqa: F401
    attach_manifest,
    build_manifest,
    column_digest,
    crc32c,
    crc32c_parts,
    decode_fresh,
    detach_manifest,
    encoded_parts,
    iter_columns,
)
from .policy import (  # noqa: F401
    build_device_column,
    choose_device_encoding,
    column_uniques,
    device_space_report,
    resolve_device_encoding,
)
from .snapshot import (  # noqa: F401
    latest_generation,
    list_generations,
    load_column_arrays,
    restore_db,
    snapshot_db,
)
