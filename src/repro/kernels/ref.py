"""Pure-jnp oracles for every Pallas kernel (allclose targets for the sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitgather_ref(packed: jnp.ndarray, width: int, ids: jnp.ndarray) -> jnp.ndarray:
    """Decode the little-endian ``width``-bit values at positions ``ids`` from a
    uint32 word stream — the double-word extraction at arbitrary positions.
    Also the point-decode behind ``storage.DeviceColumn.gather``."""
    idx = jnp.asarray(ids, jnp.uint32)
    # split the bit offset as 32·q·width + r·width (q = idx//32) so nothing
    # exceeds uint32: a plain idx*width wraps past 2^32 bits (~138M values at
    # width 31) and would silently read from the wrong word. r·width < 1024
    # and q·width < word count, which any indexable word stream satisfies.
    q, r = idx >> 5, idx & jnp.uint32(31)
    bitr = r * jnp.uint32(width)
    w0 = (q * jnp.uint32(width) + (bitr >> 5)).astype(jnp.int32)
    off = (bitr & jnp.uint32(31)).astype(jnp.uint32)
    lo = packed[w0]
    hi = packed[jnp.minimum(w0 + 1, packed.shape[0] - 1)]
    # 64-bit-free double-word extraction: value = (lo >> off) | (hi << (32-off)),
    # with the straddle term vanishing under the width mask when off == 0 or the
    # value fits entirely in ``lo``.
    word = jnp.where(off == 0, lo, (lo >> off) | _safe_shl(hi, jnp.uint32(32) - off))
    mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
    return (word & mask).astype(jnp.int32)


def bitunpack_ref(packed: jnp.ndarray, width: int, count: int) -> jnp.ndarray:
    """Decode little-endian ``width``-bit values from uint32 words.

    Value i occupies bits [i*width, (i+1)*width) of the word stream; a value may
    straddle two words. Returns int32[count]."""
    return bitgather_ref(packed, width, jnp.arange(count, dtype=jnp.uint32))


def _safe_shl(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """x << s with s possibly 32 (→ 0), avoiding UB on 32-bit shifts."""
    return jnp.where(s >= 32, jnp.uint32(0), x << (s & jnp.uint32(31)))


def fragment_spmv_ref(
    weights: jnp.ndarray,  # f32[n_src]
    src_ids: jnp.ndarray,  # i32[E]
    dst_ids: jnp.ndarray,  # i32[E]
    measures: jnp.ndarray,  # f32[E]
    n_dst: int,
    op: str = "sum",
) -> jnp.ndarray:
    """One relationship hop: y[dst] = ⊕_edges w[src] ⊗ m (the frontier SpMV),
    with the combine op ⊕ selected by the aggregation semiring."""
    ws = jnp.take(weights, src_ids)
    if op == "sum":
        return jax.ops.segment_sum(ws * measures, dst_ids, num_segments=n_dst)
    if op == "bool":
        ew = ((ws > 0) & (measures != 0)).astype(jnp.float32)
        # clamp segment_max's empty-segment fill (-inf) to the bool
        # ⊕-identity 0 — the kernels initialize with IDENTITY['bool'] and a
        # downstream binarize must see the same representation
        return jnp.maximum(
            jax.ops.segment_max(ew, dst_ids, num_segments=n_dst), 0.0
        )
    zero = float("inf") if op == "min" else float("-inf")
    ew = jnp.where(ws == zero, zero, ws * measures)  # ∞·0 guard
    seg = jax.ops.segment_min if op == "min" else jax.ops.segment_max
    return seg(ew, dst_ids, num_segments=n_dst)


def fragment_spmv_packed_ref(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst,  # uint32 words if dst_width else i32[E]
    measure,  # uint32 words | f32[E] | None, per m_mode
    mdict,  # f32[u] | None
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
) -> jnp.ndarray:
    """Decode-then-hop oracle for the fused kernel: whole-column bitunpack
    followed by the plain SpMV — same math, decompression outside the loop."""
    E = src_ids.shape[0]
    d = bitunpack_ref(dst, dst_width, E) if dst_width else dst
    if m_mode == "none":
        m = jnp.ones(E, jnp.float32)
    elif m_mode == "dense":
        m = measure
    else:
        idx = bitunpack_ref(measure, m_width, E)
        m = jnp.take(mdict, idx) if m_mode == "dict" else idx.astype(jnp.float32)
    return fragment_spmv_ref(weights, src_ids, d, m, n_dst, op=op)


def fragment_spmm_ref(
    weights: jnp.ndarray,  # f32[B, n_src]
    src_ids: jnp.ndarray,  # i32[E]
    dst_ids: jnp.ndarray,  # i32[E]
    measures: jnp.ndarray,  # f32[E] shared, or f32[B, E] per-row
    n_dst: int,
    op: str = "sum",
) -> jnp.ndarray:
    """Batched hop oracle: B independent SpMVs (vmap'd segment-combine).
    Also the XLA fallback for per-row measure streams, which the fused SpMM
    kernel cannot express (one edge stream serves the whole batch there)."""
    if measures.ndim == 1:
        return jax.vmap(
            lambda w: fragment_spmv_ref(w, src_ids, dst_ids, measures, n_dst, op=op)
        )(weights)
    return jax.vmap(
        lambda w, m: fragment_spmv_ref(w, src_ids, dst_ids, m, n_dst, op=op)
    )(weights, measures)


def fragment_spmm_packed_ref(
    weights: jnp.ndarray,  # f32[B, n_src]
    src_ids: jnp.ndarray,
    dst,  # uint32 words if dst_width else i32[E]
    measure,  # uint32 words | f32[E] | None, per m_mode
    mdict,  # f32[u] | None
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
) -> jnp.ndarray:
    """Decode-then-hop oracle for the fused batched kernel: whole-column
    bitunpack once, then the vmap'd SpMV sweep."""
    E = src_ids.shape[0]
    d = bitunpack_ref(dst, dst_width, E) if dst_width else dst
    if m_mode == "none":
        m = jnp.ones(E, jnp.float32)
    elif m_mode == "dense":
        m = measure
    else:
        idx = bitunpack_ref(measure, m_width, E)
        m = jnp.take(mdict, idx) if m_mode == "dict" else idx.astype(jnp.float32)
    return fragment_spmm_ref(weights, src_ids, d, m, n_dst, op=op)


def _mid_transform_ref(u, mid_mask, mid_binarize: bool, op: str):
    """The fused region's phase boundary: constant filter mask then hop2's
    semijoin binarize — mirrors ``Semiring.mask`` / ``Semiring.binarize``."""
    zero = {"sum": 0.0, "bool": 0.0, "min": float("inf"), "max": float("-inf")}[op]
    if mid_mask is not None:
        keep = mid_mask[None, :] if u.ndim == 2 else mid_mask
        u = jnp.where(keep > 0, u, zero)
    if mid_binarize:
        if op == "sum":
            u = (u > 0).astype(jnp.float32)
        else:
            u = jnp.where(u != zero, jnp.float32(1.0), jnp.float32(zero))
    return u


def fragment_spmv_fused_ref(
    weights: jnp.ndarray,
    src1, dst1, m1, md1,
    src2, dst2, m2, md2,
    mid_mask,
    n_mid: int,
    n_dst: int,
    dst1_width: int = 0, m1_mode: str = "none", m1_width: int = 0,
    dst2_width: int = 0, m2_mode: str = "none", m2_width: int = 0,
    op: str = "sum",
    mid_binarize: bool = False,
) -> jnp.ndarray:
    """Oracle for the pipelined 2-hop region: hop1 → mask/binarize → hop2, each
    stage through the existing per-hop oracles (``src2=None`` ⇒ degenerate
    1-hop+filter region, where the mask applies to the output domain)."""
    u = fragment_spmv_packed_ref(
        weights, src1, dst1, m1, md1, n_mid,
        dst_width=dst1_width, m_mode=m1_mode, m_width=m1_width, op=op,
    )
    if src2 is None:
        return _mid_transform_ref(u, mid_mask, False, op)
    u = _mid_transform_ref(u, mid_mask, mid_binarize, op)
    return fragment_spmv_packed_ref(
        u, src2, dst2, m2, md2, n_dst,
        dst_width=dst2_width, m_mode=m2_mode, m_width=m2_width, op=op,
    )


def fragment_spmm_fused_ref(
    weights: jnp.ndarray,  # f32[B, n_src]
    src1, dst1, m1, md1,
    src2, dst2, m2, md2,
    mid_mask,
    n_mid: int,
    n_dst: int,
    dst1_width: int = 0, m1_mode: str = "none", m1_width: int = 0,
    dst2_width: int = 0, m2_mode: str = "none", m2_width: int = 0,
    op: str = "sum",
    mid_binarize: bool = False,
) -> jnp.ndarray:
    """Batched oracle for the pipelined region (B rows through both hops)."""
    u = fragment_spmm_packed_ref(
        weights, src1, dst1, m1, md1, n_mid,
        dst_width=dst1_width, m_mode=m1_mode, m_width=m1_width, op=op,
    )
    if src2 is None:
        return _mid_transform_ref(u, mid_mask, False, op)
    u = _mid_transform_ref(u, mid_mask, mid_binarize, op)
    return fragment_spmm_packed_ref(
        u, src2, dst2, m2, md2, n_dst,
        dst_width=dst2_width, m_mode=m2_mode, m_width=m2_width, op=op,
    )


def bitmap_and_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Word-wise AND of two uint32 bitmap word arrays."""
    return a & b


def bitmap_and_popcount_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Total set bits of (a & b) — merge-intersection cardinality (paper §6.1)."""
    return jnp.sum(jax.lax.population_count(a & b).astype(jnp.int32))
