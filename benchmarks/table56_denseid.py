"""Paper Tables 5 + 6: the dense-IDs ablations.

Table 5 — fragment lookup: direct offset-table indexing (dense IDs) vs binary
search on the sorted key column (GQ-Fast-UA vs GQ-Fast-UA(Binary)).
Table 6 — final aggregation: dense γ¹ array vs hash-style grouping
(GQ-Fast-UA vs GQ-Fast-UA(Map))."""
from __future__ import annotations

from repro.core.planner import plan_query
from repro.core.reference import NumpyQueryEngine
from repro.core.sql import parse
from repro.data import synth_graph as SG

from .common import emit, pubmed_m, semmeddb, timeit

CASES = [
    ("SD", SG.QUERY_SD, {"d0": 997}, pubmed_m),
    ("FSD", SG.QUERY_FSD, {"d0": 997}, pubmed_m),
    ("AD", SG.QUERY_AD, {"t1": 30, "t2": 50}, pubmed_m),
    ("AS", SG.QUERY_AS, {"a0": 900}, pubmed_m),
    ("CS", SG.QUERY_CS, {"c0": 230}, semmeddb),
]


def run() -> None:
    for qname, sql, params, schema_fn in CASES:
        schema = schema_fn()
        plan = plan_query(schema, parse(sql))
        direct = NumpyQueryEngine(schema, lookup="index", agg="dense")
        binary = NumpyQueryEngine(schema, lookup="binary", agg="dense")
        hashag = NumpyQueryEngine(schema, lookup="index", agg="hash")
        t_d = timeit(direct.execute_plan, plan, params, iters=5)
        t_b = timeit(binary.execute_plan, plan, params, iters=5)
        t_h = timeit(hashag.execute_plan, plan, params, iters=5)
        emit(f"table5/{qname}/direct", t_d * 1e6, f"binary_saving={1-t_d/max(t_b,1e-12):.2%}")
        emit(f"table5/{qname}/binary", t_b * 1e6, "")
        emit(f"table6/{qname}/dense_agg", t_d * 1e6, f"map_saving={1-t_d/max(t_h,1e-12):.2%}")
        emit(f"table6/{qname}/hash_agg", t_h * 1e6, "")


if __name__ == "__main__":
    run()
