"""Paper Table 4: overall space cost — GQ-Fast's two compressed indices vs the
uncompressed-array variant (UA = what a column store keeps, two sorted copies =
OMC; one copy = PMC)."""
from __future__ import annotations

from repro.core.engine import GQFastDatabase

from .common import emit, gqfast_db, pubmed_m, pubmed_ms, semmeddb


def run() -> None:
    for ds_name, schema_fn, key in [
        ("pubmed-m", pubmed_m, "m"), ("pubmed-ms", pubmed_ms, "ms"),
        ("semmeddb", semmeddb, "sem"),
    ]:
        schema = schema_fn()
        gq = gqfast_db(key).space_report()
        # UA-only database = the column-store layout (no dense compression)
        ua_enc = {}
        for rel in schema.relationships.values():
            for k in (rel.fk1, rel.fk2):
                for col in rel.columns:
                    if col != k:
                        ua_enc[(rel.name, k, col)] = "UA"
        ua = GQFastDatabase(schema, encodings=ua_enc, account_space=True).space_report()
        pmc_bytes = ua["total_bytes"] / 2  # one copy, no second sort order
        emit(f"table4/{ds_name}/gqfast_bytes", gq["total_bytes"],
             f"ua_ratio={ua['total_bytes']/gq['total_bytes']:.2f} "
             f"pmc_ratio={pmc_bytes/gq['total_bytes']:.2f}")
        emit(f"table4/{ds_name}/omc_ua_bytes", ua["total_bytes"], "")
        for iname, idx in gq["indexes"].items():
            encs = ",".join(f"{c}:{v['encoding']}" for c, v in idx["columns"].items())
            emit(f"table4/{ds_name}/{iname}", idx["bytes"], encs)


if __name__ == "__main__":
    run()
