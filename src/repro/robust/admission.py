"""Admission control: reject (or demote) queries before they OOM the device.

DESIGN.md §Robustness documents the formula. The estimator combines two
models the engine already maintains:

  * the **resident** term — real device bytes the column store holds,
    from :func:`repro.storage.device_space_report`;
  * the **working** term — what executing this plan allocates on top:
    per-op frontier vectors over entity domains (×batch for the SpMM path,
    ×2 for AVG's fused SUM+COUNT walk) plus the expected edge-stream traffic
    from the PR-4 ``_hop_fractions`` cardinality model
    (est_active_fraction × E × bytes/edge).

``AdmissionController.decide`` compares predicted peak bytes against a
:class:`MemoryBudget` and returns one of three actions:

    admit   — run as requested.
    demote  — the batched footprint exceeds budget but a single query fits:
              serve the bucket serially (degraded, but alive). The runner /
              serve loop implements the demotion.
    reject  — even one query at B=1 is predicted over budget → raise
              :class:`repro.robust.errors.ResourceError` (never submit work
              the device cannot hold).

This module also owns :class:`PreparedCache` — the fixed-size LRU that
bounds the engine's prepared-query (compile) cache under many distinct query
shapes; evictions are counted on the shared metrics registry.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any

from ..obs.metrics import REGISTRY, MetricsRegistry
from .errors import ResourceError

#: Bytes per edge the frontier hop streams in the worst (all-dense) case:
#: src id + dst id + measure, 4 bytes each.
EDGE_STREAM_BYTES = 12

#: f32 accumulator cell.
CELL_BYTES = 4


@dataclass(frozen=True)
class MemoryBudget:
    """``limit_bytes`` is the hard ceiling for resident + working bytes;
    ``headroom`` (fraction of the limit) is reserved for allocator slack and
    XLA temporaries, so the effective budget is ``limit × (1 − headroom)``.
    ``limit_bytes=None`` disables admission (everything admits)."""

    limit_bytes: int | None = None
    headroom: float = 0.1

    @property
    def effective_bytes(self) -> float | None:
        if self.limit_bytes is None:
            return None
        return self.limit_bytes * (1.0 - self.headroom)


@dataclass
class AdmissionDecision:
    action: str  # admit | demote | reject
    predicted_bytes: int
    single_bytes: int  # the B=1 prediction (the demotion target)
    limit_bytes: int | None
    reason: str = ""

    @property
    def admitted(self) -> bool:
        return self.action == "admit"


def _plan_working_bytes(phys, batch: int, hop_estimates=None) -> int:
    """Working-set model for one execution of ``phys`` at batch B: the peak
    pair of live frontier vectors (walker state + the hop it feeds) plus the
    expected touched edge stream. AVG runs the walk twice in one program
    (fused SUM+COUNT) → double the frontier term. Mask-seed sub-programs
    recurse with the boolean semiring (same widths)."""
    from ..core.lower import GroupOp, HopOp, SeedOp, iter_flat_ops

    doms: list[int] = []
    edge_bytes = 0
    est = {
        (h["table"], h["src_key"]): h["est_active_fraction"]
        for h in (hop_estimates or [])
    }
    # flattened walk: a FusedHopOp's member hops still stream their edges and
    # hold a live intermediate (the VMEM scratch), so the model charges them
    # exactly as it charges the unfused plan
    for op in iter_flat_ops(phys):
        if isinstance(op, SeedOp):
            doms.append(op.dom)
            for prog in op.programs:
                edge_bytes += _plan_working_bytes(prog, batch)
        elif isinstance(op, HopOp):
            doms.append(op.dom_dst)
            E = int(op.src_ids.shape[0])
            frac = est.get((op.table, op.src_key), 1.0)
            edge_bytes += int(frac * E) * EDGE_STREAM_BYTES
        elif isinstance(op, GroupOp):
            doms.append(op.dom)
    doms.sort(reverse=True)
    peak_frontier = sum(doms[:2]) * CELL_BYTES * batch
    if getattr(phys, "agg", None) == "avg":
        peak_frontier *= 2
    return peak_frontier + edge_bytes


def estimate_query_bytes(prepared, batch: int = 1) -> dict[str, int]:
    """Predicted device footprint of executing ``prepared`` at batch B:
    ``resident`` (column store) + ``working`` (frontiers + edge streams).
    Pure host arithmetic — never allocates on device."""
    from ..storage import device_space_report

    resident = 0
    if prepared.device_db is not None:
        rep = device_space_report(prepared.device_db)
        resident = int(rep["total_bytes"]) + int(rep.get("materialized_bytes", 0))
    working = (
        _plan_working_bytes(prepared.phys, batch, prepared.hop_estimates)
        if prepared.phys is not None else 0
    )
    return {
        "resident_bytes": resident,
        "working_bytes": working,
        "total_bytes": resident + working,
    }


class AdmissionController:
    """Pre-execute gate. ``decide`` never raises; ``admit`` raises
    :class:`ResourceError` on reject (and on demote when ``allow_demote``
    is False) — the one-call form for callers without a serial fallback."""

    def __init__(self, budget: MemoryBudget,
                 registry: MetricsRegistry | None = None):
        self.budget = budget
        self.registry = registry if registry is not None else REGISTRY

    def decide(self, prepared, batch: int = 1) -> AdmissionDecision:
        limit = self.budget.effective_bytes
        if limit is None:
            est = estimate_query_bytes(prepared, batch)
            return AdmissionDecision(
                "admit", est["total_bytes"], est["total_bytes"], None,
                reason="no budget configured",
            )
        est = estimate_query_bytes(prepared, batch)
        single = estimate_query_bytes(prepared, 1) if batch > 1 else est
        if est["total_bytes"] <= limit:
            return AdmissionDecision(
                "admit", est["total_bytes"], single["total_bytes"],
                self.budget.limit_bytes,
            )
        self.registry.counter("robust.admission_over_budget").inc()
        if batch > 1 and single["total_bytes"] <= limit:
            self.registry.counter("robust.admission_demotions").inc()
            return AdmissionDecision(
                "demote", est["total_bytes"], single["total_bytes"],
                self.budget.limit_bytes,
                reason=f"batch={batch} over budget; single-query fits",
            )
        self.registry.counter("robust.admission_rejections").inc()
        return AdmissionDecision(
            "reject", est["total_bytes"], single["total_bytes"],
            self.budget.limit_bytes,
            reason="predicted footprint exceeds budget even at batch=1",
        )

    def admit(self, prepared, batch: int = 1,
              allow_demote: bool = False) -> AdmissionDecision:
        d = self.decide(prepared, batch)
        if d.action == "reject" or (d.action == "demote" and not allow_demote):
            raise ResourceError(
                f"admission rejected: predicted {d.predicted_bytes} bytes"
                f" > budget {self.budget.limit_bytes}",
                code="ADMISSION",
                predicted_bytes=d.predicted_bytes,
                limit_bytes=self.budget.limit_bytes,
                batch=batch, action=d.action,
            )
        return d


class PreparedCache:
    """Fixed-capacity LRU for prepared queries: bounds compile-cache growth
    under many distinct query shapes (each entry pins a traced executable
    pair). Eviction order is least-recently-*used* — ``get`` refreshes.

    Thread-safe: serve workers, the hot-swap warm-up thread, and scrubber
    heal callbacks (which :meth:`clear` stale executables) touch one cache
    concurrently; an unguarded ``move_to_end`` during ``popitem`` corrupts
    the OrderedDict."""

    def __init__(self, capacity: int = 64,
                 registry: MetricsRegistry | None = None):
        if capacity < 1:
            raise ValueError(f"PreparedCache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.registry = registry if registry is not None else REGISTRY
        self._data: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.RLock()

    def get(self, key):
        with self._lock:
            v = self._data.get(key)
            if v is not None:
                self._data.move_to_end(key)
        if v is not None:
            self.registry.counter("engine.prepared_cache_hits").inc()
        return v

    def put(self, key, value) -> None:
        evictions = 0
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                evictions += 1
        if evictions:
            self.registry.counter("engine.prepared_cache_evictions").inc(evictions)

    def clear(self) -> int:
        """Drop every entry (device arrays were swapped under the prepared
        executables — a heal or generation reload). Returns entries dropped."""
        with self._lock:
            n = len(self._data)
            self._data.clear()
        if n:
            self.registry.counter("engine.prepared_cache_invalidations").inc(n)
        return n

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data
