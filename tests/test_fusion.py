"""Pipelined multi-hop fusion tests (DESIGN.md §Pipelined fusion).

Covers the IR fusion pass (region formation boundaries, recursion into mask
seeds, the unfuse inverse, the reach matrix), fused-vs-unfused/oracle
bit-identity at the kernel level (dense/packed operands × every kernel op ×
every skip mode × B=1/8), and the engine surface (every SQL aggregate,
batched serving, the VMEM-budget auto fallback, explain/ladder integration).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.core.fragments import _pack_words
from repro.core.fuse import (
    _block_reach,
    fuse_plan,
    fusion_groups,
    has_fused,
    unfuse_plan,
)
from repro.core.lower import (
    DegreeFilterOp,
    EntityFilterOp,
    FusedHopOp,
    GroupOp,
    HopOp,
    PhysicalPlan,
    SeedOp,
)
from repro.data import synth_graph as SG
from repro.kernels import active, ops, ref
from repro.kernels.ops import FusedHopOperands
from repro.kernels.params import EDGE_BLOCK

OPS = ["sum", "min", "max", "bool"]
ZERO = {"sum": 0.0, "min": np.inf, "max": -np.inf, "bool": 0.0}


# ---------------------------------------------------------------------------
# IR pass: region formation
# ---------------------------------------------------------------------------


def _mk_hop(n_src: int, n_dst: int, E: int, seed: int, **kw) -> HopOp:
    from repro.storage import DenseColumn

    rng = np.random.default_rng(seed)
    src = np.sort(rng.integers(0, n_src, E)).astype(np.int32)
    dst = rng.integers(0, n_dst, E).astype(np.int32)
    indptr = np.searchsorted(src, np.arange(n_src + 1)).astype(np.int32)
    smin, smax = active.block_ranges(src)
    return HopOp(
        "T", f"K{seed}", "E2", n_dst, jnp.asarray(indptr), jnp.asarray(src),
        DenseColumn(jnp.asarray(dst)), block_src_min=smin, block_src_max=smax,
        **kw,
    )


def _mk_plan(ops_, agg="sum", out_dom=64):
    return PhysicalPlan(tuple(ops_), (), agg, out_dom, None)


def _seed(dom=64):
    return SeedOp("E0", dom, ids=(3,))


def test_two_hop_chain_fuses_with_trailing_group():
    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    p = _mk_plan([_seed(), h1, h2, GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert [type(o).__name__ for o in f.ops] == ["SeedOp", "FusedHopOp"]
    region = f.ops[1]
    assert region.members == (h1, h2, p.ops[3])
    assert region.n_mid == h1.dom_dst
    assert region.hops == (h1, h2) and region.group is p.ops[3]
    assert region.reach is not None and region.reach.dtype == bool
    assert "Fused[" in f.op_signature()[1]
    assert fusion_groups(f) and "Hop(" in fusion_groups(f)[0]


def test_mid_mask_filter_joins_region():
    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    filt = EntityFilterOp("E1", const_mask=jnp.ones(48, jnp.float32))
    p = _mk_plan([_seed(), h1, filt, h2, GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert [type(o).__name__ for o in f.ops] == ["SeedOp", "FusedHopOp"]
    assert f.ops[1].mid_filters == (filt,)


def test_bare_single_hop_stays_unfused():
    p = _mk_plan([_seed(), _mk_hop(64, 64, 500, 1), GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert not has_fused(f)
    assert f.ops == p.ops


def test_one_hop_plus_mask_filter_fuses_degenerate():
    h1 = _mk_hop(64, 64, 500, 1)
    filt = EntityFilterOp("E2", const_mask=jnp.ones(64, jnp.float32))
    p = _mk_plan([_seed(), h1, filt, GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert isinstance(f.ops[1], FusedHopOp)
    assert f.ops[1].hops == (h1,) and f.ops[1].reach is None


def test_degree_filter_ends_region():
    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    dfilt = DegreeFilterOp("T", "K", jnp.ones(48, jnp.int32))
    p = _mk_plan([_seed(), h1, dfilt, h2, GroupOp("E2", 64)])
    f = fuse_plan(p)
    # neither side of the DegreeFilterOp has a fusable run
    assert not has_fused(f)
    assert [type(o).__name__ for o in f.ops] == [
        "SeedOp", "HopOp", "DegreeFilterOp", "HopOp", "GroupOp",
    ]


def test_factor_or_param_filter_ends_region():
    from repro.core.lower import LCond

    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    cond = LCond(("attr", "E1", "x"), jnp.ones(48), ">", 0)
    filt = EntityFilterOp("E1", param_conds=(cond,))
    p = _mk_plan([_seed(), h1, filt, h2, GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert not has_fused(f)


def test_group_only_joins_as_plan_tail():
    # a GroupOp that is NOT the last op (mask sub-chain shape) stays outside
    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    p = _mk_plan([_seed(), h1, h2, GroupOp(None, 64),
                  EntityFilterOp("E2", const_mask=jnp.ones(64, jnp.float32))])
    f = fuse_plan(p)
    region = f.ops[1]
    assert isinstance(region, FusedHopOp) and region.group is None
    assert [type(o).__name__ for o in f.ops] == [
        "SeedOp", "FusedHopOp", "GroupOp", "EntityFilterOp",
    ]


def test_mask_seed_subprograms_fuse_recursively():
    sub = _mk_plan(
        [SeedOp("E0", 64, ids=(1,)), _mk_hop(64, 48, 500, 3),
         _mk_hop(48, 64, 600, 4), GroupOp(None, 64)], agg=None,
    )
    seed = SeedOp("E0", 64, ids=None, programs=(sub,))
    p = _mk_plan([seed, _mk_hop(64, 64, 500, 1), GroupOp("E2", 64)])
    f = fuse_plan(p)
    assert has_fused(f)  # only via the sub-program
    assert isinstance(f.ops[0].programs[0].ops[1], FusedHopOp)
    u = unfuse_plan(f)
    assert not has_fused(u)


def test_unfuse_is_exact_inverse():
    h1, h2 = _mk_hop(64, 48, 500, 1), _mk_hop(48, 64, 600, 2)
    filt = EntityFilterOp("E1", const_mask=jnp.ones(48, jnp.float32))
    p = _mk_plan([_seed(), h1, filt, h2, GroupOp("E2", 64)])
    u = unfuse_plan(fuse_plan(p))
    assert u.ops == p.ops  # same member objects, same order


def test_reach_matrix_matches_brute_force():
    h1, h2 = _mk_hop(64, 9000, 6000, 7), _mk_hop(9000, 64, 2 * EDGE_BLOCK, 8)
    reach = _block_reach(h1, h2)
    dst1 = np.asarray(h1.dst_ids)
    smin2, smax2 = np.asarray(h2.block_src_min), np.asarray(h2.block_src_max)
    nb1, nb2 = reach.shape
    assert nb1 == active.n_edge_blocks(dst1.shape[0])
    assert nb2 == smin2.shape[0]
    for b1 in range(nb1):
        vals = dst1[b1 * EDGE_BLOCK:(b1 + 1) * EDGE_BLOCK]
        for b2 in range(nb2):
            want = bool(((vals >= smin2[b2]) & (vals <= smax2[b2])).any())
            assert reach[b1, b2] == want, (b1, b2)


# ---------------------------------------------------------------------------
# Kernel level: fused vs unfused vs oracle, bit-identical
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def chain():
    """Two-hop chain spanning several edge blocks: hop1 E0→E1, hop2 E1→E2.
    hop2's length is deliberately not block-aligned (pad-edge handling)."""
    rng = np.random.default_rng(11)
    n0, n1, n2 = 512, 300, 256
    E1, E2 = 2 * EDGE_BLOCK, 2 * EDGE_BLOCK + 1000
    src1 = np.sort(rng.integers(0, n0, E1)).astype(np.int32)
    dst1 = rng.integers(0, n1, E1).astype(np.int32)
    m1 = rng.integers(1, 8, E1).astype(np.float32)
    src2 = np.sort(rng.integers(0, n1, E2)).astype(np.int32)
    dst2 = rng.integers(0, n2, E2).astype(np.int32)
    m2 = rng.integers(1, 8, E2).astype(np.float32)
    mask = (rng.random(n1) < 0.7).astype(np.float32)
    return dict(n0=n0, n1=n1, n2=n2, src1=src1, dst1=dst1, m1=m1,
                src2=src2, dst2=dst2, m2=m2, mask=mask)


def _operands(c, packed: bool):
    """(hop1, hop2, ref-kwargs) with dense or bit-packed dst/measure columns."""
    b1 = active.block_ranges(c["src1"])
    b2 = active.block_ranges(c["src2"])
    reach = _reach_np(c["dst1"], *b2)
    if not packed:
        h1 = FusedHopOperands(c["src1"], c["dst1"], c["m1"], None, c["n1"],
                              m_mode="dense", blocks=b1)
        h2 = FusedHopOperands(c["src2"], c["dst2"], c["m2"], None, c["n2"],
                              m_mode="dense", blocks=b2, reach=reach)
        rk = dict(dst1_width=0, m1_mode="dense", m1_width=0,
                  dst2_width=0, m2_mode="dense", m2_width=0)
        return h1, h2, rk
    w1 = int(c["n1"] - 1).bit_length()
    w2 = int(c["n2"] - 1).bit_length()
    mw = 3  # measures are < 8
    h1 = FusedHopOperands(
        c["src1"], _pack_words(c["dst1"], w1), _pack_words(c["m1"].astype(np.int64), mw),
        None, c["n1"], dst_width=w1, m_mode="packed", m_width=mw, blocks=b1,
    )
    h2 = FusedHopOperands(
        c["src2"], _pack_words(c["dst2"], w2), _pack_words(c["m2"].astype(np.int64), mw),
        None, c["n2"], dst_width=w2, m_mode="packed", m_width=mw, blocks=b2,
        reach=reach,
    )
    rk = dict(dst1_width=w1, m1_mode="packed", m1_width=mw,
              dst2_width=w2, m2_mode="packed", m2_width=mw)
    return h1, h2, rk


def _reach_np(dst1, smin2, smax2):
    nb1 = active.n_edge_blocks(dst1.shape[0])
    smin2, smax2 = np.asarray(smin2), np.asarray(smax2)
    reach = np.zeros((nb1, smin2.shape[0]), bool)
    for b1 in range(nb1):
        vals = dst1[b1 * EDGE_BLOCK:(b1 + 1) * EDGE_BLOCK]
        reach[b1] = [((vals >= lo) & (vals <= hi)).any()
                     for lo, hi in zip(smin2, smax2)]
    return reach


def _w(n, sl, op, B=None):
    shape = (n,) if B is None else (B, n)
    w = np.full(shape, ZERO[op], np.float32)
    if B is None:
        w[sl] = 2.0
    else:
        for b in range(B):
            w[b, b * 16:(b * 16) + 8] = 2.0
    return w


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("skip", ["off", "on", "auto"])
def test_fused_two_hop_bit_identical(chain, op, skip):
    c = chain
    w = _w(c["n0"], slice(0, 24), op)
    for packed in (False, True):
        h1, h2, rk = _operands(c, packed)
        for binarize in (False, True):
            want = np.asarray(ref.fragment_spmv_fused_ref(
                jnp.asarray(w), h1.src_ids, h1.dst, h1.measure, None,
                h2.src_ids, h2.dst, h2.measure, None, c["mask"],
                n_mid=c["n1"], n_dst=c["n2"], op=op, mid_binarize=binarize, **rk,
            ))
            got = np.asarray(ops.fragment_spmv_fused(
                w, h1, h2, c["mask"], op=op, mid_binarize=binarize,
                fusion="on", block_skipping=skip,
            ))
            np.testing.assert_array_equal(
                got, want, err_msg=f"packed={packed} binarize={binarize}"
            )
            off = np.asarray(ops.fragment_spmv_fused(
                w, h1, h2, c["mask"], op=op, mid_binarize=binarize,
                fusion="off", block_skipping=skip,
            ))
            np.testing.assert_array_equal(off, want)


@pytest.mark.parametrize("op", OPS)
def test_fused_batched_bit_identical(chain, op):
    c = chain
    B = 8
    W = _w(c["n0"], None, op, B=B)
    for packed in (False, True):
        h1, h2, rk = _operands(c, packed)
        want = np.asarray(ref.fragment_spmm_fused_ref(
            jnp.asarray(W), h1.src_ids, h1.dst, h1.measure, None,
            h2.src_ids, h2.dst, h2.measure, None, c["mask"],
            n_mid=c["n1"], n_dst=c["n2"], op=op, mid_binarize=False, **rk,
        ))
        got = np.asarray(ops.fragment_spmm_fused(
            W, h1, h2, c["mask"], op=op, fusion="on", block_skipping="auto",
        ))
        np.testing.assert_array_equal(got, want, err_msg=f"packed={packed}")


def test_fused_degenerate_one_hop(chain):
    c = chain
    w = _w(c["n0"], slice(0, 24), "sum")
    mask1 = (np.random.default_rng(3).random(c["n1"]) < 0.5).astype(np.float32)
    for packed in (False, True):
        h1, _, rk = _operands(c, packed)
        want = np.asarray(ref.fragment_spmv_fused_ref(
            jnp.asarray(w), h1.src_ids, h1.dst, h1.measure, None,
            None, None, None, None, mask1,
            n_mid=c["n1"], n_dst=c["n1"], op="sum",
            dst1_width=rk["dst1_width"], m1_mode=rk["m1_mode"],
            m1_width=rk["m1_width"],
        ))
        for skip in ("off", "on", "auto"):
            got = np.asarray(ops.fragment_spmv_fused(
                w, h1, None, mask1, op="sum", fusion="on", block_skipping=skip,
            ))
            np.testing.assert_array_equal(got, want)


def test_fused_inside_jit_traced_tier(chain):
    import jax

    c = chain
    h1, h2, rk = _operands(c, False)
    want = np.asarray(ref.fragment_spmv_fused_ref(
        jnp.asarray(_w(c["n0"], slice(0, 24), "sum")), h1.src_ids, h1.dst,
        h1.measure, None, h2.src_ids, h2.dst, h2.measure, None, c["mask"],
        n_mid=c["n1"], n_dst=c["n2"], op="sum", **rk,
    ))

    @jax.jit
    def f(w):
        return ops.fragment_spmv_fused(
            w, h1, h2, c["mask"], op="sum", fusion="on", block_skipping="auto",
        )

    np.testing.assert_array_equal(np.asarray(f(_w(c["n0"], slice(0, 24), "sum"))), want)


def test_auto_fusion_respects_vmem_budget(chain, monkeypatch):
    c = chain
    assert not ops._fusion_unfusable("auto", c["n1"], 1)
    monkeypatch.setattr(ops, "FUSED_VMEM_BUDGET_BYTES", 4 * c["n1"] - 1)
    assert ops._fusion_unfusable("auto", c["n1"], 1)
    assert not ops._fusion_unfusable("on", c["n1"], 1)  # 'on' forces fused
    # over budget, auto degrades to the unfused composition — same bits
    h1, h2, rk = _operands(c, False)
    w = _w(c["n0"], slice(0, 24), "sum")
    want = np.asarray(ops.fragment_spmv_fused(
        w, h1, h2, c["mask"], op="sum", fusion="off", block_skipping="auto",
    ))
    got = np.asarray(ops.fragment_spmv_fused(
        w, h1, h2, c["mask"], op="sum", fusion="auto", block_skipping="auto",
    ))
    np.testing.assert_array_equal(got, want)


def test_fused_rejects_unknown_modes(chain):
    from repro.robust.errors import ValidationError

    c = chain
    h1, h2, _ = _operands(c, False)
    w = _w(c["n0"], slice(0, 8), "sum")
    with pytest.raises(ValidationError, match="fusion"):
        ops.fragment_spmv_fused(w, h1, h2, op="sum", fusion="bogus")
    with pytest.raises(ValidationError, match="block_skipping"):
        ops.fragment_spmv_fused(w, h1, h2, op="sum", block_skipping="bogus")


# ---------------------------------------------------------------------------
# Engine surface
# ---------------------------------------------------------------------------


Q_SCORE = """
SELECT dt2.Doc, {agg}
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.ID
"""

AGG_CALLS = {
    "SUM": "SUM(dt1.Fre * dt2.Fre)", "COUNT": "COUNT(*)",
    "MIN": "MIN(dt1.Fre * dt2.Fre)", "MAX": "MAX(dt1.Fre * dt2.Fre)",
    "AVG": "AVG(dt1.Fre * dt2.Fre)", "EXISTS": "EXISTS(*)",
}


@pytest.fixture(scope="module")
def pm():
    return SG.make_pubmed(n_docs=1500, n_terms=80, n_authors=400, seed=5)


@pytest.fixture(scope="module")
def engine(pm):
    return GQFastEngine(GQFastDatabase(pm, account_space=False))


@pytest.fixture(scope="module")
def engine_dense(pm):
    return GQFastEngine(
        GQFastDatabase(pm, account_space=False, device_encodings="dense")
    )


@pytest.mark.parametrize("agg", list(AGG_CALLS))
def test_engine_fused_matches_unfused_all_aggs(engine, agg):
    q = Q_SCORE.format(agg=AGG_CALLS[agg])
    on = engine.prepare(q, fusion="on")
    off = engine.prepare(q, fusion="off")
    assert has_fused(on.phys) and not has_fused(off.phys)
    np.testing.assert_array_equal(on(d0=7), off(d0=7))
    assert (np.asarray(off(d0=7)) != 0).any(), "degenerate test: empty result"


@pytest.mark.parametrize("skip", ["off", "on", "auto"])
def test_engine_fused_matches_unfused_skip_modes(engine, skip):
    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    on = engine.prepare(q, block_skipping=skip, fusion="on")
    off = engine.prepare(q, block_skipping=skip, fusion="off")
    np.testing.assert_array_equal(on(d0=7), off(d0=7))


def test_engine_fused_batched_matches_unfused(engine):
    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    d0 = np.arange(8)
    on = engine.prepare(q, fusion="on").execute_batch(d0=d0)
    off = engine.prepare(q, fusion="off").execute_batch(d0=d0)
    np.testing.assert_array_equal(on, off)


def test_engine_dense_encoding_fused(engine_dense):
    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    on = engine_dense.prepare(q, fusion="on")
    assert has_fused(on.phys)
    np.testing.assert_array_equal(
        on(d0=7), engine_dense.prepare(q, fusion="off")(d0=7)
    )


def test_engine_four_hop_chain_fuses_pairwise(engine):
    # QUERY_AS: hops 1-2 fuse; the factor filter after hop 3 breaks the rest
    pq = engine.prepare(SG.QUERY_AS, fusion="on")
    regions = [op for op in pq.phys.ops if isinstance(op, FusedHopOp)]
    assert len(regions) == 1 and len(regions[0].hops) == 2
    np.testing.assert_array_equal(
        pq(a0=2), engine.prepare(SG.QUERY_AS, fusion="off")(a0=2)
    )


def test_distributed_and_fragment_loop_stay_unfused(pm):
    from repro.launch.mesh import make_mesh

    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    db = GQFastDatabase(pm, account_space=False)
    dist = GQFastEngine(db, mesh=make_mesh((1,), ("data",)))
    assert not has_fused(dist.prepare(q).phys)
    floop = GQFastEngine(db, strategy="fragment_loop")
    assert not has_fused(floop.prepare(SG.QUERY_SD).phys)


def test_prepare_rejects_unknown_fusion(engine):
    from repro.robust.errors import ValidationError

    with pytest.raises(ValidationError, match="fusion"):
        engine.prepare(Q_SCORE.format(agg=AGG_CALLS["SUM"]), fusion="bogus")


def test_fusion_modes_are_distinct_cache_entries(engine):
    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    on = engine.prepare(q, fusion="on")
    assert engine.prepare(q, fusion="off") is not on
    assert engine.prepare(q, fusion="on") is on


def test_explain_reports_fusion(engine):
    q = Q_SCORE.format(agg=AGG_CALLS["SUM"])
    text = engine.prepare(q, fusion="on").explain()
    assert "fusion: on" in text
    assert "fused region:" in text and "Hop(" in text
    assert "FusedHopOp" in text


def test_profile_fused_plan_covers_all_hops(engine):
    # a fused plan still reports one HopProfile per member hop, and the
    # region's single span carries the member list
    pq = engine.prepare(Q_SCORE.format(agg=AGG_CALLS["SUM"]), fusion="on")
    prof = pq.profile(reps=1, d0=7)
    assert len(prof.hops) == 2
    assert len(prof.ops) == len(pq.phys.ops)
    fused_ops = [o for o in prof.ops if o.meta.get("fused")]
    assert fused_ops and fused_ops[0].meta.get("members")
