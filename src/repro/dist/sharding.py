"""Sharding-spec derivation for the arch-config families (DESIGN.md §6).

One rule table per family maps parameter/batch leaf *names* to PartitionSpecs
on the production mesh axes — ``pod`` (DCN data parallel), ``data`` (FSDP) and
``model`` (tensor parallel). Every spec goes through :func:`_filter` before it
touches a NamedSharding, which (a) drops axis names the current mesh doesn't
have and (b) drops an axis whenever it doesn't divide the dimension — so the
same rule table serves the 1-device smoke tests, the 256-chip pod and the
512-chip multi-pod mesh (same degrade-gracefully contract as
``models.common.shard_hint``).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP = ("pod", "data")  # fully-sharded data-parallel axes
EDGE = ("data", "model")  # flat edge/candidate axes (counts padded to 512)


def _filter(mesh, spec, shape=None):
    """Adapt a PartitionSpec to ``mesh``: drop absent axis names, collapse
    single-axis tuples, and (when ``shape`` is given) drop any axis whose
    total size doesn't divide the dimension."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names)))
    out = []
    for i, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((s,) if isinstance(s, str) else s) if a in names)
        if not axes:
            out.append(None)
            continue
        n = 1
        for a in axes:
            n *= sizes[a]
        if shape is not None and shape[i] % n != 0:
            out.append(None)
            continue
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def named(mesh, spec, shape=None) -> NamedSharding:
    return NamedSharding(mesh, _filter(mesh, spec, shape))


def replicated(tree, mesh):
    return jax.tree.map(lambda _: named(mesh, P()), tree)


def _leaf_name(path) -> str:
    """Last dict key on a tree path (param name; moments mirror the params,
    so 'm'/'v' wrappers and tuple indices are skipped by taking the last)."""
    name = ""
    for k in path:
        if hasattr(k, "key"):
            name = str(k.key)
    return name


def _shard_by_name(tree, mesh, spec_fn):
    def one(path, leaf):
        spec = spec_fn(_leaf_name(path), leaf.shape)
        full = tuple(spec) + (None,) * (len(leaf.shape) - len(spec))
        return named(mesh, P(*full[: len(leaf.shape)]), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

# name → spec over the *parameter* dims; layer-stacked leaves carry a leading
# (L, …) dim which is never sharded (scan carries over it)
_LM_RULES = {
    # attention: FSDP on d_model, tensor parallel on (kv-)heads
    "wq": (None, FSDP, "model", None),
    "wk": (None, FSDP, "model", None),
    "wv": (None, FSDP, "model", None),
    "wo": (None, "model", None, FSDP),
    "bq": (None, "model", None),
    "bk": (None, "model", None),
    "bv": (None, "model", None),
    # dense mlp: tensor parallel on d_ff
    "w_gate": (None, FSDP, "model"),
    "w_up": (None, FSDP, "model"),
    "w_down": (None, "model", FSDP),
    # MoE: experts over model, FSDP inside the expert
    "router": (None, FSDP, None),
    "e_gate": (None, "model", FSDP, None),
    "e_up": (None, "model", FSDP, None),
    "e_down": (None, "model", None, FSDP),
    # embeddings / head: vocab over FSDP, model over d
    "embed": (FSDP, "model"),
    "lm_head": (FSDP, "model"),
    # norms
    "ln1": (None, FSDP),
    "ln2": (None, FSDP),
    "ln_f": (FSDP,),
    # int8-blocked optimizer moments ([nb, 256] + per-block scales)
    "q": (EDGE, None),
    "s": (EDGE,),
}


def lm_param_spec(path: str, shape, mesh, n_kv_heads: int = 1) -> P:
    """Unfiltered spec for one LM parameter; ``path`` is '/'-joined tree keys.
    ``n_kv_heads`` documents the head-dim divisibility contract — the actual
    check happens in :func:`_filter` against the concrete shape."""
    name = path.split("/")[-1]
    spec = _LM_RULES.get(name, (None,) * len(shape))
    full = tuple(spec) + (None,) * (len(shape) - len(spec))
    return P(*full[: len(shape)])


def lm_state_shardings(tree, mesh, n_kv_heads: int = 1):
    """Shardings for params or (params, opt) trees: moments mirror the param
    layout (leaf names repeat under 'm'/'v'); scalars replicate."""
    return _shard_by_name(
        tree, mesh, lambda name, shape: lm_param_spec(name, shape, mesh, n_kv_heads)
    )


def lm_batch_shardings(tree, mesh):
    """Token batches: batch dim over (pod, data), sequence dim replicated."""
    return _shard_by_name(tree, mesh, lambda name, shape: (FSDP,))


def kv_cache_shardings(cache, mesh, n_kv_heads: int = 1):
    """KV cache [L, B, S, H_kv, hd]: batch over (pod, data), heads over model
    (dropped by the filter when model ∤ H_kv — the GQA small-head case)."""
    return _shard_by_name(
        tree=cache, mesh=mesh,
        spec_fn=lambda name, shape: (None, FSDP, None, "model", None),
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_input_shardings(batch, mesh):
    """Edge arrays (padded to 512) shard over data×model; node/graph arrays
    over data when divisible, else replicate (the filter decides)."""
    return _shard_by_name(
        batch, mesh,
        lambda name, shape: (EDGE,) if name.startswith("edge_") else (("data",),),
    )


# ---------------------------------------------------------------------------
# Recsys family
# ---------------------------------------------------------------------------


def recsys_state_shardings(tree, mesh):
    """Embedding tables row-sharded over model (the big-vocab lever); the tiny
    MLP towers and their moments replicate."""

    def spec(name, shape):
        if name.endswith("_emb"):
            return ("model", None)
        if name == "q":
            return (EDGE, None)
        if name == "s":
            return (EDGE,)
        return ()

    return _shard_by_name(tree, mesh, spec)


def recsys_batch_shardings(batch, mesh):
    """Request batches over (pod, data); the flat retrieval candidate array
    (padded to 512) over data×model."""
    return _shard_by_name(
        batch, mesh,
        lambda name, shape: (EDGE,) if name == "cand_items" else (FSDP,),
    )
