"""Lowering golden tests: each benchmark query shape compiles to a known
physical-op sequence, with ref resolution / seed-scalar capture / constant
condition masks done at lower time (DESIGN.md §2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import GQFastDatabase
from repro.core.lower import (
    EntityFilterOp,
    GroupOp,
    HopOp,
    LCol,
    LParam,
    SeedOp,
    lower,
)
from repro.core.planner import plan_query
from repro.core.sql import parse
from repro.data import synth_graph as SG


@pytest.fixture(scope="module")
def db():
    return GQFastDatabase(
        SG.make_pubmed(n_docs=300, n_terms=40, n_authors=100), account_space=False
    )


def _lower(db, sql):
    return lower(db.device, plan_query(db.schema, parse(sql)))


def test_sd_signature(db):
    phys = _lower(db, SG.QUERY_SD)
    assert phys.op_signature() == [
        "Seed(Document, ids)",
        "Hop(DT.Doc->Term)",
        "Hop(DT.Term->Document)",
        "Group(Document)",
    ]
    assert phys.agg == "count" and phys.param_names == ("d0",)


def test_fsd_signature_and_seed_scalars(db):
    phys = _lower(db, SG.QUERY_FSD)
    assert phys.op_signature() == [
        "Seed(Document, ids)",
        "Hop(DT.Doc->Term;measure)",
        "Hop(DT.Term->Document;measure)",
        "EntityFilter(Document;factor)",
        "Group(Document)",
    ]
    seed = phys.ops[0]
    # d1.Year referenced downstream → captured as a seed-scalar column
    assert ("d1", "Year") in seed.scalars
    assert seed.scalars[("d1", "Year")].array.shape[0] == db.schema.domain_size(
        "Document"
    )


def test_as_signature(db):
    phys = _lower(db, SG.QUERY_AS)
    assert phys.op_signature() == [
        "Seed(Author, ids)",
        "Hop(DA.Author->Document)",
        "Hop(DT.Doc->Term;measure)",
        "Hop(DT.Term->Document;measure)",
        "EntityFilter(Document;factor)",
        "Hop(DA.Doc->Author)",
        "Group(Author)",
    ]
    assert phys.agg == "sum" and phys.out_dom == db.schema.domain_size("Author")


def test_ad_mask_seed_and_semijoin(db):
    phys = _lower(db, SG.QUERY_AD)
    assert phys.op_signature() == [
        "Seed(Document, mask[2])",
        "Hop(DA.Doc->Author;semijoin)",
        "Group(Author)",
    ]
    seed = phys.ops[0]
    # each IN-INTERSECT chain lowers to its own mask-producing sub-program
    for prog in seed.programs:
        assert prog.agg is None
        assert prog.op_signature()[-1] == "Group(None)"


def test_recent_authors_degree_filter_and_param_conds(db):
    phys = _lower(db, SG.QUERY_RECENT_AUTHORS)
    assert phys.op_signature() == [
        "Seed(Document, mask[2])",
        "Hop(DA.Doc->Author;semijoin)",
        "Group(None)",
    ]
    seed = phys.ops[0]
    # Year > :y is parameter-dependent → stays a residual LCond row
    assert len(seed.param_conds) == 1
    assert seed.param_conds[0].op == ">" and isinstance(
        seed.param_conds[0].value, LParam
    )
    # the third chain projects da.Doc → its sub-program ends in a degree filter
    sigs = [p.op_signature() for p in seed.programs]
    assert any("DegreeFilter(DA.Doc)" in s for s in sigs)


def test_const_conds_prebuilt_at_lower_time(db):
    # constant predicate → a concrete 0/1 mask baked into the op, no residue
    sql = """SELECT da.Author, COUNT(*) FROM DA da WHERE da.Doc IN
             (SELECT d.ID FROM Document d WHERE d.Year > 2000)
             GROUP BY da.Author"""
    phys = _lower(db, sql)
    seed = phys.ops[0]
    assert seed.param_conds == () and seed.const_mask is not None
    year = db.schema.entities["Document"].attributes["Year"]
    np.testing.assert_array_equal(
        np.asarray(seed.const_mask), (year > 2000).astype(np.float32)
    )


def test_measure_refs_bound_to_columns(db):
    phys = _lower(db, SG.QUERY_FSD)
    hop = next(op for op in phys.ops if isinstance(op, HopOp) and op.measure)
    cols = []

    def walk(e):
        if isinstance(e, LCol):
            cols.append(e)
        for attr in ("left", "right"):
            if hasattr(e, attr):
                walk(getattr(e, attr))
        for a in getattr(e, "args", ()):
            walk(a)

    walk(hop.measure)
    assert cols, "hop measure must reference at least one bound column"
    for c in cols:
        assert c.key[0] == "edge" and isinstance(c.array, jnp.ndarray)
        assert c.array.shape[0] == hop.src_ids.shape[0]


def test_agg_threading(db):
    for agg in ("MIN", "MAX", "AVG"):
        sql = f"""SELECT dt2.Doc, {agg}(dt1.Fre * dt2.Fre)
                  FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
                  WHERE dt1.Doc = :d0 GROUP BY dt2.Doc"""
        assert _lower(db, sql).agg == agg.lower()
    sql = """SELECT dt2.Doc, EXISTS(*)
             FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
             WHERE dt1.Doc = :d0 GROUP BY dt2.Doc"""
    phys = _lower(db, sql)
    assert phys.agg == "exists"
    # EXISTS(*) carries no score expression: hops stay measure-free
    assert all(op.measure is None for op in phys.ops if isinstance(op, HopOp))
