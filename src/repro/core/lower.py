"""Lowering: ChainPlan → linear physical IR (DESIGN.md §2).

The normalized chain plan is *logical*: its expressions hold symbolic
``Ref(var, attr)`` nodes and its predicate conditions name entity attributes.
Every execution strategy used to re-resolve those against the device DB inside
the traced function — measure-column lookups, seed-scalar capture and constant
condition-mask construction all re-ran on every prepare/trace. This pass does
that binding exactly once, producing a :class:`PhysicalPlan`:

  * a flat tuple of typed ops — ``SeedOp → (HopOp | EntityFilterOp |
    DegreeFilterOp)* → GroupOp`` — with device arrays (edge lists, measure
    columns, attribute columns, degree vectors) attached to the op that needs
    them;
  * expressions rewritten into *lowered* form (:data:`LExpr`): every Ref is
    replaced by an :class:`LCol` bound to its concrete column (plus a symbolic
    key so the edge-sharded distributed strategy can re-route the same IR
    through its shard_map argument trees) or an :class:`LSeedScalar`;
  * predicate masks over entity domains split into a prebuilt constant mask
    (all non-parameter conditions, evaluated here, once) and a residual list
    of parameter-dependent :class:`LCond` rows evaluated per execute.

The strategies in :mod:`repro.core.executor` are thin interpreters over this
IR; none of them touches :class:`repro.core.algebra.ChainPlan` again.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Union

import jax.numpy as jnp

from ..robust.errors import ExecutionError, PlanError
from ..storage import DenseColumn
from .algebra import (
    BinOp,
    Call,
    ChainPlan,
    Const,
    ConstCond,
    EntityStep,
    Expr,
    Param,
    Ref,
    RelHop,
    SeedIds,
    SeedMask,
    expr_refs,
)

# ---------------------------------------------------------------------------
# Lowered expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LConst:
    value: float


@dataclass(frozen=True)
class LParam:
    name: str


@dataclass(eq=False)
class LCol:
    """A concrete column: per-edge measure or per-entity attribute.

    ``key`` is the symbolic address — ``('edge', table, src_key, attr)`` or
    ``('attr', entity, attr)`` — used by the distributed strategy to fetch the
    same column from its shard_map argument trees instead of the closure.

    ``col`` is the bound :class:`repro.storage.DeviceColumn`: per-edge measures
    inherit the index's device encoding (dense / packed / dict-packed), entity
    attributes are always dense. The frontier strategy inspects ``col`` to fuse
    single-column packed measures into the hop kernel; every other consumer
    reads ``array``, which decodes on demand (free for dense columns)."""

    key: tuple
    col: Any  # repro.storage.DeviceColumn

    @property
    def array(self):
        return self.col.materialize()


@dataclass(eq=False)
class LSeedScalar:
    """Seed-entity attribute (e.g. d1.Year): a scalar once the seed id is
    known. Carries the full attribute column; execute gathers ``array[sid]``."""

    key: tuple  # ('attr', entity, attr)
    array: Any


@dataclass(frozen=True)
class LBin:
    op: str  # + - * /
    left: "LExpr"
    right: "LExpr"


@dataclass(frozen=True)
class LCall:
    fn: str  # abs
    args: tuple


LExpr = Union[LConst, LParam, LCol, LSeedScalar, LBin, LCall]


def eval_lexpr(e: LExpr, params: dict, scalars: dict, col):
    """Evaluate a lowered expression. ``col(LCol)`` supplies the column values
    (whole array for vector strategies, one element for the scalar strategy);
    ``scalars`` maps LSeedScalar keys to captured per-execution scalars."""
    if isinstance(e, LConst):
        return e.value
    if isinstance(e, LParam):
        return params[e.name]
    if isinstance(e, LCol):
        return col(e)
    if isinstance(e, LSeedScalar):
        return scalars[e.key]
    if isinstance(e, LBin):
        l = eval_lexpr(e.left, params, scalars, col)
        r = eval_lexpr(e.right, params, scalars, col)
        return {"+": l + r, "-": l - r, "*": l * r, "/": l / r}[e.op]
    if isinstance(e, LCall):
        args = [eval_lexpr(a, params, scalars, col) for a in e.args]
        if e.fn == "abs":
            return jnp.abs(args[0])
        raise ExecutionError(
            f"unknown function {e.fn} in lowered expression",
            retryable=False, fn=e.fn,
        )
    raise ExecutionError(
        f"unknown lowered expression node {type(e).__name__}",
        retryable=False, node=type(e).__name__,
    )


@dataclass(eq=False)
class LCond:
    """One parameter-dependent predicate row: col ⟨op⟩ value."""

    key: tuple  # ('attr', entity, attr)
    array: Any  # the attribute column
    op: str  # = > < >= <=
    value: Any  # LParam | number

    def mask(self, params: dict, col) -> jnp.ndarray:
        c = col(self)
        v = params[self.value.name] if isinstance(self.value, LParam) else self.value
        return {
            "=": c == v, ">": c > v, "<": c < v, ">=": c >= v, "<=": c <= v,
        }[self.op]


# ---------------------------------------------------------------------------
# Physical ops
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class SeedOp:
    """Establish the initial frontier over ``entity``'s domain: either explicit
    ids (constants / parameters) or the ∧ of lowered sub-programs and entity
    predicates (IN-INTERSECT context mask). Also owns the seed-scalar capture:
    attribute columns whose ``[seed_id]`` element feeds downstream exprs."""

    entity: str
    dom: int
    var: str | None = None
    ids: tuple | None = None  # elements: int | LParam — None ⇒ mask seed
    programs: tuple = ()  # lowered sub-chain PhysicalPlans (bool semiring)
    const_mask: Any | None = None  # prebuilt ∧ of non-param entity conds
    param_conds: tuple = ()  # LCond, evaluated per execute
    scalars: dict = field(default_factory=dict)  # (var, attr) → LSeedScalar


@dataclass(eq=False)
class HopOp:
    """One ⋈/⋉ through I_{table.src_key}: gather ⊗ measure → scatter-⊕.

    ``dst_col`` is the index's device dst column (any
    :class:`repro.storage.DeviceColumn` kind); the frontier strategy streams
    packed words straight into the decode-fused kernel, and ``dst_ids``
    decodes on demand for strategies without a packed path."""

    table: str
    src_key: str
    dst_entity: str
    dom_dst: int
    indptr: Any
    src_ids: Any
    dst_col: Any  # repro.storage.DeviceColumn
    measure: LExpr | None = None
    semijoin: bool = False
    # per-block [src_min, src_max] skip metadata (DeviceIndex.block_src_*);
    # None when the index was built without it → hop always full-scans
    block_src_min: Any = None
    block_src_max: Any = None

    @property
    def dst_ids(self):
        return self.dst_col.materialize()


@dataclass(eq=False)
class DegreeFilterOp:
    """Existence projection of the hop's *source* side: frontier ∧ degree>0."""

    table: str
    src_key: str
    degrees: Any


@dataclass(eq=False)
class EntityFilterOp:
    """Per-domain elementwise ⊗-factor and/or predicate mask on an entity."""

    entity: str
    factor: LExpr | None = None
    const_mask: Any | None = None
    param_conds: tuple = ()


@dataclass(eq=False)
class GroupOp:
    """Final γ: dense accumulator over ``entity`` (None ⇒ membership mask)."""

    entity: str | None
    dom: int


@dataclass(eq=False)
class FusedHopOp:
    """A pipelined region (DESIGN.md §Pipelined fusion): up to two adjacent
    HopOps plus any interleaved constant-mask EntityFilterOps and the trailing
    GroupOp, executed as ONE kernel pass. The first hop accumulates its output
    frontier in a VMEM scratch accumulator, the mid filter mask is applied
    in-register, and the second hop streams its edge blocks against the
    VMEM-resident frontier — the intermediate ``[n_mid]`` vector never
    round-trips through HBM.

    ``members`` is the original op sub-sequence (order preserved), so any
    interpreter without a fused kernel path replays them one by one and gets
    bit-identical results. ``reach`` is an optional host-precomputed
    ``bool[nb1, nb2]`` block-to-block reachability matrix: hop2's active block
    list is derived from hop1's by OR-ing the rows of hop1's active blocks
    (conservative: a skipped hop2 block provably reads only ⊕-identity)."""

    members: tuple  # (HopOp | EntityFilterOp | GroupOp, ...)
    n_mid: int  # intermediate entity domain (hop1.dom_dst)
    reach: Any = None  # np.bool_[nb1, nb2] | None

    @property
    def hops(self) -> tuple:
        return tuple(m for m in self.members if isinstance(m, HopOp))

    @property
    def mid_filters(self) -> tuple:
        """Constant-mask EntityFilterOps between hop1 and hop2 (or after the
        sole hop of a degenerate 1-hop region)."""
        return tuple(m for m in self.members if isinstance(m, EntityFilterOp))

    @property
    def group(self):
        last = self.members[-1]
        return last if isinstance(last, GroupOp) else None


Op = Union[SeedOp, HopOp, DegreeFilterOp, EntityFilterOp, GroupOp, FusedHopOp]


def iter_flat_ops(phys: "PhysicalPlan"):
    """Yield the plan's ops with FusedHopOp regions expanded to their members
    (top level only — SeedOp sub-programs are separate plans)."""
    for op in phys.ops:
        if isinstance(op, FusedHopOp):
            yield from op.members
        else:
            yield op


@dataclass(eq=False)
class PhysicalPlan:
    ops: tuple
    param_names: tuple
    agg: str | None  # sum | count | min | max | avg | exists | None (mask)
    out_dom: int
    source: ChainPlan  # the logical plan this was lowered from

    def op_signature(self) -> list[str]:
        """Golden-test helper: compact one-line-per-op description."""

        def sig(op: Op) -> str:
            if isinstance(op, SeedOp):
                kind = "ids" if op.ids is not None else f"mask[{len(op.programs)}]"
                return f"Seed({op.entity}, {kind})"
            if isinstance(op, HopOp):
                flags = "".join(
                    f for f, c in ((";semijoin", op.semijoin), (";measure", op.measure))
                    if c
                )
                return f"Hop({op.table}.{op.src_key}->{op.dst_entity}{flags})"
            if isinstance(op, DegreeFilterOp):
                return f"DegreeFilter({op.table}.{op.src_key})"
            if isinstance(op, EntityFilterOp):
                flags = "".join(
                    f for f, c in (
                        (";factor", op.factor),
                        (";const_mask", op.const_mask is not None),
                        (";param_conds", op.param_conds),
                    ) if c
                )
                return f"EntityFilter({op.entity}{flags})"
            if isinstance(op, FusedHopOp):
                return "Fused[" + "+".join(sig(m) for m in op.members) + "]"
            return f"Group({op.entity})"

        return [sig(op) for op in self.ops]


# ---------------------------------------------------------------------------
# The lowering pass
# ---------------------------------------------------------------------------


def lower(db, plan: ChainPlan) -> PhysicalPlan:
    """Compile a normalized chain plan against a DeviceDB. ``db`` is
    :class:`repro.core.executor.DeviceDB` (duck-typed: needs ``schema``,
    ``index()`` and ``entity_attrs``)."""
    from .executor import collect_params  # avoid import cycle at module load

    ops: list[Op] = [_lower_seed(db, plan)]
    for s in plan.steps:
        if isinstance(s, RelHop):
            di = db.index(s.table, s.src_key)
            if s.degree_filter:
                ops.append(DegreeFilterOp(s.table, s.src_key, di.degrees))
                continue
            measure = (
                _lower_expr(db, s.measure_expr, s, plan)
                if s.measure_expr is not None else None
            )
            ops.append(HopOp(
                s.table, s.src_key, s.dst_entity,
                db.schema.domain_size(s.dst_entity),
                di.indptr, di.src_ids, di.dst_col,
                measure=measure, semijoin=s.semijoin,
                block_src_min=getattr(di, "block_src_min", None),
                block_src_max=getattr(di, "block_src_max", None),
            ))
        else:  # EntityStep
            factor = (
                _lower_expr(db, s.factor_expr, s, plan)
                if s.factor_expr is not None else None
            )
            const_mask, pconds = _lower_conds(db, s.entity, s.conds)
            ops.append(EntityFilterOp(s.entity, factor, const_mask, pconds))

    out_entity = plan.group_entity
    if out_entity is None:
        out_dom = db.schema.domain_size(_final_entity(plan))
        ops.append(GroupOp(None, out_dom))
    else:
        out_dom = db.schema.domain_size(out_entity)
        ops.append(GroupOp(out_entity, out_dom))
    return PhysicalPlan(
        tuple(ops), tuple(collect_params(plan)), plan.agg, out_dom, plan
    )


def _lower_seed(db, plan: ChainPlan) -> SeedOp:
    seed = plan.seed
    if isinstance(seed, SeedIds):
        raw = seed.ids if isinstance(seed.ids, list) else [seed.ids]
        ids = tuple(LParam(i.name) if isinstance(i, Param) else int(i) for i in raw)
        scalars = (
            _seed_scalar_capture(db, plan, seed) if len(ids) == 1 else {}
        )
        return SeedOp(
            seed.entity, db.schema.domain_size(seed.entity),
            var=seed.var, ids=ids, scalars=scalars,
        )
    # SeedMask: lower each sub-chain into its own program (run under the
    # boolean semiring by the walker) + split the entity conditions
    programs = tuple(lower(db, chain) for chain in seed.chains)
    const_mask, pconds = _lower_conds(db, seed.entity, seed.entity_conds)
    return SeedOp(
        seed.entity, db.schema.domain_size(seed.entity),
        programs=programs, const_mask=const_mask, param_conds=pconds,
    )


def _seed_scalar_capture(db, plan: ChainPlan, seed: SeedIds) -> dict:
    """Columns whose [seed_id] element downstream expressions reference.
    A relationship-variable seed is itself the first hop, so refs to it are
    per-edge measures bound by that step — never scalars."""
    bound = {s.var for s in plan.steps}
    scalars: dict[tuple, LSeedScalar] = {}
    for s in plan.steps:
        e = s.measure_expr if isinstance(s, RelHop) else s.factor_expr
        if e is None:
            continue
        for r in expr_refs(e):
            if r.var == seed.var and r.var not in bound and (r.var, r.attr) not in scalars:
                scalars[(r.var, r.attr)] = LSeedScalar(
                    ("attr", seed.entity, r.attr),
                    db.entity_attrs[(seed.entity, r.attr)],
                )
    return scalars


def _lower_expr(db, e: Expr, step, plan: ChainPlan) -> LExpr:
    """Bind every Ref: step-local refs to the step's columns, seed refs to
    seed-scalar slots. Anything else was rejected by the planner."""
    if isinstance(e, Const):
        return LConst(float(e.value))
    if isinstance(e, Param):
        return LParam(e.name)
    if isinstance(e, Ref):
        if e.var == step.var:
            if isinstance(step, RelHop):
                di = db.index(step.table, step.src_key)
                return LCol(
                    ("edge", step.table, step.src_key, e.attr),
                    di.measure_cols[e.attr],
                )
            return LCol(
                ("attr", step.entity, e.attr),
                DenseColumn(db.entity_attrs[(step.entity, e.attr)]),
            )
        seed = plan.seed
        if isinstance(seed, SeedIds) and e.var == seed.var:
            return LSeedScalar(
                ("attr", seed.entity, e.attr),
                db.entity_attrs[(seed.entity, e.attr)],
            )
        raise PlanError(
            f"unresolvable reference {e.var}.{e.attr} while lowering",
            var=e.var, attr=e.attr, step=type(step).__name__,
        )
    if isinstance(e, BinOp):
        return LBin(e.op, _lower_expr(db, e.left, step, plan),
                    _lower_expr(db, e.right, step, plan))
    if isinstance(e, Call):
        return LCall(e.fn, tuple(_lower_expr(db, a, step, plan) for a in e.args))
    raise PlanError(
        f"unknown expression node {type(e).__name__} while lowering",
        node=type(e).__name__,
    )


def _lower_conds(db, entity: str, conds: list[ConstCond]):
    """Fold all constant-valued conditions into one prebuilt 0/1 mask (this is
    the work that used to rerun inside every traced call); parameter-valued
    conditions stay as LCond rows."""
    const_mask = None
    pconds: list[LCond] = []
    for c in conds:
        col = db.entity_attrs[(entity, c.ref.attr)]
        key = ("attr", entity, c.ref.attr)
        if isinstance(c.value, Param):
            pconds.append(LCond(key, col, c.op, LParam(c.value.name)))
            continue
        m = {
            "=": col == c.value, ">": col > c.value, "<": col < c.value,
            ">=": col >= c.value, "<=": col <= c.value,
        }[c.op].astype(jnp.float32)
        const_mask = m if const_mask is None else const_mask * m
    return const_mask, tuple(pconds)


def _final_entity(plan: ChainPlan) -> str:
    hops = [s for s in plan.steps if isinstance(s, RelHop) and not s.degree_filter]
    return hops[-1].dst_entity if hops else plan.seed.entity
