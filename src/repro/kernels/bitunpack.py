"""Pallas TPU kernel: BCA fragment decode (paper §5 bit-aligned compressed array).

Layout contract (written by ``core.fragments._pack_words``): values are packed
little-endian at ``width`` bits each into a uint32 word stream. The kernel
decodes 1024 values per grid step. Because 1024·width ≡ 0 (mod 32), every
1024-value output block starts and ends word-aligned: the input block is exactly
32·width words and no halo is needed.

TPU mapping: the output block is shaped (32, 32) — 32 groups of 32 values — and
the input block (32, width) words, because every 32 consecutive values consume
exactly ``width`` words with a *fixed* intra-group bit-offset pattern. The two
word operands per output column are therefore **static** column selects
(unrolled slices, no dynamic gather), followed by vectorized shift/mask on the
VPU. This is the TPU-native replacement for the paper's sequential decode loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

GROUP = 32  # values per group; GROUP*width bits = width words
GROUPS_PER_BLOCK = 32  # 1024 values per grid step
BLOCK_VALUES = GROUP * GROUPS_PER_BLOCK


def decode_groups(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """In-kernel group decode: (G, width) uint32 words → (G, GROUP) int32 values.

    Every row holds GROUP consecutive values (GROUP·width bits = width words)
    with a *fixed* intra-group bit-offset pattern, so the two word operands per
    output column are static column selects. Shared by the standalone
    ``bitunpack`` kernel and the decode-fused SpMV (`fragment_spmv_packed`)."""
    # static per-column patterns for one 32-value group
    j = np.arange(GROUP)
    bit0 = j * width
    w_lo = (bit0 // 32).astype(np.int32)  # word holding the low bits
    w_hi = np.minimum(w_lo + 1, width - 1)

    # unrolled static column selects (no dynamic gather on TPU)
    lo = jnp.stack([words[:, int(c)] for c in w_lo], axis=1)  # (G, 32)
    hi = jnp.stack([words[:, int(c)] for c in w_hi], axis=1)
    # bit offsets computed in-kernel (iota), not captured as a constant
    offv = (
        jax.lax.broadcasted_iota(jnp.uint32, (1, GROUP), 1) * jnp.uint32(width)
    ) % jnp.uint32(32)
    shl = (jnp.uint32(32) - offv) & jnp.uint32(31)
    straddle = jnp.where(offv == 0, jnp.uint32(0), hi << shl)
    word = jnp.where(offv == 0, lo, (lo >> offv) | straddle)
    mask = jnp.uint32((1 << width) - 1) if width < 32 else jnp.uint32(0xFFFFFFFF)
    return (word & mask).astype(jnp.int32)


def _kernel(width: int, packed_ref, out_ref):
    out_ref[...] = decode_groups(packed_ref[...], width)


@functools.partial(jax.jit, static_argnames=("width", "count", "interpret"))
def bitunpack(packed: jnp.ndarray, width: int, count: int, interpret: bool = False) -> jnp.ndarray:
    """Decode ``count`` ``width``-bit values from a uint32 word stream."""
    assert 1 <= width <= 32
    n_blocks = max(1, -(-count // BLOCK_VALUES))
    words_needed = n_blocks * GROUPS_PER_BLOCK * width
    pad = words_needed - packed.shape[0]
    if pad > 0:
        packed = jnp.concatenate([packed, jnp.zeros(pad, jnp.uint32)])
    packed2d = packed[:words_needed].reshape(n_blocks * GROUPS_PER_BLOCK, width)

    out = pl.pallas_call(
        functools.partial(_kernel, width),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((GROUPS_PER_BLOCK, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((GROUPS_PER_BLOCK, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * GROUPS_PER_BLOCK, GROUP), jnp.int32),
        interpret=interpret,
    )(packed2d)
    return out.reshape(-1)[:count]
