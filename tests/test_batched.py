"""Batched multi-query execution (DESIGN.md §Batched serving).

``PreparedQuery.execute_batch`` must be *bit-identical* to a Python loop of
single-query calls — the batched SpMM path changes the schedule (one edge
stream serves B frontier rows) but not one float of per-row math. Covered
here: all strategies (frontier SpMM, fragment_loop vmap fallback, 1-device
distributed), all semirings (SUM/COUNT/MIN/MAX/AVG/EXISTS), packed and dense
device encodings, and batch sizes 1/3/64 — 3 exercises the ragged-pad bucket
boundary (pads to 4, pad rows sliced off), 64 an exact bucket. Plus the
kernel-level SpMM-vs-oracle sweep and the execute_batch validation contract.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import GQFastDatabase, GQFastEngine, batch_bucket
from repro.data import synth_graph as SG
from repro.kernels import ops, ref

N_DOCS, N_TERMS, N_AUTHORS = 300, 40, 120

AGG_SQL = """
SELECT dt2.Doc, {agg}
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.Doc
"""


@pytest.fixture(scope="module")
def schema():
    return SG.make_pubmed(
        n_docs=N_DOCS, n_terms=N_TERMS, n_authors=N_AUTHORS, seed=9
    )


@pytest.fixture(scope="module")
def dbs(schema):
    return {
        "packed": GQFastDatabase(schema, account_space=False),
        "dense": GQFastDatabase(schema, account_space=False,
                                device_encodings="dense"),
    }


def _assert_batch_matches_loop(pq, B: int, rng, param_doms: dict[str, int]):
    params = {n: rng.integers(0, dom, size=B) for n, dom in param_doms.items()}
    got = pq.execute_batch(**params)
    loop = np.stack(
        [pq(**{n: int(v[i]) for n, v in params.items()}) for i in range(B)]
    )
    assert got.shape == loop.shape
    assert np.array_equal(got, loop), (
        f"batched != per-query loop at B={B} (max|Δ|="
        f"{np.abs(got - loop).max()})"
    )


# ---------------------------------------------------------------------------
# Kernel level: SpMM vs oracle vs per-row SpMV
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "min", "max", "bool"])
def test_fragment_spmm_matches_spmv_rows(op):
    rng = np.random.default_rng(3)
    B, n_src, n_dst, E = 4, 150, 90, 6000
    W = rng.random((B, n_src)).astype(np.float32)
    src = rng.integers(0, n_src, E).astype(np.int32)
    dst = rng.integers(0, n_dst, E).astype(np.int32)
    m = rng.integers(1, 6, E).astype(np.float32)
    got = np.asarray(ops.fragment_spmm(W, src, dst, m, n_dst, op=op))
    rows = np.stack([
        np.asarray(ops.fragment_spmv(W[b], src, dst, m, n_dst, op=op))
        for b in range(B)
    ])
    assert np.array_equal(got, rows)
    oracle = np.asarray(
        ref.fragment_spmm_ref(jnp.asarray(W), jnp.asarray(src),
                              jnp.asarray(dst), jnp.asarray(m), n_dst, op=op)
    )
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-5)


def test_fragment_spmm_per_row_measures():
    """[B, E] measure streams (seed-scalar-dependent expressions) take the
    vmap'd XLA fallback; each row must equal its own SpMV."""
    rng = np.random.default_rng(4)
    B, n_src, n_dst, E = 3, 80, 60, 2000
    W = rng.random((B, n_src)).astype(np.float32)
    src = rng.integers(0, n_src, E).astype(np.int32)
    dst = rng.integers(0, n_dst, E).astype(np.int32)
    m = rng.random((B, E)).astype(np.float32)
    got = np.asarray(ops.fragment_spmm(W, src, dst, m, n_dst))
    for b in range(B):
        row = np.asarray(ops.fragment_spmv(W[b], src, dst, m[b], n_dst))
        np.testing.assert_allclose(got[b], row, rtol=1e-5, atol=1e-5)


def test_fragment_spmm_empty_relation():
    W = np.ones((2, 5), np.float32)
    e = np.zeros(0, np.int32)
    out = np.asarray(ops.fragment_spmm(W, e, e, np.zeros(0, np.float32), 7))
    assert out.shape == (2, 7) and (out == 0).all()


# ---------------------------------------------------------------------------
# Engine level: every semiring, batched == loop, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("agg", [
    "SUM(dt1.Fre * dt2.Fre)", "COUNT(*)", "MIN(dt2.Fre)", "MAX(dt2.Fre)",
    "AVG(dt2.Fre)", "EXISTS(*)",
])
def test_semirings_batched(dbs, agg):
    eng = GQFastEngine(dbs["packed"], strategy="frontier")
    pq = eng.prepare(AGG_SQL.format(agg=agg))
    rng = np.random.default_rng(1)
    for B in (1, 3, 64):
        _assert_batch_matches_loop(pq, B, rng, {"d0": N_DOCS})


# ---------------------------------------------------------------------------
# Engine level: every strategy × encoding (incl. ragged bucket boundary)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["frontier", "fragment_loop", "auto"])
@pytest.mark.parametrize("enc", ["packed", "dense"])
def test_strategies_batched(dbs, strategy, enc):
    eng = GQFastEngine(dbs[enc], strategy=strategy)
    pq = eng.prepare(SG.QUERY_SD)
    rng = np.random.default_rng(2)
    assert batch_bucket(3) == 4  # B=3 really exercises the ragged pad
    for B in (3, 64):
        _assert_batch_matches_loop(pq, B, rng, {"d0": N_DOCS})


def test_distributed_batched(dbs):
    """1-device mesh: the shard_map body vmaps over the parameter vectors."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    for enc in ("packed", "dense"):
        eng = GQFastEngine(dbs[enc], mesh=mesh)
        pq = eng.prepare(SG.QUERY_SD)
        rng = np.random.default_rng(5)
        _assert_batch_matches_loop(pq, 3, rng, {"d0": N_DOCS})


def test_mask_seed_and_seed_scalar_batched(dbs):
    """AD seeds from an IN-INTERSECT mask (batched sub-programs); FSD carries
    a seed-scalar (d1.Year) into a downstream factor — both must batch."""
    eng = GQFastEngine(dbs["packed"], strategy="frontier")
    rng = np.random.default_rng(6)
    _assert_batch_matches_loop(
        eng.prepare(SG.QUERY_AD), 5, rng, {"t1": N_TERMS, "t2": N_TERMS}
    )
    _assert_batch_matches_loop(eng.prepare(SG.QUERY_FSD), 5, rng, {"d0": N_DOCS})


def test_query_topk_batch(dbs):
    eng = GQFastEngine(dbs["packed"], strategy="frontier")
    ids = [3, 7, 11]
    tops = eng.query_topk_batch(SG.QUERY_SD, k=4, d0=ids)
    assert len(tops) == 3
    for i, top in zip(ids, tops):
        assert top == eng.query_topk(SG.QUERY_SD, k=4, d0=i)


# ---------------------------------------------------------------------------
# execute_batch validation contract
# ---------------------------------------------------------------------------


def test_batch_bucket_policy():
    assert [batch_bucket(b) for b in (1, 2, 3, 5, 8, 9, 64)] == \
        [1, 2, 4, 8, 8, 16, 64]
    assert batch_bucket(65) == 128 and batch_bucket(129) == 192


def test_execute_batch_accepts_lists(dbs):
    eng = GQFastEngine(dbs["packed"], strategy="frontier")
    pq = eng.prepare(SG.QUERY_SD)
    a = pq.execute_batch(d0=[0, 1, 2])
    b = pq.execute_batch(d0=np.asarray([0, 1, 2]))
    assert np.array_equal(a, b)


def test_execute_batch_rejects_bad_inputs(dbs):
    eng = GQFastEngine(dbs["packed"], strategy="frontier")
    pq = eng.prepare(SG.QUERY_AD)
    with pytest.raises(ValueError, match="ragged"):
        pq.execute_batch(t1=[1, 2, 3], t2=[1, 2])
    with pytest.raises(ValueError, match="scalar"):
        pq.execute_batch(t1=5, t2=[1, 2])
    with pytest.raises(TypeError, match="missing"):
        pq.execute_batch(t1=[1, 2])
    with pytest.raises(ValueError, match="empty"):
        pq.execute_batch(t1=[], t2=[])
    with pytest.raises(ValueError, match="1-D"):
        pq.execute_batch(t1=np.zeros((2, 2)), t2=[1, 2])
