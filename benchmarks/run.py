# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: python -m benchmarks.run [table3 table4 ...]

Each module reproduces one paper table/figure (DESIGN.md §8); the roofline
summary reads the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        fig14_pipelining,
        perf_baseline,
        fig15_parallel,
        table3_runtime,
        table4_space,
        table56_denseid,
        table8_encodings,
        table9_decode,
    )

    suites = {
        "table3": table3_runtime.run,
        "table4": table4_space.run,
        "table56": table56_denseid.run,
        "fig14": fig14_pipelining.run,
        "table8": table8_encodings.run,
        "table9": table9_decode.run,
        "fig15": fig15_parallel.run,
        "perf": perf_baseline.run,
    }
    picked = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in picked:
        t0 = time.time()
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    # roofline summary (if dry-run artifacts exist)
    try:
        from repro.roofline.analysis import load_records, roofline_from_record

        for rec in load_records("artifacts/dryrun"):
            if rec.get("status") != "ok" or rec.get("variant"):
                continue
            rl = roofline_from_record(rec)
            print(
                f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},"
                f"{rl.bound_s*1e6:.1f},dominant={rl.dominant}"
            )
    except Exception as e:  # noqa: BLE001
        print(f"roofline/ERROR,0,{e}")


if __name__ == "__main__":
    main()
