"""Synthetic click-stream generator for DIN (Zipf item popularity)."""
from __future__ import annotations

import numpy as np


def make_din_batch(
    batch: int,
    seq_len: int = 100,
    n_items: int = 10_000_000,
    n_users: int = 1_000_000,
    n_candidates: int = 0,
    seed: int = 0,
) -> dict:
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    # Zipf-ish popularity without building a 10M-entry prob table
    def zipf_ids(size):
        u = rng.random(size)
        return np.minimum((n_items ** u).astype(np.int64), n_items - 1)

    hist = zipf_ids((batch, seq_len))
    lengths = rng.integers(5, seq_len + 1, size=batch)
    mask = (np.arange(seq_len)[None, :] < lengths[:, None]).astype(np.float32)
    out = {
        "user": jnp.asarray(rng.integers(0, n_users, batch).astype(np.int32)),
        "hist_items": jnp.asarray(hist.astype(np.int32)),
        "hist_mask": jnp.asarray(mask),
    }
    if n_candidates:
        out["cand_items"] = jnp.asarray(zipf_ids(n_candidates).astype(np.int32))
    else:
        out["cand_item"] = jnp.asarray(zipf_ids(batch).astype(np.int32))
        out["label"] = jnp.asarray(rng.integers(0, 2, batch).astype(np.int32))
    return out
