"""Deterministic, seedable fault injection (DESIGN.md §Robustness).

Mirrors ``obs/trace.py``'s design: the active :class:`FaultPlan` lives in a
:mod:`contextvars` ContextVar, the disabled fast path is one ContextVar read
returning immediately, and activation is a context manager (:class:`active`)
so plans never leak across tests/threads.

Injection sites are plain function calls threaded through the codebase::

    from repro.robust import faults
    faults.fire("ops.fragment_spmv")          # may raise or sleep
    out = faults.corrupt("storage.materialize", out)   # may transform value

Registered sites (the site registry below is the documentation contract —
chaos tests address faults by these names):

    engine.prepare          parse/plan/lower/compile of one query
    ops.fragment_spmv       Pallas SpMV dispatch (single-query hop)
    ops.fragment_spmv_packed    decode-fused SpMV dispatch
    ops.fragment_spmm       Pallas SpMM dispatch (batched hop)
    ops.fragment_spmm_packed    decode-fused SpMM dispatch
    storage.materialize     whole-column decode in the device column store
    snapshot.load           snapshot restore (fire at entry; corrupt applies
                            to each loaded array *before* checksum verify)
    scrub.verify            scrubber encoded-bytes re-read (corrupt emulates
                            at-rest device corruption for one verification)
    runner.execute          one ladder-rung execution attempt
    serve.request           one serve-loop micro-batch

Sites match by exact name or prefix: a spec with ``site="ops."`` fires at
every kernel-dispatch site. Determinism: each :class:`FaultSpec` draws from
its own ``random.Random`` stream seeded by ``(plan_seed, spec_index)``, so a
given (seed, call sequence) always fires the same faults regardless of which
other specs exist.

Modes:

    raise    — raise a retryable :class:`repro.robust.errors.ExecutionError`
               (code ``FAULT_INJECTED``), or a caller-supplied exception.
    delay    — ``time.sleep(delay_ms)``: trips deadlines without failing.
    corrupt  — transform a value flowing through a ``corrupt()`` site
               (default: numeric negation). Corrupt-then-restore by
               construction: the transformation applies to the *returned*
               value only; caches/stored arrays keep the original, so the
               corruption vanishes when the plan deactivates.
"""
from __future__ import annotations

import random
import time
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Callable

from .errors import ExecutionError

_PLAN: ContextVar["FaultPlan | None"] = ContextVar("repro_fault_plan", default=None)

MODES = ("raise", "delay", "corrupt")


@dataclass
class FaultSpec:
    """One fault: where (``site`` exact name or prefix), what (``mode``),
    how often (``prob`` per matching call), and bounds (skip the first
    ``after`` matching calls, fire at most ``max_fires`` times; None ⇒
    unbounded)."""

    site: str
    mode: str = "raise"
    prob: float = 1.0
    delay_ms: float = 0.0
    after: int = 0
    max_fires: int | None = None
    error: Callable[[], BaseException] | None = None
    mutate: Callable[[Any], Any] | None = None
    # runtime state (owned by the enclosing plan)
    calls: int = field(default=0, repr=False)
    fires: int = field(default=0, repr=False)
    _rng: random.Random = field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"fault mode must be one of {MODES}, got {self.mode!r}")

    def matches(self, site: str) -> bool:
        return site == self.site or site.startswith(self.site)

    def should_fire(self) -> bool:
        self.calls += 1
        if self.calls <= self.after:
            return False
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        if self.prob < 1.0 and self._rng.random() >= self.prob:
            return False
        self.fires += 1
        return True


class FaultPlan:
    """A seeded set of :class:`FaultSpec`\\s. Stats (``calls``/``fires`` per
    spec) accumulate while the plan is active — chaos tests assert on them."""

    def __init__(self, seed: int = 0, specs: list[FaultSpec] | None = None):
        self.seed = int(seed)
        self.specs: list[FaultSpec] = []
        for s in specs or []:
            self.add(s)

    def add(self, spec: FaultSpec) -> "FaultPlan":
        spec._rng = random.Random(self.seed * 1_000_003 + len(self.specs))
        self.specs.append(spec)
        return self

    def total_fires(self) -> int:
        return sum(s.fires for s in self.specs)

    def stats(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = {}
        for s in self.specs:
            d = out.setdefault(f"{s.site}:{s.mode}", {"calls": 0, "fires": 0})
            d["calls"] += s.calls
            d["fires"] += s.fires
        return out


def current() -> FaultPlan | None:
    return _PLAN.get()


class active:
    """``with active(plan): ...`` — install a fault plan for the block."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._token = None

    def __enter__(self) -> FaultPlan:
        self._token = _PLAN.set(self.plan)
        return self.plan

    def __exit__(self, exc_type, exc, tb) -> bool:
        _PLAN.reset(self._token)
        return False


def fire(site: str, **ctx: Any) -> None:
    """Raise-or-delay injection point. One ContextVar read when no plan is
    active (the production fast path)."""
    plan = _PLAN.get()
    if plan is None:
        return
    for spec in plan.specs:
        if spec.mode == "corrupt" or not spec.matches(site):
            continue
        if not spec.should_fire():
            continue
        if spec.mode == "delay":
            time.sleep(spec.delay_ms / 1e3)
            continue
        if spec.error is not None:
            raise spec.error()
        raise ExecutionError(
            f"injected fault at {site}", code="FAULT_INJECTED",
            retryable=True, site=site, **ctx,
        )


def corrupt(site: str, value: Any) -> Any:
    """Value-transforming injection point. Returns ``value`` untouched unless
    a corrupt-mode spec matches and fires; the caller must pass the result
    onward without storing it (corrupt-then-restore contract)."""
    plan = _PLAN.get()
    if plan is None:
        return value
    for spec in plan.specs:
        if spec.mode != "corrupt" or not spec.matches(site):
            continue
        if not spec.should_fire():
            continue
        value = spec.mutate(value) if spec.mutate is not None else -value
    return value
