"""Relational schema following the paper's E/R conventions (§4).

* Entity tables: integer dense primary key ``ID`` in [0, h), optional attribute
  columns (measures or FKs capturing many-to-one relationships, e.g. Doc.Journal).
* Relationship tables: exactly two FK columns referencing entity IDs plus any
  number of measure columns.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class EntityTable:
    name: str
    size: int  # domain size h; IDs are the dense range [0, h)
    attributes: dict[str, np.ndarray] = field(default_factory=dict)

    def __post_init__(self):
        for a, col in self.attributes.items():
            assert col.shape[0] == self.size, (self.name, a, col.shape, self.size)


@dataclass
class RelationshipTable:
    name: str
    fk1: str  # attribute name of the first foreign key
    fk2: str
    entity1: str  # referenced entity table names
    entity2: str
    columns: dict[str, np.ndarray] = field(default_factory=dict)  # fk + measure cols

    @property
    def measures(self) -> list[str]:
        return [c for c in self.columns if c not in (self.fk1, self.fk2)]

    @property
    def num_rows(self) -> int:
        return int(self.columns[self.fk1].shape[0])

    def fk_entity(self, fk: str) -> str:
        return self.entity1 if fk == self.fk1 else self.entity2

    def other_fk(self, fk: str) -> str:
        return self.fk2 if fk == self.fk1 else self.fk1


@dataclass
class Schema:
    entities: dict[str, EntityTable]
    relationships: dict[str, RelationshipTable]

    def entity_of(self, table: str, attr: str) -> str:
        """Entity domain an attribute draws its values from (for key attrs)."""
        if table in self.entities:
            return table  # ID attr of an entity table
        rel = self.relationships[table]
        if attr == rel.fk1:
            return rel.entity1
        if attr == rel.fk2:
            return rel.entity2
        raise KeyError(f"{table}.{attr} is not a key attribute")

    def domain_size(self, entity: str) -> int:
        return self.entities[entity].size

    def is_relationship(self, table: str) -> bool:
        return table in self.relationships

    def validate(self) -> None:
        for r in self.relationships.values():
            assert r.entity1 in self.entities and r.entity2 in self.entities
            n = r.num_rows
            for c, col in r.columns.items():
                assert col.shape[0] == n, (r.name, c)
            for fk, ent in ((r.fk1, r.entity1), (r.fk2, r.entity2)):
                col = r.columns[fk]
                assert col.min(initial=0) >= 0
                assert col.max(initial=0) < self.entities[ent].size, (r.name, fk)
