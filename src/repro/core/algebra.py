"""RQNA — Relationship Query Normalized Algebra (paper §4, Fig. 6).

Two levels:
  * the SQL-facing AST (``Query`` with joins / IN-subqueries / INTERSECT /
    GROUP BY), produced by :mod:`repro.core.sql`;
  * the normalized *chain plan* (paper's left-deep RQNA), produced by
    :mod:`repro.core.planner`: a seed over an entity domain, a sequence of
    relationship hops / entity factor steps, and a final single-key γ.

Expressions support the multiplicative score shapes of relationship queries
(products/quotients of measures, entity attributes and constants; ``abs``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Union

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Const:
    value: float


@dataclass(frozen=True)
class Param:
    """Named query parameter (prepare-once / execute-many, paper §3)."""

    name: str


@dataclass(frozen=True)
class Ref:
    var: str
    attr: str


@dataclass(frozen=True)
class BinOp:
    op: str  # + - * /
    left: "Expr"
    right: "Expr"


@dataclass(frozen=True)
class Call:
    fn: str  # abs
    args: tuple["Expr", ...]


Expr = Union[Const, Param, Ref, BinOp, Call]


def expr_refs(e: Expr) -> set[Ref]:
    if isinstance(e, Ref):
        return {e}
    if isinstance(e, BinOp):
        return expr_refs(e.left) | expr_refs(e.right)
    if isinstance(e, Call):
        out: set[Ref] = set()
        for a in e.args:
            out |= expr_refs(a)
        return out
    return set()


def multiplicative_factors(e: Expr) -> list[tuple[Expr, bool]]:
    """Flatten into (factor, inverted) terms: e = Π f_i^(±1). Non-multiplicative
    structure stays inside a single factor."""
    if isinstance(e, BinOp) and e.op == "*":
        return multiplicative_factors(e.left) + multiplicative_factors(e.right)
    if isinstance(e, BinOp) and e.op == "/":
        return multiplicative_factors(e.left) + [
            (f, not inv) for f, inv in multiplicative_factors(e.right)
        ]
    return [(e, False)]


def eval_expr(e: Expr, env: dict[tuple[str, str], Any], params: dict[str, Any], np_mod):
    if isinstance(e, Const):
        return e.value
    if isinstance(e, Param):
        return params[e.name]
    if isinstance(e, Ref):
        return env[(e.var, e.attr)]
    if isinstance(e, BinOp):
        l = eval_expr(e.left, env, params, np_mod)
        r = eval_expr(e.right, env, params, np_mod)
        return {"+": l + r, "-": l - r, "*": l * r, "/": l / r}[e.op]
    if isinstance(e, Call):
        args = [eval_expr(a, env, params, np_mod) for a in e.args]
        if e.fn == "abs":
            return np_mod.abs(args[0])
        raise ValueError(f"unknown function {e.fn}")
    raise TypeError(e)


# ---------------------------------------------------------------------------
# SQL-facing AST
# ---------------------------------------------------------------------------


@dataclass
class TableRef:
    table: str
    var: str


@dataclass
class JoinCond:
    left: Ref
    right: Ref


@dataclass
class ConstCond:
    ref: Ref
    op: str  # = > < >= <= in
    value: Any  # number | Param | Subquery | list (for op 'in' on values)


@dataclass
class Subquery:
    """A SELECT projecting one column, possibly INTERSECTed with others."""

    query: "Query"
    intersect: list["Query"] = field(default_factory=list)


@dataclass
class SelectItem:
    expr: Expr | None  # None for plain column
    ref: Ref | None
    agg: str | None  # count | sum | min | max | avg | exists | None


@dataclass
class Query:
    select: list[SelectItem]
    tables: list[TableRef]
    join_conds: list[JoinCond]
    const_conds: list[ConstCond]
    group_by: Ref | None = None

    def var_table(self, var: str) -> str:
        for t in self.tables:
            if t.var == var:
                return t.table
        raise KeyError(var)


# ---------------------------------------------------------------------------
# Normalized chain plan (RQNA physical form)
# ---------------------------------------------------------------------------


@dataclass
class SeedIds:
    """σ_{key=c}: one or more constant/parameter entity ids."""

    entity: str
    ids: Any  # int | Param | list[int|Param]
    var: str  # the seeded variable (its entity attrs become seed scalars)


@dataclass
class SeedMask:
    """Context mask over an entity domain: intersection of sub-chains and/or
    entity-attribute predicates (paper Fig. 6 lines 5-7)."""

    entity: str
    chains: list["ChainPlan"]
    entity_conds: list[ConstCond] = field(default_factory=list)


@dataclass
class RelHop:
    """One ⋈ (or ⋉ when ``semijoin``) through I_{table.src_key}."""

    table: str
    src_key: str
    dst_key: str
    src_entity: str
    dst_entity: str
    var: str
    measure_expr: Expr | None = None  # per-edge factor, refs only this var
    semijoin: bool = False  # binarize incoming weights (dedup, paper §6.1)
    degree_filter: bool = False  # project src entity itself (mask ∧ degree>0)


@dataclass
class EntityStep:
    """Entity-table variable joined on its ID: per-domain elementwise factor
    and/or predicate mask; may also export seed scalars (e.g. d1.Year)."""

    entity: str
    var: str
    factor_expr: Expr | None = None  # refs this var's attrs + seed scalars
    conds: list[ConstCond] = field(default_factory=list)


@dataclass
class ChainPlan:
    seed: SeedIds | SeedMask
    steps: list[RelHop | EntityStep]
    group_entity: str | None  # None → plan yields a mask/id-set (subquery)
    group_ref: Ref | None
    agg: str | None  # count | sum | min | max | avg | exists (picks the semiring)
    output_ref: Ref | None = None  # projected column for mask-producing plans

    def domains(self) -> list[str]:
        doms = [self.seed.entity]
        for s in self.steps:
            if isinstance(s, RelHop) and not s.degree_filter:
                doms.append(s.dst_entity)
        return doms
