"""Paper Fig. 14/17: pipelining vs materializing intermediate results.

The frontier engine never materializes join paths; OMC-denseID materializes
every hop. Queries AS/AD with seeds of increasing fan-out (the paper's A1..A5 /
D1..D5) show the materialized engine's time growing with intermediate size
while the pipelined engine stays flat."""
from __future__ import annotations

import numpy as np

from repro.core.engine import GQFastEngine
from repro.core.planner import plan_query
from repro.core.reference import NumpyQueryEngine
from repro.core.sql import parse
from repro.data import synth_graph as SG

from .common import emit, gqfast_db, pubmed_m, timeit


def _seeds_by_fanout(schema, rel: str, key: str, n: int) -> list[int]:
    col = schema.relationships[rel].columns[key]
    counts = np.bincount(col)
    order = np.argsort(counts)
    # spread from light to heavy seeds
    picks = [order[int(f * (len(order) - 1))] for f in np.linspace(0.45, 0.92, n)]
    return [int(p) for p in picks]


def run() -> None:
    schema = pubmed_m()
    db = gqfast_db("m")
    gq = GQFastEngine(db)
    omc = NumpyQueryEngine(schema, lookup="index")
    plan = plan_query(schema, parse(SG.QUERY_AS))
    pq = gq.prepare(SG.QUERY_AS)
    for i, a in enumerate(_seeds_by_fanout(schema, "DA", "Author", 5)):
        t_gq = timeit(lambda: np.asarray(pq(a0=a)), iters=3)
        t_omc = timeit(omc.execute_plan, plan, {"a0": a}, iters=2, warmup=0)
        elems = omc.stats.materialized_elements
        emit(f"fig14/AS/A{i+1}/pipelined", t_gq * 1e6,
             f"materialized_elems={elems} ratio={t_omc/t_gq:.1f}")
        emit(f"fig14/AS/A{i+1}/materialized", t_omc * 1e6, "")


if __name__ == "__main__":
    run()
