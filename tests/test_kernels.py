"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the pure-jnp
oracles in kernels/ref.py, plus hypothesis property tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.codecs import pack_bits
from repro.kernels import ops, ref

settings.register_profile("k", deadline=None, max_examples=15)
settings.load_profile("k")


def _pack_words(vals, width):
    buf = pack_bits(vals, width)
    pad = (-len(buf)) % 4
    return np.frombuffer(buf.tobytes() + b"\0" * pad, dtype=np.uint32)


@pytest.mark.parametrize("width", [1, 3, 5, 8, 12, 16, 17, 24, 31])
@pytest.mark.parametrize("count", [1, 1000, 1024, 2050])
def test_bitunpack_sweep(width, count):
    rng = np.random.default_rng(width * 1000 + count)
    vals = rng.integers(0, 2**width, size=count, dtype=np.uint64)
    words = _pack_words(vals, width)
    got = np.asarray(ops.bitunpack(words, width, count))
    assert np.array_equal(got, vals.astype(np.int64))
    refv = np.asarray(ref.bitunpack_ref(jnp.asarray(words), width, count))
    assert np.array_equal(refv, vals.astype(np.int64))


@given(st.integers(1, 31), st.integers(1, 3000), st.integers(0, 2**31))
def test_bitunpack_property(width, count, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 2**width, size=count, dtype=np.uint64)
    words = _pack_words(vals, width)
    got = np.asarray(ops.bitunpack(words, width, count, use_pallas=False))
    assert np.array_equal(got, vals.astype(np.int64))


@pytest.mark.parametrize(
    "n_src,n_dst,E", [(100, 80, 500), (1000, 1000, 10000), (17, 5, 3), (4096, 4096, 4096)]
)
def test_fragment_spmv_sweep(n_src, n_dst, E):
    rng = np.random.default_rng(n_src + E)
    w = rng.random(n_src).astype(np.float32)
    src = rng.integers(0, n_src, E).astype(np.int32)
    dst = rng.integers(0, n_dst, E).astype(np.int32)
    m = rng.random(E).astype(np.float32)
    expect = np.zeros(n_dst, np.float64)
    np.add.at(expect, dst, w[src].astype(np.float64) * m)
    for use_pallas in (True, False):
        got = np.asarray(ops.fragment_spmv(w, src, dst, m, n_dst, use_pallas=use_pallas))
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_fragment_spmv_is_one_hop():
    """Kernel result == one frontier RelHop of the query engine."""
    from repro.core.engine import GQFastDatabase
    from repro.data.synth_graph import make_pubmed

    schema = make_pubmed(n_docs=300, n_terms=30, n_authors=100)
    db = GQFastDatabase(schema, account_space=False)
    di = db.device.index("DT", "Doc")
    n_terms = schema.entities["Term"].size
    w = np.zeros(schema.entities["Document"].size, np.float32)
    w[5] = 1.0
    got = np.asarray(
        ops.fragment_spmv(w, di.src_ids, di.dst_ids, di.measures["Fre"], n_terms)
    )
    dt = schema.relationships["DT"]
    expect = np.zeros(n_terms)
    sel = dt.columns["Doc"] == 5
    np.add.at(expect, dt.columns["Term"][sel], dt.columns["Fre"][sel].astype(float))
    np.testing.assert_allclose(got, expect, rtol=1e-5)


@pytest.mark.parametrize("n", [1, 100, 1024, 5000])
def test_bitmap_ops_sweep(n):
    rng = np.random.default_rng(n)
    a = rng.integers(0, 2**32, n, dtype=np.uint32)
    b = rng.integers(0, 2**32, n, dtype=np.uint32)
    assert np.array_equal(np.asarray(ops.bitmap_and(a, b)), a & b)
    pc = int(ops.bitmap_and_popcount(a, b))
    assert pc == int(np.unpackbits((a & b).view(np.uint8)).sum())


@given(st.integers(1, 4000), st.integers(0, 2**31))
def test_bitmap_popcount_property(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**32, n, dtype=np.uint32)
    b = rng.integers(0, 2**32, n, dtype=np.uint32)
    assert int(ops.bitmap_and_popcount(a, b, use_pallas=False)) == int(
        np.unpackbits((a & b).view(np.uint8)).sum()
    )


def test_bitunpack_matches_loader_packing():
    """End-to-end: FragmentIndex packed column → kernel decode == host values."""
    from repro.core.engine import GQFastDatabase
    from repro.data.synth_graph import make_pubmed

    schema = make_pubmed(n_docs=200, n_terms=40, n_authors=80)
    db = GQFastDatabase(schema, account_space=False, keep_packed=True)
    idx = db.host_indexes[("DT", "Doc")]
    cf = idx.columns["Term"]
    got = np.asarray(ops.bitunpack(jnp.asarray(cf.packed), cf.packed_width, len(cf.values)))
    assert np.array_equal(got, cf.values)
