"""AdamW with optional int8-quantized moments (8-bit-Adam-style, per-tensor
absmax scales) — the memory lever that lets arctic-480b's optimizer state fit
v5e HBM (DESIGN.md §6). Pure-pytree functional optimizer."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_moments: bool = False  # int8 m/v with blockwise scales
    moment_dtype: object = jnp.float32  # bf16: halves moment HBM, keeps the
    # param tree layout so FSDP sharding propagates (the at-scale choice; the
    # int8 blocked layout defeats SPMD propagation across its reshape)


_QBLOCK = 256


def _bhint(x: jnp.ndarray) -> jnp.ndarray:
    """Shard blocked [nb, 256] fp32 intermediates over (data, model): the
    param→blocked reshape defeats SPMD propagation, so without this hint the
    quantize/dequantize temporaries replicate (2 × param-sized fp32 — the
    3.9 TB/device arctic dry-run bug)."""
    from ..models.common import shard_hint

    return shard_hint(x, ("data", "model"), None)


def _q8(x: jnp.ndarray, sqrt_domain: bool = False) -> dict:
    """Blockwise int8 quantization (256-value blocks, absmax scales). The
    second moment is stored in the sqrt domain to halve its dynamic range —
    the 8-bit-Adam recipe (Dettmers et al.); per-tensor scales diverge."""
    flat = x.reshape(-1)
    if sqrt_domain:
        flat = jnp.sqrt(jnp.maximum(flat, 0.0))
    pad = (-flat.shape[0]) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = _bhint(flat.reshape(-1, _QBLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    return {"q": jnp.round(blocks / scale[:, None]).astype(jnp.int8), "s": scale}


def _dq8(q: dict, shape: tuple, sqrt_domain: bool = False) -> jnp.ndarray:
    flat = (_bhint(q["q"].astype(jnp.float32) * q["s"][:, None])).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    flat = flat[:n].reshape(shape)
    return flat * flat if sqrt_domain else flat


def adamw_init(params, cfg: AdamWConfig) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    if cfg.quantize_moments:
        zf = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m = jax.tree.map(lambda z: _q8(z), zf)
        v = jax.tree.map(lambda z: _q8(z, sqrt_domain=True), zf)
    else:
        m, v = zeros, jax.tree.map(jnp.copy, zeros)
    return {"step": jnp.zeros((), jnp.int32), "m": m, "v": v}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, state: dict, params, cfg: AdamWConfig,
                 param_shardings=None):
    """``param_shardings``: optional pytree of NamedShardings matching params.
    Required at scale with quantize_moments: the blocked-int8 → param-shape
    reshape breaks SPMD propagation, so the dequantized fp32 moments (2×
    param-sized trees) replicate without explicit constraints (dry-run:
    arctic-480b 3.9 TB/device)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, sh: jax.lax.with_sharding_constraint(x, sh), tree, param_shardings
        )

    is_q = cfg.quantize_moments
    if is_q:
        leaf = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
        m_f = constrain(jax.tree.map(
            lambda q, g: _dq8(q, g.shape), state["m"], grads, is_leaf=leaf
        ))
        v_f = constrain(jax.tree.map(
            lambda q, g: _dq8(q, g.shape, sqrt_domain=True), state["v"], grads, is_leaf=leaf
        ))
    else:
        m_f = jax.tree.map(lambda m: m.astype(jnp.float32), state["m"])
        v_f = jax.tree.map(lambda v: v.astype(jnp.float32), state["v"])

    m_new = constrain(jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, m_f, grads))
    v_new = constrain(jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, v_f, grads))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m_new, v_new)
    if is_q:
        m_new = jax.tree.map(lambda m: _q8(m), m_new)
        v_new = jax.tree.map(lambda v: _q8(v, sqrt_domain=True), v_new)
    else:
        m_new = jax.tree.map(lambda m: m.astype(cfg.moment_dtype), m_new)
        v_new = jax.tree.map(lambda v: v.astype(cfg.moment_dtype), v_new)
    return new_params, {"step": step, "m": m_new, "v": v_new}, {"grad_norm": gn}


def cosine_warmup(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)

    return sched
