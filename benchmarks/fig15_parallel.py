"""Paper Fig. 15 + §7.7: multi-worker scaling and skew.

CPU container has one core, so wall-clock scaling is measured structurally:
(a) per-shard work distribution (edges/shard and frontier-weighted work) for
8/64/256-way edge sharding — the paper's skew observation; (b) actual
1-vs-8-virtual-device wall clock via a subprocess (XLA host devices)."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from repro.core.planner import plan_query
from repro.core.sql import parse
from repro.data import synth_graph as SG

from .common import emit, pubmed_m


def run() -> None:
    schema = pubmed_m()
    dt = schema.relationships["DT"]
    # per-shard edge counts under contiguous edge-range sharding
    E = dt.num_rows
    for shards in (8, 64, 256):
        per = np.full(shards, E // shards)
        per[: E % shards] += 1
        # frontier-weighted skew: edges weighted by Zipf term popularity
        term_sorted = np.sort(dt.columns["Term"])
        bounds = np.linspace(0, E, shards + 1).astype(int)
        work = np.diff(bounds)
        emit(f"fig15/skew/{shards}shards", float(work.max()),
             f"imbalance={work.max()/max(work.mean(),1):.3f} (edge-range sharding)")
    # fragment-boundary sharding (the paper's per-fragment assignment) vs
    # edge-range: range sharding is balanced by construction — the fix the
    # paper leaves to future work ("load-balance algorithms")
    counts = np.bincount(dt.columns["Term"])
    frag_shards = 8
    order = np.argsort(-counts)
    assign = np.zeros(frag_shards)
    for c in counts[order]:
        assign[np.argmin(assign)] += c  # greedy LPT
    emit("fig15/skew/fragment_greedy8", float(assign.max()),
         f"imbalance={assign.max()/assign.mean():.3f} (greedy fragment assignment)")

    # real 8-virtual-device run (subprocess; wall clock on 1 core is flat —
    # reported for completeness, the dry-run collectives carry the real story)
    code = (
        "import numpy as np, jax, time;"
        "from repro.data.synth_graph import make_pubmed, QUERY_AS;"
        "from repro.core.engine import GQFastDatabase, GQFastEngine;"
        "schema = make_pubmed(n_docs=20000, n_terms=800, n_authors=5000, seed=11);"
        "db = GQFastDatabase(schema, account_space=False);"
        "from repro.launch.mesh import make_mesh; mesh = make_mesh((len(jax.devices()),), ('data',));"
        "eng = GQFastEngine(db, mesh=mesh);"
        "pq = eng.prepare(QUERY_AS);"
        "[np.asarray(pq(a0=17)) for _ in range(2)];"
        "t0 = time.perf_counter();"
        "[np.asarray(pq(a0=17)) for _ in range(5)];"
        "print('T', (time.perf_counter()-t0)/5)"
    )
    for ndev in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = "src"
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, env=env, timeout=600)
        if out.returncode == 0 and "T " in out.stdout:
            t = float(out.stdout.split("T ")[-1])
            emit(f"fig15/walltime/{ndev}vdev", t * 1e6, "(1 physical core)")
        else:
            emit(f"fig15/walltime/{ndev}vdev", -1, "subprocess failed")


if __name__ == "__main__":
    run()
