"""Production meshes (importing this module never touches jax device state).

Also the jax-version compatibility shim for mesh construction: ``axis_types``
/ ``jax.sharding.AxisType`` only exist on newer jax; :func:`make_mesh` and
:func:`_mesh_from_devices` request Auto axes when available and degrade to the
plain constructor otherwise, so tests and benchmarks build meshes the same way
everywhere."""
from __future__ import annotations


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh(shape, axes, axis_types=Auto…)`` across jax versions."""
    import jax

    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def _mesh_from_devices(devices, axes: tuple[str, ...]):
    import jax

    try:
        return jax.sharding.Mesh(
            devices, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
    model=16) = 512 chips; the pod axis is pure data parallelism over DCN."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, found {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 for the dry-run"
        )
    import numpy as np

    return _mesh_from_devices(np.asarray(devices).reshape(shape), axes)


def make_local_mesh(axes: tuple[str, ...] = ("data",), shape: tuple[int, ...] | None = None):
    """Development mesh over whatever devices exist (tests, examples)."""
    import jax
    import numpy as np

    n = len(jax.devices())
    shape = shape or (n,)
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return _mesh_from_devices(devices, axes)
