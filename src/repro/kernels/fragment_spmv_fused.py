"""Pallas TPU kernels: pipelined 2-hop fused fragment join-aggregate.

GQ-Fast's bottom-up execution is *fully pipelined* — intermediate results are
never materialized. These kernels execute a whole
:class:`repro.core.lower.FusedHopOp` region in ONE grid pass: the first hop's
output frontier accumulates in a VMEM scratch buffer ``u``, the region's
constant filter mask (and the second hop's semijoin binarize) is applied to
``u`` in-register at the phase boundary, and the second hop streams its edge
blocks against the VMEM-resident ``u`` — the intermediate ``[n_mid]`` vector
never round-trips through HBM, and the two hops cost one kernel launch
instead of two launches plus a frontier read-back.

Grid layout: ``C1 + 1 + C2`` steps, where ``C1``/``C2`` are the lengths of
the two scalar-prefetched block lists (kernels/active.py). Steps ``< C1`` run
hop1 (accumulate into ``u``), the dedicated step ``C1`` applies the mid
mask/binarize, steps ``> C1`` run hop2 (accumulate into the output). Each
step picks its phase with a value-level ``lax.switch`` over PURE branches
(every ref read is hoisted above the switch): after discharge the switch
stays a real conditional, so a grid step executes only its own phase's
gather/scatter — and steps past a phase's ``n_active`` take the idle branch
and cost almost nothing. This is what makes runtime block skipping effective
even in the traced tier, where grids cannot shrink: the unfused kernels'
``pl.when`` guards discharge to selects whose both-sides compute runs at
every step regardless. Both phases reuse the packed operand layout and
per-block decode of :mod:`.fragment_spmv_packed` (``_packed_operands``), so
dense and BCA-packed streams fuse identically and results stay bit-identical
to the unfused two-kernel path on every semiring × encoding × skip-mode
combination. The degenerate 1-hop+filter region runs a ``C1 + 1`` grid: hop1
accumulates into ``u`` and the final step applies the mask and writes the
output.

Block skipping composes: hop1's list comes from the incoming frontier's
support (as in the unfused active kernels); hop2's list is derived *without
reading u* from the fuse-time block reachability matrix
(:func:`repro.core.fuse._block_reach`) — the OR of the rows of hop1's active
blocks. Skipping off simply passes full ``arange`` lists, so one kernel body
serves every mode.

Padding contract: identical to the unfused kernels — hop1's src pads one past
the frontier, hop2's src pads one past ``n_mid`` (``u``'s gather fills the
⊕-identity), packed word streams pad with zero words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitunpack import decode_groups
from .fragment_spmm import _edge_product_batched, _segment_combine_batched
from .fragment_spmv import IDENTITY, _combine, _edge_product, _segment_combine
from .fragment_spmv_packed import (
    GROUPS_PER_EDGE_BLOCK,
    _packed_operands,
)
from .params import EDGE_BLOCK


def _binarize(w, op: str):
    """Semijoin ⋉ on a raw frontier vector — mirrors ``Semiring.binarize``
    exactly (bit-identity with the unfused path depends on it)."""
    if op == "sum":
        return (w > 0).astype(jnp.float32)
    zero = IDENTITY[op]
    return jnp.where(w != zero, jnp.float32(1.0), jnp.float32(zero))


def _apply_mask(w, keep, op: str):
    """Predicate filter — mirrors ``Semiring.mask``: keep where >0, else 0̄."""
    return jnp.where(keep > 0, w, IDENTITY[op])


def _n_hop_refs(m_mode: str) -> int:
    """Refs per hop operand set (src + dst + optional measure (+ dict));
    the fused hop sets never carry the resident frontier."""
    return 2 + (m_mode != "none") + (m_mode == "dict")


def _decode_vals(dst_width: int, m_mode: str, m_width: int, dst, rest):
    """Value-level twin of ``fragment_spmv_packed._decode_block``: one edge
    block's (dst, measure) from already-read VALUES. Pure, so it can live
    inside a ``lax.switch`` branch — a ref read inside a branch would force
    the discharge back to select-over-all-branches and every step would pay
    for both phases again."""
    if dst_width:
        dst = decode_groups(dst, dst_width).reshape(-1)
    if m_mode == "none":
        m = jnp.ones(EDGE_BLOCK, jnp.float32)
    elif m_mode == "dense":
        m = rest[0]
    else:
        idx = decode_groups(rest[0], m_width).reshape(-1)
        if m_mode == "dict":
            m = jnp.take(rest[1], idx)
        else:
            m = idx.astype(jnp.float32)
    return dst, m


def _phase_specs(kinds, pick):
    """BlockSpecs for one phase of the fused grid. ``pick(i, bi1, bi2)``
    selects the edge block: phase 1 clamps into ``bi1``, phase 2 into ``bi2``
    (during the other phase the clamp re-fetches a valid block; no compute
    reads it). Index maps see the 4 prefetched scalars (na1, bi1, na2, bi2)."""
    specs = []
    for k in kinds:
        if k == "edge":
            specs.append(pl.BlockSpec(
                (EDGE_BLOCK,),
                lambda i, na1, bi1, na2, bi2, _p=pick: (_p(i, bi1, bi2),),
            ))
        elif k[0] == "resident":
            shape = k[1]
            specs.append(pl.BlockSpec(
                shape, lambda i, na1, bi1, na2, bi2, _z=(0,) * len(shape): _z
            ))
        else:  # ('words', width)
            specs.append(pl.BlockSpec(
                (GROUPS_PER_EDGE_BLOCK, k[1]),
                lambda i, na1, bi1, na2, bi2, _p=pick: (_p(i, bi1, bi2), 0),
            ))
    return specs


def _kernel_fused2(
    C1: int, n_mid: int, n_dst: int, op: str,
    cfg1: tuple, cfg2: tuple, has_mask: bool, mid_binarize: bool,
    batched: bool, *refs,
):
    dw1, mm1, mw1 = cfg1
    dw2, mm2, mw2 = cfg2
    na1_ref, _bi1, na2_ref, _bi2, w_ref, *rest = refs
    n1 = _n_hop_refs(mm1)
    n2 = _n_hop_refs(mm2)
    h1, h2 = rest[:n1], rest[n1:n1 + n2]
    k = n1 + n2
    mask_ref = rest[k] if has_mask else None
    out_ref = rest[k + int(has_mask)]
    u_ref = rest[k + int(has_mask) + 1]
    ep = _edge_product_batched if batched else _edge_product
    seg = _segment_combine_batched if batched else _segment_combine
    i = pl.program_id(0)
    zero = jnp.float32(IDENTITY[op])

    # every ref read happens HERE, above the switch — the branches must stay
    # pure value functions or the discharge lowers the switch to a select
    # that computes all four branches at every step
    u = jnp.where(i == 0, zero, u_ref[...])
    out = jnp.where(i == 0, zero, out_ref[...])
    w = w_ref[...]
    b1 = [r[...] for r in h1]
    b2 = [r[...] for r in h2]
    keep = mask_ref[...] if has_mask else None

    def hop1(u, out):
        dst, m = _decode_vals(dw1, mm1, mw1, b1[1], b1[2:])
        prod = ep(w, b1[0], m, op)
        return _combine(u, seg(prod, dst, n_mid, op), op), out

    def mid(u, out):
        if has_mask:
            u = _apply_mask(u, keep[None, :] if batched else keep, op)
        if mid_binarize:
            u = _binarize(u, op)
        return u, out

    def hop2(u, out):
        dst, m = _decode_vals(dw2, mm2, mw2, b2[1], b2[2:])
        prod = ep(u, b2[0], m, op)
        return u, _combine(out, seg(prod, dst, n_dst, op), op)

    def idle(u, out):
        return u, out

    branch = jnp.where(
        i < C1,
        jnp.where(i < na1_ref[0], 0, 3),
        jnp.where(i == C1, 1, jnp.where(i - C1 - 1 < na2_ref[0], 2, 3)),
    )
    u, out = jax.lax.switch(branch, [hop1, mid, hop2, idle], u, out)
    u_ref[...] = u
    out_ref[...] = out


def _kernel_fused1(
    C1: int, n_dst: int, op: str, cfg1: tuple, has_mask: bool,
    batched: bool, *refs,
):
    """Degenerate 1-hop+filter region (``C1 + 1`` grid): accumulate in VMEM
    scratch, then the dedicated final step applies the output-domain mask
    in-register and writes out. Same value-level switch structure as
    :func:`_kernel_fused2` so inactive steps stay cheap."""
    dw1, mm1, mw1 = cfg1
    na1_ref, _bi1, w_ref, *rest = refs
    n1 = _n_hop_refs(mm1)
    h1 = rest[:n1]
    mask_ref = rest[n1] if has_mask else None
    out_ref = rest[n1 + int(has_mask)]
    u_ref = rest[n1 + int(has_mask) + 1]
    ep = _edge_product_batched if batched else _edge_product
    seg = _segment_combine_batched if batched else _segment_combine
    i = pl.program_id(0)
    zero = jnp.float32(IDENTITY[op])

    u = jnp.where(i == 0, zero, u_ref[...])
    out = jnp.where(i == 0, zero, out_ref[...])
    w = w_ref[...]
    b1 = [r[...] for r in h1]
    keep = mask_ref[...] if has_mask else None

    def hop1(u, out):
        dst, m = _decode_vals(dw1, mm1, mw1, b1[1], b1[2:])
        prod = ep(w, b1[0], m, op)
        return _combine(u, seg(prod, dst, n_dst, op), op), out

    def final(u, out):
        o = u
        if has_mask:
            o = _apply_mask(o, keep[None, :] if batched else keep, op)
        return u, o

    def idle(u, out):
        return u, out

    branch = jnp.where(i < C1, jnp.where(i < na1_ref[0], 0, 2), 1)
    u, out = jax.lax.switch(branch, [hop1, final, idle], u, out)
    u_ref[...] = u
    out_ref[...] = out


def _mask_spec(n_mid: int, num_prefetch: int):
    if num_prefetch == 4:
        return pl.BlockSpec((n_mid,), lambda i, na1, bi1, na2, bi2: (0,))
    return pl.BlockSpec((n_mid,), lambda i, na, bi: (0,))


def _clamped_specs(kinds, C1: int):
    """Degenerate-region BlockSpecs (2 prefetch scalars): the final mask step
    at ``i == C1`` has no block of its own, so the pick clamps into ``bi``."""
    specs = []
    for k in kinds:
        if k == "edge":
            specs.append(pl.BlockSpec(
                (EDGE_BLOCK,),
                lambda i, na, bi: (bi[jnp.minimum(i, C1 - 1)],),
            ))
        elif k[0] == "resident":
            shape = k[1]
            specs.append(pl.BlockSpec(
                shape, lambda i, na, bi, _z=(0,) * len(shape): _z
            ))
        else:  # ('words', width)
            specs.append(pl.BlockSpec(
                (GROUPS_PER_EDGE_BLOCK, k[1]),
                lambda i, na, bi: (bi[jnp.minimum(i, C1 - 1)], 0),
            ))
    return specs


def _fused_call(
    weights,
    src1, dst1, m1, md1,
    src2, dst2, m2, md2,
    mid_mask,
    block_idx1, n_active1, block_idx2, n_active2,
    n_mid, n_dst, cfg1, cfg2, op, mid_binarize, interpret, batched,
):
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    two_hop = src2 is not None
    has_mask = mid_mask is not None
    E1 = src1.shape[0]
    pad1 = (-E1) % EDGE_BLOCK
    nb1 = max(1, (E1 + pad1) // EDGE_BLOCK)
    ops1, kinds1 = _packed_operands(
        weights, src1, dst1, m1, md1, *cfg1, nb1, pad1,
    )
    C1 = int(block_idx1.shape[0])
    out_shape = (weights.shape[0], n_dst) if batched else (n_dst,)
    u_shape = (weights.shape[0], n_mid) if batched else (n_mid,)
    if not two_hop:
        in_specs = _clamped_specs(kinds1, C1)
        operands = list(ops1)
        if has_mask:
            in_specs.append(_mask_spec(n_mid, 2))
            operands.append(mid_mask)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(C1 + 1,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(out_shape, lambda i, na, bi: (0,) * len(out_shape)),
            scratch_shapes=[pltpu.VMEM(u_shape, jnp.float32)],
        )
        return pl.pallas_call(
            functools.partial(_kernel_fused1, C1, n_dst, op, cfg1, has_mask, batched),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
            interpret=interpret,
        )(n_active1, block_idx1, *operands)
    E2 = src2.shape[0]
    pad2 = (-E2) % EDGE_BLOCK
    nb2 = max(1, (E2 + pad2) // EDGE_BLOCK)
    ops2, kinds2 = _packed_operands(
        None, src2, dst2, m2, md2, *cfg2, nb2, pad2, n_src=n_mid,
    )
    C2 = int(block_idx2.shape[0])

    def pick1(i, bi1, bi2):
        return bi1[jnp.minimum(i, C1 - 1)]

    def pick2(i, bi1, bi2):
        return bi2[jnp.clip(i - C1 - 1, 0, C2 - 1)]

    in_specs = _phase_specs(kinds1, pick1) + _phase_specs(kinds2, pick2)
    operands = list(ops1) + list(ops2)
    if has_mask:
        in_specs.append(_mask_spec(n_mid, 4))
        operands.append(mid_mask)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(C1 + 1 + C2,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            out_shape, lambda i, na1, bi1, na2, bi2: (0,) * len(out_shape)
        ),
        scratch_shapes=[pltpu.VMEM(u_shape, jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel_fused2, C1, n_mid, n_dst, op, cfg1, cfg2,
            has_mask, mid_binarize, batched,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(n_active1, block_idx1, n_active2, block_idx2, *operands)


_FUSED_STATICS = (
    "n_mid", "n_dst", "op",
    "dst1_width", "m1_mode", "m1_width",
    "dst2_width", "m2_mode", "m2_width",
    "mid_binarize", "interpret",
)


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def fragment_spmv_fused(
    weights: jnp.ndarray,  # f32[n_src] — hop1's incoming frontier
    src1, dst1, m1, md1,  # hop1 streams (per cfg1 modes)
    src2, dst2, m2, md2,  # hop2 streams; src2=None ⇒ degenerate 1-hop region
    mid_mask,  # f32[n_mid] ∧ of member filter masks | None
    block_idx1, n_active1,  # hop1's prefetched block list
    block_idx2, n_active2,  # hop2's list (ignored when degenerate)
    *,
    n_mid: int, n_dst: int,
    dst1_width: int = 0, m1_mode: str = "none", m1_width: int = 0,
    dst2_width: int = 0, m2_mode: str = "none", m2_width: int = 0,
    op: str = "sum", mid_binarize: bool = False, interpret: bool = False,
) -> jnp.ndarray:
    return _fused_call(
        weights, src1, dst1, m1, md1, src2, dst2, m2, md2, mid_mask,
        block_idx1, n_active1, block_idx2, n_active2,
        n_mid, n_dst,
        (dst1_width, m1_mode, m1_width), (dst2_width, m2_mode, m2_width),
        op, mid_binarize, interpret, batched=False,
    )


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def fragment_spmm_fused(
    weights: jnp.ndarray,  # f32[B, n_src] — the batched frontier matrix
    src1, dst1, m1, md1,
    src2, dst2, m2, md2,
    mid_mask,
    block_idx1, n_active1,
    block_idx2, n_active2,
    *,
    n_mid: int, n_dst: int,
    dst1_width: int = 0, m1_mode: str = "none", m1_width: int = 0,
    dst2_width: int = 0, m2_mode: str = "none", m2_width: int = 0,
    op: str = "sum", mid_binarize: bool = False, interpret: bool = False,
) -> jnp.ndarray:
    """Batched twin: the VMEM scratch holds ``[B, n_mid]`` and both phases use
    the batched gather/scatter helpers — B queries share one fused pass."""
    return _fused_call(
        weights, src1, dst1, m1, md1, src2, dst2, m2, md2, mid_mask,
        block_idx1, n_active1, block_idx2, n_active2,
        n_mid, n_dst,
        (dst1_width, m1_mode, m1_width), (dst2_width, m2_mode, m2_width),
        op, mid_binarize, interpret, batched=True,
    )
