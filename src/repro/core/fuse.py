"""IR fusion pass: group adjacent HopOp chains into pipelined regions.

GQ-Fast's execution model is *fully pipelined* — intermediate results never
materialize between operators. The physical IR from :mod:`.lower` is a flat op
list, and the frontier interpreter used to write a full ``[n_entity]`` frontier
vector to HBM after every HopOp. This pass rewrites the plan so that adjacent
hops (plus any interleaved constant-mask EntityFilterOps and the trailing
GroupOp) become one :class:`repro.core.lower.FusedHopOp` region, which the
frontier/batched interpreters execute in a single Pallas grid pass
(:mod:`repro.kernels.fragment_spmv_fused`): hop1 accumulates into a VMEM
scratch buffer, the mid mask is applied in-register, hop2 streams its edge
blocks against the VMEM-resident frontier.

Region formation rules (DESIGN.md §Pipelined fusion):

  * a region opens at a HopOp and absorbs at most TWO hops (the kernel is a
    two-phase grid; longer chains become back-to-back regions);
  * EntityFilterOps join only if they are pure constant masks — a ``factor``
    expression or parameter-dependent conditions end the region (their values
    are not known at fuse time);
  * DegreeFilterOp always ends a region (it reads the *pre-hop* frontier);
  * the final GroupOp joins when it immediately follows the region, so the
    whole tail of the plan is one span in profiles;
  * a region must contain either two hops or one hop plus at least one filter
    (a bare single hop gains nothing from fusion and stays as-is);
  * SeedOp sub-programs (mask seeds) are fused recursively;
  * under ``mode='auto'`` a two-hop region only forms when its reach matrix
    is sparse enough (``REACH_DENSITY_MAX``) — dense reach means the fused
    pass would stream nearly every hop2 block regardless of the realized
    intermediate support, while the unfused composition plans hop2's block
    list from the frontier it just materialized; ``mode='on'`` fuses
    unconditionally.

For two-hop regions we also precompute a host-side block-to-block
reachability matrix ``reach[nb1, nb2]``: hop1's edge block ``b1`` reaches
hop2's edge block ``b2`` iff some dst produced by ``b1`` falls inside
``b2``'s ``[src_min, src_max]`` range. At dispatch time hop2's active block
list is the OR of the reach rows of hop1's active blocks — conservative
(a skipped hop2 block provably contributes only ⊕-identity), so block
skipping composes with fusion without reading the intermediate frontier.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..kernels.params import EDGE_BLOCK
from .lower import (
    EntityFilterOp,
    FusedHopOp,
    GroupOp,
    HopOp,
    PhysicalPlan,
    SeedOp,
)


#: 'auto' fuses a two-hop region only when the mean reach density is below
#: this — above it the reach-derived hop2 block list approaches a full scan
#: and the unfused support-planned composition wins.
REACH_DENSITY_MAX = 0.5


def _pure_mask_filter(op) -> bool:
    return (
        isinstance(op, EntityFilterOp)
        and op.factor is None
        and not op.param_conds
    )


def _block_reach(hop1: HopOp, hop2: HopOp):
    """``bool[nb1, nb2]``: which hop2 edge blocks can hop1 block b1 touch.

    hop2's blocks are CSR-ordered, so their ``[src_min, src_max]`` ranges are
    monotone: the blocks containing a given src value form one contiguous run,
    found with two searchsorteds; runs are accumulated per hop1 block with a
    difference array (O(E1·log nb2 + nb1·nb2) host work, done once at fuse
    time)."""
    if hop2.block_src_min is None or hop2.block_src_max is None:
        return None
    dst1 = np.asarray(hop1.dst_ids)
    smin2 = np.asarray(hop2.block_src_min)
    smax2 = np.asarray(hop2.block_src_max)
    nb2 = int(smin2.shape[0])
    e1 = int(dst1.shape[0])
    nb1 = max(1, -(-e1 // EDGE_BLOCK))
    reach = np.zeros((nb1, nb2), dtype=bool)
    for b1 in range(nb1):
        vals = dst1[b1 * EDGE_BLOCK:(b1 + 1) * EDGE_BLOCK]
        if vals.size == 0:
            continue
        starts = np.searchsorted(smax2, vals, side="left")
        ends = np.searchsorted(smin2, vals, side="right")
        diff = np.zeros(nb2 + 1, dtype=np.int64)
        np.add.at(diff, starts, 1)
        np.add.at(diff, ends, -1)
        reach[b1] = np.cumsum(diff[:nb2]) > 0
    return reach


def _form_regions(ops: tuple, mode: str) -> tuple:
    out: list = []
    i = 0
    n = len(ops)
    while i < n:
        op = ops[i]
        if not isinstance(op, HopOp):
            out.append(op)
            i += 1
            continue
        members: list = [op]
        j = i + 1
        while j < n and _pure_mask_filter(ops[j]):
            members.append(ops[j])
            j += 1
        second = None
        if j < n and isinstance(ops[j], HopOp):
            second = ops[j]
            members.append(second)
            j += 1
        if len(members) == 1:  # bare hop: nothing to pipeline
            out.append(op)
            i += 1
            continue
        reach = _block_reach(op, second) if second is not None else None
        if (
            mode == "auto"
            and second is not None
            and (reach is None or reach.mean() > REACH_DENSITY_MAX)
        ):
            # dense (or unknown) reach: the fused hop2 phase would touch
            # ~every block; keep the support-planned unfused composition
            out.append(op)
            i += 1
            continue
        if j < n and isinstance(ops[j], GroupOp) and j == n - 1:
            members.append(ops[j])
            j += 1
        n_mid = op.dom_dst
        out.append(FusedHopOp(tuple(members), n_mid, reach))
        i = j
    return tuple(out)


def fuse_plan(phys: PhysicalPlan, mode: str = "on") -> PhysicalPlan:
    """Return a plan with fusable op runs collapsed into FusedHopOp regions
    (idempotent; plans with no fusable run come back unchanged). ``mode``:
    'on' fuses every eligible region; 'auto' additionally applies the reach
    density guard (see module docstring)."""
    ops = []
    for op in phys.ops:
        if isinstance(op, SeedOp) and op.programs:
            op = dataclasses.replace(
                op, programs=tuple(fuse_plan(p, mode) for p in op.programs)
            )
        ops.append(op)
    fused = _form_regions(tuple(ops), mode)
    return dataclasses.replace(phys, ops=fused)


def unfuse_plan(phys: PhysicalPlan) -> PhysicalPlan:
    """Inverse of :func:`fuse_plan`: expand every region back to its member
    ops (the robustness ladder's ``unfused`` rung and the scan/xla rungs
    compile against this)."""
    ops: list = []
    for op in phys.ops:
        if isinstance(op, SeedOp) and op.programs:
            op = dataclasses.replace(
                op, programs=tuple(unfuse_plan(p) for p in op.programs)
            )
        if isinstance(op, FusedHopOp):
            ops.extend(op.members)
        else:
            ops.append(op)
    return dataclasses.replace(phys, ops=tuple(ops))


def has_fused(phys: PhysicalPlan) -> bool:
    return any(isinstance(op, FusedHopOp) for op in phys.ops) or any(
        isinstance(op, SeedOp) and any(has_fused(p) for p in op.programs)
        for op in phys.ops
    )


def fusion_groups(phys: PhysicalPlan) -> list[str]:
    """One line per fused region, for ``explain()``."""
    groups = []
    for op in phys.ops:
        if isinstance(op, FusedHopOp):
            sigs = dataclasses.replace(phys, ops=op.members).op_signature()
            groups.append(" + ".join(sigs))
    return groups
