"""GQ-Fast index (paper §5): per-direction fragment storage.

``FragmentIndex(R, F1)`` materializes, for key attribute F1 with domain size h:
  * ``indptr`` — the offset lookup table 𝒫 (h+1 int32 entries). Fragment c of any
    co-stored column spans [indptr[c], indptr[c+1]). Because sizes come from
    consecutive offsets, none are stored (paper §5).
  * per co-attribute value arrays holding the fragments consecutively, built from
    R lexsorted by (F1, F2) so FK fragments are internally sorted (bitmap-codec
    safe) and measure fragments stay aligned.

This is a CSR/CSC pair when both directions are built — the TPU-native layout of
the paper's byte-array + lookup-table design (DESIGN.md §2). Device arrays are
int32/float32; the encoded byte streams are kept (optionally) for space accounting
and for the bitunpack kernel path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import codecs as C
from .schema import RelationshipTable, Schema


@dataclass
class ColumnFragments:
    name: str
    values: np.ndarray  # int64 host values, fragment-concatenated order
    domain: int
    encoding: str
    encoded_bytes: int  # total space of the encoded byte array (bits/8)
    packed: np.ndarray | None = None  # bit-packed words for kernel path (BCA only)
    packed_width: int = 0


@dataclass
class FragmentIndex:
    table: str
    key: str  # the F_i this index is keyed on
    key_entity: str
    indptr: np.ndarray  # int64[h+1]
    columns: dict[str, ColumnFragments] = field(default_factory=dict)

    @property
    def num_edges(self) -> int:
        return int(self.indptr[-1])

    def fragment(self, c: int, col: str) -> np.ndarray:
        s, e = int(self.indptr[c]), int(self.indptr[c + 1])
        return self.columns[col].values[s:e]

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def src_ids(self) -> np.ndarray:
        """Expand the indptr back to one key id per edge (CSR row indices)."""
        h = self.indptr.shape[0] - 1
        return np.repeat(np.arange(h, dtype=np.int64), np.diff(self.indptr))

    def lookup_bytes(self) -> int:
        """Space of the offset lookup table with minimum-width offsets (paper §5):
        ⌈log256 b_A⌉ bytes per offset per co-stored column."""
        total = 0
        for cf in self.columns.values():
            b = max(cf.encoded_bytes, 1)
            obytes = max(1, int(np.ceil(np.log(b + 1) / np.log(256))))
            total += (self.indptr.shape[0]) * obytes
        return total

    def total_bytes(self) -> int:
        return self.lookup_bytes() + sum(cf.encoded_bytes for cf in self.columns.values())


def build_index(
    schema: Schema,
    rel: RelationshipTable,
    key: str,
    encodings: dict[str, str] | None = None,
    keep_packed: bool = True,
    account_space: bool = True,
) -> FragmentIndex:
    """Build I_{R.key}. ``encodings`` overrides the Fig.-12 chooser per column.

    ``keep_packed=True`` is the repo-wide default (``GQFastDatabase`` threads
    the same value): the bit-packed words are the device column store's wire
    layout, so keeping them costs host memory only and saves a re-pack when
    the storage policy ships a column packed (storage/policy.py)."""
    other = rel.other_fk(key)
    kcol = rel.columns[key].astype(np.int64)
    ocol = rel.columns[other].astype(np.int64)
    h = schema.domain_size(rel.fk_entity(key))
    order = np.lexsort((ocol, kcol))  # sort by key, then other FK (paper §5)
    counts = np.bincount(kcol, minlength=h)
    indptr = np.zeros(h + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    idx = FragmentIndex(rel.name, key, rel.fk_entity(key), indptr)
    avg = rel.num_rows / max(1, int((counts > 0).sum()))

    cols = {other: ocol[order]}
    for m in rel.measures:
        cols[m] = rel.columns[m].astype(np.int64)[order]

    for cname, cvals in cols.items():
        if cname == other:
            dom = schema.domain_size(rel.fk_entity(other))
            enc = (encodings or {}).get(cname) or C.choose_key_encoding(avg, dom)
        else:
            dom = int(cvals.max(initial=0)) + 1
            ent = C.column_entropy(cvals) if account_space else float(C.bits_needed(dom))
            enc = (encodings or {}).get(cname) or C.choose_measure_encoding(avg, dom, ent)
        nbytes = _encoded_size(cvals, indptr, dom, enc) if account_space else cvals.nbytes
        cf = ColumnFragments(cname, cvals, dom, enc, nbytes)
        if keep_packed:
            cf.packed_width = C.bits_needed(dom)
            cf.packed = _pack_words(cvals, cf.packed_width)
        idx.columns[cname] = cf
    return idx


def _pack_words(values: np.ndarray, width: int) -> np.ndarray:
    """Whole-column little-endian bit packing into uint32 words (kernel layout —
    per-column contiguous, not per-fragment padded; offsets are value indices)."""
    buf = C.pack_bits(values, width)
    pad = (-buf.shape[0]) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, dtype=np.uint8)])
    return buf.view(np.uint32)


def _encoded_size(values: np.ndarray, indptr: np.ndarray, domain: int, enc: str) -> int:
    """Exact encoded byte-array size, fragment by fragment (analytic forms for the
    per-fragment codecs; real Huffman lengths via the global code table)."""
    sizes = np.diff(indptr)
    nz = sizes[sizes > 0]
    if enc == "UA":
        w = C.bits_needed(domain)
        item = 1 if w <= 8 else 2 if w <= 16 else 4 if w <= 32 else 8
        return int(values.shape[0] * item)
    if enc == "BCA":
        w = C.bits_needed(domain)
        return int(np.ceil(nz * w / 8).sum())
    if enc == "UB":
        return int(len(nz) * np.ceil(domain / 8))
    if enc == "BB":
        # varint-7 gap encoding; exact size needs the gaps — estimate with the
        # paper's uniform-gap bound per fragment (cheap, matches §5 analysis)
        gaps = np.maximum((domain - nz) / nz, 1.0)
        nb = np.maximum(1, np.ceil(np.log(gaps) / np.log(128)))
        return int((nz * nb).sum())
    if enc in ("Huffman", "DictBCA"):
        cod = C.make_codec(enc, domain, values)
        if enc == "DictBCA":
            idx = cod.to_index[values]
            esc_bits = (idx >= cod.cap).astype(np.int64) * 32
            starts = indptr[:-1][sizes > 0]
            ends = indptr[1:][sizes > 0]
            cs = np.concatenate([[0], np.cumsum(esc_bits)])
            frag_bits = (ends - starts) * cod.width + (cs[ends] - cs[starts])
            return int(np.ceil(frag_bits / 8).sum())
        # Huffman: sum of per-value code lengths, fragment byte-padded
        lens = np.zeros(int(values.max(initial=0)) + 1, dtype=np.int64)
        lens[cod.sym] = cod.len_sorted
        per_val = lens[values]
        starts = indptr[:-1][sizes > 0]
        ends = indptr[1:][sizes > 0]
        cs = np.concatenate([[0], np.cumsum(per_val)])
        frag_bits = cs[ends] - cs[starts]
        return int(np.ceil(frag_bits / 8).sum())
    raise ValueError(enc)


def build_both_indexes(
    schema: Schema, rel: RelationshipTable, **kw
) -> tuple[FragmentIndex, FragmentIndex]:
    return build_index(schema, rel, rel.fk1, **kw), build_index(schema, rel, rel.fk2, **kw)
