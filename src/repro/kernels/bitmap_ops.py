"""Pallas TPU kernel: bitmap intersection (paper §6.1 merge-intersection, θ=0).

Word-wise AND over uint32 bitmap words (the on-device uncompressed form of the
paper's byte-aligned bitmaps — DESIGN.md §2), plus a fused popcount reduction
for cardinality. Pure VPU work with (8, 128)-aligned VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS, LANES = 8, 128
BLOCK_WORDS = ROWS * LANES


def _and_kernel(a_ref, b_ref, out_ref):
    out_ref[...] = a_ref[...] & b_ref[...]


def _and_popcount_kernel(a_ref, b_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = a_ref[...] & b_ref[...]
    out_ref[0, 0] += jnp.sum(jax.lax.population_count(w).astype(jnp.int32))


def _pad2d(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % BLOCK_WORDS
    if pad:
        x = jnp.concatenate([x, jnp.zeros(pad, x.dtype)])
    return x.reshape(-1, LANES), (n + pad) // BLOCK_WORDS


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_and(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    n = a.shape[0]
    a2, blocks = _pad2d(a)
    b2, _ = _pad2d(b)
    out = pl.pallas_call(
        _and_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a.dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_and_popcount(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    a2, blocks = _pad2d(a)
    b2, _ = _pad2d(b)
    out = pl.pallas_call(
        _and_popcount_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(a2, b2)
    return out[0, 0]
