"""Fragment encodings (paper §5): UA, BCA, UB, BB, Huffman, and the DictBCA
TPU substitute for Huffman.

Two layers:
  * storage codecs — host-side numpy encode/decode of one fragment to/from bytes,
    used by the loader for space accounting (reproduces paper Tables 4/8/9/10) and
    as the oracle for the Pallas ``bitunpack`` kernel.
  * analytic space model — the paper's closed-form sizes (§5 table + Fig. 12) and
    the per-column encoding chooser.

All codecs operate on non-negative integer arrays (dictionary encoding of strings
happens upstream at load time, as in the paper).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------------
# Bit I/O helpers (little-endian bit order within the byte stream, paper §5 BB)
# ---------------------------------------------------------------------------


def pack_bits(values: np.ndarray, width: int) -> np.ndarray:
    """Pack ``values`` at ``width`` bits each (little-endian) into a uint8 array,
    padded to whole bytes. Vectorized: explode to a bit matrix then ``packbits``.
    """
    values = np.asarray(values, dtype=np.uint64)
    n = values.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.uint8)
    if width <= 0:
        raise ValueError(f"width must be >= 1, got {width}")
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((values[:, None] >> shifts[None, :]) & np.uint64(1)).astype(np.uint8)
    flat = bits.reshape(-1)  # bit i*width+j = bit j of value i
    return np.packbits(flat, bitorder="little")


def unpack_bits(buf: np.ndarray, width: int, count: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns int64 array of ``count`` values."""
    if count == 0:
        return np.zeros(0, dtype=np.int64)
    flat = np.unpackbits(np.asarray(buf, dtype=np.uint8), bitorder="little")
    flat = flat[: count * width].reshape(count, width).astype(np.uint64)
    shifts = np.arange(width, dtype=np.uint64)
    return (flat << shifts[None, :]).sum(axis=1).astype(np.int64)


def bits_needed(domain: int) -> int:
    """⌈log2 D⌉ with the paper's convention (at least 1 bit)."""
    return max(1, int(math.ceil(math.log2(max(int(domain), 2)))))


# ---------------------------------------------------------------------------
# Storage codecs
# ---------------------------------------------------------------------------


class Codec:
    name: str = "abstract"

    def encode(self, values: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        raise NotImplementedError


class UACodec(Codec):
    """Uncompressed array in the narrowest of {8,16,32,64}-bit unsigned types."""

    name = "UA"

    def __init__(self, domain: int):
        self.domain = int(domain)
        w = bits_needed(domain)
        self.itemsize = 1 if w <= 8 else 2 if w <= 16 else 4 if w <= 32 else 8
        self.dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[self.itemsize]

    def encode(self, values: np.ndarray) -> bytes:
        return np.asarray(values, dtype=self.dtype).tobytes()

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        return np.frombuffer(buf, dtype=self.dtype, count=count).astype(np.int64)


class BCACodec(Codec):
    """Bit-aligned compressed array: ⌈log2 D⌉ bits/value, fragment byte-padded."""

    name = "BCA"

    def __init__(self, domain: int):
        self.domain = int(domain)
        self.width = bits_needed(domain)

    def encode(self, values: np.ndarray) -> bytes:
        return pack_bits(values, self.width).tobytes()

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        return unpack_bits(np.frombuffer(buf, dtype=np.uint8), self.width, count)


class UBCodec(Codec):
    """Uncompressed bitmap over the domain; values must be unique & sortable.

    Decode returns the *sorted* values (bitmaps are order-destroying; the loader
    only assigns bitmap codecs to columns whose fragments are stored sorted —
    guaranteed by the (F1, F2) lexsort at index build, paper §5).
    """

    name = "UB"

    def __init__(self, domain: int):
        self.domain = int(domain)
        self.nbytes = (self.domain + 7) // 8

    def encode(self, values: np.ndarray) -> bytes:
        bits = np.zeros(self.domain, dtype=np.uint8)
        bits[np.asarray(values, dtype=np.int64)] = 1
        return np.packbits(bits, bitorder="little").tobytes()

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), bitorder="little")
        vals = np.nonzero(bits[: self.domain])[0].astype(np.int64)
        assert vals.shape[0] == count, (vals.shape[0], count)
        return vals


class BBCodec(Codec):
    """Byte-aligned compressed bitmap (paper §5 BB): zero-run lengths between the
    set bits, each length written as 7-bit groups, MSB of each byte = continuation
    flag, little-endian groups. Unique sorted values only.
    """

    name = "BB"

    def encode(self, values: np.ndarray) -> bytes:
        values = np.sort(np.asarray(values, dtype=np.int64))
        runs = np.diff(values, prepend=-1) - 1  # zeros before each set bit
        out = bytearray()
        for r in runs.tolist():
            while True:
                group = r & 0x7F
                r >>= 7
                out.append(group | (0x80 if r else 0x00))
                if not r:
                    break
        return bytes(out)

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        vals = np.empty(count, dtype=np.int64)
        pos = -1
        i = 0
        for k in range(count):
            run = 0
            shift = 0
            while True:
                b = buf[i]
                i += 1
                run |= (b & 0x7F) << shift
                shift += 7
                if not (b & 0x80):
                    break
            pos += run + 1
            vals[k] = pos
        return vals


class HuffmanCodec(Codec):
    """Canonical Huffman with a *global* code table per column (paper §5) but each
    fragment encoded separately. Decode is array/table-based (no tree walk).

    Host-side only — see DESIGN.md §2 for why bit-serial Huffman decode has no TPU
    analogue and what replaces it on device (DictBCA).
    """

    name = "Huffman"

    def __init__(self, column_values: np.ndarray):
        vals, counts = np.unique(np.asarray(column_values, dtype=np.int64), return_counts=True)
        self.lengths = _huffman_code_lengths(counts)
        # canonical codes: sort by (length, value)
        order = np.lexsort((vals, self.lengths))
        self.sym = vals[order]
        self.len_sorted = self.lengths[order]
        codes = np.zeros(len(vals), dtype=np.uint64)
        code = 0
        prev_len = int(self.len_sorted[0]) if len(vals) else 0
        for i in range(len(vals)):
            li = int(self.len_sorted[i])
            code <<= li - prev_len
            prev_len = li
            codes[i] = code
            code += 1
        self.codes = codes
        self.code_of = dict(zip(self.sym.tolist(), zip(codes.tolist(), self.len_sorted.tolist())))
        self.max_len = int(self.len_sorted.max()) if len(vals) else 0
        # table-based decoder: index by the next max_len bits
        if self.max_len and self.max_len <= 20:
            tbl_sym = np.zeros(1 << self.max_len, dtype=np.int64)
            tbl_len = np.zeros(1 << self.max_len, dtype=np.int32)
            for s, c, li in zip(self.sym.tolist(), codes.tolist(), self.len_sorted.tolist()):
                li = int(li)
                base = c << (self.max_len - li)
                span = 1 << (self.max_len - li)
                tbl_sym[base : base + span] = s
                tbl_len[base : base + span] = li
            self.tbl_sym, self.tbl_len = tbl_sym, tbl_len
        else:
            self.tbl_sym = self.tbl_len = None

    def encode(self, values: np.ndarray) -> bytes:
        bits: list[int] = []
        for v in np.asarray(values, dtype=np.int64).tolist():
            code, li = self.code_of[v]
            for j in range(li - 1, -1, -1):  # MSB-first within the code
                bits.append((code >> j) & 1)
        arr = np.array(bits, dtype=np.uint8)
        return np.packbits(arr, bitorder="big").tobytes()

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), bitorder="big")
        out = np.empty(count, dtype=np.int64)
        pos = 0
        ml = self.max_len
        padded = np.concatenate([bits, np.zeros(ml, dtype=np.uint8)])
        weights = (1 << np.arange(ml - 1, -1, -1)).astype(np.int64)
        for k in range(count):
            window = int(padded[pos : pos + ml] @ weights)
            out[k] = self.tbl_sym[window]
            pos += int(self.tbl_len[window])
        return out

    def encoded_bits(self, values: np.ndarray) -> int:
        vals = np.asarray(values, dtype=np.int64)
        return int(sum(self.code_of[v][1] for v in vals.tolist()))


class DictBCACodec(Codec):
    """TPU substitute for Huffman (DESIGN.md §2): global frequency-sorted
    dictionary + fixed-width packing with *adaptive escape coding* — the top
    2^k−1 values are coded inline at k bits, the heavy tail escapes to a 32-bit
    side array; k minimizes total bits over the column. Decode is fully
    vectorizable (bitunpack + two gathers + cumsum over escape flags), never
    worse than plain fixed-width, and approaches entropy on skewed columns.
    """

    name = "DictBCA"

    def __init__(self, column_values: np.ndarray):
        col = np.asarray(column_values, dtype=np.int64)
        vals, counts = np.unique(col, return_counts=True)
        order = np.argsort(-counts, kind="stable")
        self.dictionary = vals[order]  # index -> value
        self.to_index = np.zeros(int(vals.max()) + 1 if len(vals) else 1, dtype=np.int64)
        self.to_index[self.dictionary] = np.arange(len(vals))
        # choose k: N·k inline bits + 32 bits per escaped value
        csorted = counts[order]
        cum = np.concatenate([[0], np.cumsum(csorted)])
        N = col.shape[0]
        full = bits_needed(len(vals))
        best_k, best_cost = full, N * full  # no-escape baseline
        for k in range(1, full):
            cap = (1 << k) - 1
            covered = cum[min(cap, len(vals))]
            cost = N * k + (N - covered) * 32
            if cost < best_cost:
                best_k, best_cost = k, cost
        self.width = best_k
        self.cap = (1 << best_k) - 1 if best_k < full else (1 << full)

    def encode(self, values: np.ndarray) -> bytes:
        idx = self.to_index[np.asarray(values, dtype=np.int64)]
        esc = idx >= self.cap
        codes = np.where(esc, self.cap, idx)
        head = pack_bits(codes, self.width).tobytes()
        side = idx[esc].astype(np.uint32).tobytes()
        return head + side

    def decode(self, buf: bytes, count: int) -> np.ndarray:
        head_bytes = (count * self.width + 7) // 8
        codes = unpack_bits(np.frombuffer(buf[:head_bytes], dtype=np.uint8),
                            self.width, count)
        esc = codes >= self.cap
        side = np.frombuffer(buf[head_bytes:], dtype=np.uint32)
        slot = np.cumsum(esc) - 1  # j-th escape → side[j]
        idx = np.where(esc, side[np.minimum(slot, max(len(side) - 1, 0))] if len(side) else 0, codes)
        return self.dictionary[idx]

    def encoded_bits(self, values: np.ndarray) -> int:
        idx = self.to_index[np.asarray(values, dtype=np.int64)]
        return int(values.shape[0] * self.width + (idx >= self.cap).sum() * 32)


def _huffman_code_lengths(counts: np.ndarray) -> np.ndarray:
    """Code length per symbol via the standard heap construction."""
    n = len(counts)
    if n == 1:
        return np.ones(1, dtype=np.int64)
    heap: list[tuple[int, int, list[int]]] = [
        (int(c), i, [i]) for i, c in enumerate(counts)
    ]
    heapq.heapify(heap)
    lengths = np.zeros(n, dtype=np.int64)
    uid = n
    while len(heap) > 1:
        c1, _, s1 = heapq.heappop(heap)
        c2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (c1 + c2, uid, s1 + s2))
        uid += 1
    return lengths


# ---------------------------------------------------------------------------
# Analytic space model (paper §5 table + Appendix 9.1) — sizes in BITS
# ---------------------------------------------------------------------------


def space_ua(n: int, domain: int) -> int:
    return 32 * n * max(1, math.ceil(math.log(max(domain, 2), 2**32)))


def space_ub(n: int, domain: int) -> int:
    return 8 * math.ceil(domain / 8)


def space_bca(n: int, domain: int) -> int:
    return 8 * math.ceil(n * bits_needed(domain) / 8)


def space_bb(n: int, domain: int) -> int:
    if n == 0:
        return 0
    gap = max((domain - n) / n, 1.0)
    return n * 8 * max(1, math.ceil(math.log(gap, 128)))


def space_huffman(n: int, domain: int, entropy_bits: float) -> int:
    return 8 * math.ceil((n * entropy_bits + domain) / 8)


def column_entropy(values: np.ndarray) -> float:
    _, counts = np.unique(values, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


@dataclass
class EncodingChoice:
    name: str
    bits_per_fragment: float


def choose_key_encoding(avg_fragment_size: float, domain: int) -> str:
    """Fig. 12 chooser for key/FK columns (fragments hold unique values):
    evaluate the closed forms at the average fragment size, take the min.
    UA is never minimal (Case 1)."""
    n = max(1, int(round(avg_fragment_size)))
    costs = {
        "BCA": space_bca(n, domain),
        "BB": space_bb(n, domain),
        "UB": space_ub(n, domain),
    }
    return min(costs, key=costs.__getitem__)


def choose_measure_encoding(
    avg_fragment_size: float, domain: int, entropy_bits: float
) -> str:
    """Measure columns (duplicates allowed): bitmaps inapplicable; Huffman wins
    on skewed distributions (Table 8), BCA otherwise. The global code table is
    shared across fragments (paper §5 "global Huffman tree"), so the chooser
    compares per-value costs with only the per-fragment byte-padding overhead
    (~4 bits), not the +D tree term."""
    n = max(1, int(round(avg_fragment_size)))
    costs = {
        "BCA": space_bca(n, domain),
        "Huffman": n * entropy_bits + 4.0,
    }
    return min(costs, key=costs.__getitem__)


def make_codec(name: str, domain: int, column_values: np.ndarray | None = None) -> Codec:
    if name == "UA":
        return UACodec(domain)
    if name == "BCA":
        return BCACodec(domain)
    if name == "UB":
        return UBCodec(domain)
    if name == "BB":
        return BBCodec()
    if name == "Huffman":
        assert column_values is not None
        return HuffmanCodec(column_values)
    if name == "DictBCA":
        assert column_values is not None
        return DictBCACodec(column_values)
    raise ValueError(f"unknown codec {name}")
