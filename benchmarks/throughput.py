"""Batched multi-query serving throughput (BENCH_throughput.json).

us/query vs batch size 1/8/64 on the SD and AS dashboard shapes, for dense
and packed device storage. Batch B runs through ``PreparedQuery.execute_batch``
→ the SpMM serving path: every hop streams the CSR edge arrays from HBM once
for the whole batch instead of once per query (the B× operand reuse that a
``vmap`` of the single-query frontier cannot give). Records carry the
amortization ratio (batch-1 us/query ÷ batch-B us/query).

Acceptance gate (CI fast lane): batch-64 must amortize ≥ ``MIN_AMORTIZATION``×
over batch-1 on every shape/encoding, and the batched block must stay
bit-identical to the per-query loop — the suite raises (→ red CI) otherwise.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG

from .common import emit, timeit

BATCH_SIZES = (1, 8, 64)
MIN_AMORTIZATION = 2.0  # batch-64 us/query must be ≤ 0.5× batch-1


def run() -> None:
    schema = SG.make_pubmed(n_docs=8_000, n_terms=400, n_authors=2_000, seed=21)
    dbs = [
        ("packed", GQFastDatabase(schema, account_space=False)),
        ("dense", GQFastDatabase(schema, account_space=False,
                                 device_encodings="dense")),
    ]
    n_docs = schema.entities["Document"].size
    n_authors = schema.entities["Author"].size
    shapes = [
        ("SD", SG.QUERY_SD, "d0", n_docs),
        ("AS", SG.QUERY_AS, "a0", n_authors),
    ]
    failures = []
    for enc, db in dbs:
        eng = GQFastEngine(db, strategy="frontier")
        for qname, sql, pname, dom in shapes:
            pq = eng.prepare(sql)
            rng = np.random.default_rng(7)
            ids = rng.integers(0, dom, size=max(BATCH_SIZES))

            # batched results must be bit-identical to the per-query loop
            batched = pq.execute_batch(**{pname: ids})
            loop = np.stack([pq(**{pname: int(i)}) for i in ids])
            identical = bool(np.array_equal(batched, loop))

            base_us = None
            for B in BATCH_SIZES:
                arr = ids[:B]
                t = timeit(lambda: pq.execute_batch(**{pname: arr}), iters=3)
                us_per_query = t / B * 1e6
                if base_us is None:
                    base_us = us_per_query
                amort = base_us / us_per_query
                emit(
                    f"throughput/{qname}/{enc}/batch{B}", us_per_query,
                    f"amortization={amort:.2f} bit_identical={identical} "
                    f"total_ms={t*1e3:.1f}",
                    batch=B, amortization=round(amort, 2),
                    bit_identical=identical,
                )
            if not identical:
                failures.append(f"{qname}/{enc}: batched != per-query loop")
            if amort < MIN_AMORTIZATION:  # amort is the last (largest) batch
                failures.append(
                    f"{qname}/{enc}: batch-{max(BATCH_SIZES)} amortization "
                    f"{amort:.2f}x < {MIN_AMORTIZATION}x"
                )
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    run()
