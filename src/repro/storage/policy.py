"""Device storage policy: per-column encoding choice + real device-byte report.

Extends the paper's §5 space model from the host byte-array encodings to the
*device* representations the kernels actually read. Candidate layouts per
column (sizes in device bytes, uint32-word granularity):

  dense   4·E                          (full-width int32/float32 CSR array)
  packed  4·⌈E·w/32⌉                   w = ⌈log2 D⌉        (BCA on device)
  dict    4·⌈E·w_u/32⌉ + 4·u           w_u = ⌈log2 u⌉, u = #distinct values
                                       (DictBCA/Huffman substitute)

The chooser picks the minimum — the Fig. 12 decision procedure evaluated on
the device layouts instead of the host byte streams. Keys (the hop's
``dst_ids``) never take ``dict``: the fused hop kernel decodes them straight
to entity ids, and FK domains are already dense so a dictionary is pure
overhead. Columns needing ≥ 32 bits stay dense (packing saves nothing), and
signed columns never bit-pack (the bit layouts are unsigned, codecs §5
contract) though ``dict`` still applies — the dictionary stores original
values.

``resolve_device_encoding`` layers the user-facing override surface
(`GQFastDatabase(device_encodings=...)`) on top: a global mode
(``"auto" | "dense" | "packed"``) or a per-column dict keyed by
``(table, key, column)`` with ``"auto"`` filling the gaps.
"""
from __future__ import annotations

import math
from typing import Any

import jax.numpy as jnp
import numpy as np

from .columns import DenseColumn, DeviceColumn, DictPackedColumn, PackedColumn

DEVICE_ENCODINGS = ("dense", "packed", "dict")

# the fused hop kernel keeps the whole dictionary VMEM-resident next to the
# frontier and accumulator vectors, so cap its size (64k fp32 slots = 256 KB —
# comfortable inside the ~16 MB/core budget); larger-cardinality columns fall
# back to packed/dense even when dict would win on HBM bytes
DICT_MAX_ENTRIES = 1 << 16


def _codec_utils():
    """Deferred import: ``repro.core.__init__`` imports the engine, which
    imports this package — a module-level ``from ..core...`` import would
    therefore cycle whenever ``repro.storage`` loads first."""
    from ..core.codecs import bits_needed
    from ..core.fragments import _pack_words

    return bits_needed, _pack_words


def column_uniques(values: np.ndarray):
    """Zero-arg memo of ``np.unique(values, return_counts=True)`` — the chooser
    and the dict builder share one O(E log E) scan instead of each running
    their own (and no scan happens at all unless someone asks)."""
    memo: list = []

    def get():
        if not memo:
            memo.append(np.unique(values, return_counts=True))
        return memo[0]

    return get


def _candidate_bytes(
    values: np.ndarray, domain: int, is_key: bool, uniques=None
) -> dict[str, int]:
    bits_needed, _ = _codec_utils()
    E = int(values.shape[0])
    w = bits_needed(domain)
    cand = {"dense": 4 * E}
    signed = bool(E) and int(values.min()) < 0
    if w < 32 and not signed:  # bit packing is unsigned (codecs contract)
        cand["packed"] = 4 * math.ceil(E * w / 32)
    if not is_key and E:
        # dict stores original values, so signed columns are fine here
        u = int((uniques or column_uniques(values))()[0].shape[0])
        wu = bits_needed(u)
        if wu < 32 and u <= DICT_MAX_ENTRIES:
            cand["dict"] = 4 * math.ceil(E * wu / 32) + 4 * u
    return cand


def choose_device_encoding(
    values: np.ndarray, domain: int, is_key: bool, uniques=None
) -> str:
    """§5-style chooser over the device layouts: minimum candidate bytes
    (ties go to the less exotic layout: dense < packed < dict)."""
    cand = _candidate_bytes(values, domain, is_key, uniques)
    return min(DEVICE_ENCODINGS, key=lambda e: (cand.get(e, math.inf), DEVICE_ENCODINGS.index(e)))


def resolve_device_encoding(
    spec: str | dict | None,
    addr: tuple[str, str, str],
    values: np.ndarray,
    domain: int,
    is_key: bool,
    uniques=None,
) -> str:
    """Resolve the user-facing ``device_encodings`` surface for one column.
    ``addr`` = (table, key, column) — the index-qualified column address."""
    if isinstance(spec, dict):
        enc = spec.get(addr, "auto")
    else:
        enc = spec or "auto"
    if enc == "auto":
        return choose_device_encoding(values, domain, is_key, uniques)
    if enc not in DEVICE_ENCODINGS:
        raise ValueError(f"unknown device encoding {enc!r} for {addr}")
    if enc == "dict" and is_key:
        raise ValueError(f"dict encoding is measure-only; {addr} is a key column")
    # requested packing that cannot apply (≥ 32-bit or signed values — bit
    # packing is unsigned) degrades to dense; one O(E) min-reduce, never the
    # chooser's O(E log E) unique scan
    bits_needed, _ = _codec_utils()
    if enc == "packed" and (
        bits_needed(domain) >= 32
        or (values.shape[0] and int(values.min()) < 0)
    ):
        return "dense"
    return enc


def build_device_column(cf, enc: str, out_dtype, uniques=None) -> DeviceColumn:
    """Materialize one :class:`~repro.core.fragments.ColumnFragments` on device
    under ``enc``. Reuses the loader's bit-packed words when it kept them."""
    bits_needed, _pack_words = _codec_utils()
    if enc == "dense":
        return DenseColumn(jnp.asarray(cf.values, dtype=out_dtype))
    if enc == "packed":
        width = cf.packed_width or bits_needed(cf.domain)
        words = cf.packed if cf.packed is not None else _pack_words(cf.values, width)
        return PackedColumn(jnp.asarray(words), width, int(cf.values.shape[0]), out_dtype)
    if enc == "dict":
        vals, counts = (uniques or column_uniques(cf.values))()
        width = bits_needed(len(vals))
        # degenerate (indices as wide as the data) or VMEM-hostile (dictionary
        # too large to sit resident in the fused kernel): stay dense
        if width >= 32 or len(vals) > DICT_MAX_ENTRIES:
            return DenseColumn(jnp.asarray(cf.values, dtype=out_dtype))
        order = np.argsort(-counts, kind="stable")
        dictionary = vals[order]
        # frequency rank per sorted-unique slot; O(E log u) via searchsorted,
        # never sized by the value *range* (values may be huge or negative)
        rank = np.empty(len(vals), dtype=np.int64)
        rank[order] = np.arange(len(vals))
        words = _pack_words(rank[np.searchsorted(vals, cf.values)], width)
        return DictPackedColumn(
            jnp.asarray(words), width, int(cf.values.shape[0]),
            jnp.asarray(dictionary, dtype=out_dtype),
        )
    raise ValueError(f"unknown device encoding {enc!r}")


def device_space_report(device_db) -> dict[str, Any]:
    """Real device bytes, per index per column — what HBM actually holds, as
    opposed to the host byte-array accounting of ``FragmentIndex.total_bytes``.
    ``dense_bytes`` is the decoded-CSR baseline for the same data, so
    ``ratio`` directly states the §5-style compression factor on device.
    ``materialized_bytes`` counts decoded fallback copies currently pinned by
    the ``materialize()`` memo (fragment_loop / distributed prepares): those
    columns occupy packed *plus* dense bytes until the database is dropped, so
    the compression ratio only holds while ``materialized_bytes`` is 0."""
    rep: dict[str, Any] = {
        "indexes": {}, "total_bytes": 0, "dense_bytes": 0, "materialized_bytes": 0,
    }

    def arr_bytes(a) -> int:
        return int(a.size) * a.dtype.itemsize if a is not None else 0

    for (t, k), di in device_db.indexes.items():
        cols = {}
        struct = arr_bytes(di.indptr) + arr_bytes(di.src_ids) + arr_bytes(di.degrees)
        total = struct
        dense_total = struct
        mat_total = 0
        for name, col in [("__dst__", di.dst_col), *di.measure_cols.items()]:
            b, db_ = col.device_nbytes, 4 * col.count
            cols[name] = {"kind": col.kind, "device_bytes": b, "dense_bytes": db_}
            if col.materialized_nbytes:
                cols[name]["materialized_bytes"] = col.materialized_nbytes
            total += b
            dense_total += db_
            mat_total += col.materialized_nbytes
        rep["indexes"][f"I_{t}.{k}"] = {
            "columns": cols, "struct_bytes": struct,
            "device_bytes": total, "dense_bytes": dense_total,
        }
        rep["total_bytes"] += total
        rep["dense_bytes"] += dense_total
        rep["materialized_bytes"] += mat_total
    rep["ratio"] = rep["dense_bytes"] / max(rep["total_bytes"], 1)
    return rep
