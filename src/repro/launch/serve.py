"""Serving launcher: GQ-Fast analytics micro-batching server, or LM decode.

  PYTHONPATH=src python -m repro.launch.serve --workload analytics
  PYTHONPATH=src python -m repro.launch.serve --workload lm

The analytics workload is the paper's target deployment turned into a real
serving loop: many concurrent dashboard queries that differ only in parameter
bindings. The server collects queued requests per query shape, pads each
micro-batch to a fixed bucket size (one compile per shape), runs ONE batched
SpMM pass over the engine (``PreparedQuery.execute_batch`` — every hop
streams the edge arrays once for the whole bucket), scatters the result rows
back to their requests, and reports measured queries/sec against the
sequential single-query baseline.
"""
from __future__ import annotations

import argparse
import time
from collections import deque


def _serve_analytics(args) -> None:
    import json

    import numpy as np

    from repro.core.engine import GQFastDatabase, GQFastEngine, batch_bucket
    from repro.data import synth_graph as SG
    from repro.obs.metrics import MetricsRegistry

    print("loading database…")
    t0 = time.time()
    schema = SG.make_pubmed(
        n_docs=args.docs, n_terms=1_200, n_authors=args.docs // 5, seed=5
    )
    db = GQFastDatabase(schema, account_space=False)
    eng = GQFastEngine(db)
    print(f"  {time.time()-t0:.1f}s "
          f"(DT {schema.relationships['DT'].num_rows} rows, "
          f"DA {schema.relationships['DA'].num_rows} rows)")

    queries = {
        "AS": SG.QUERY_AS, "SD": SG.QUERY_SD, "FSD": SG.QUERY_FSD,
        "AD": SG.QUERY_AD, "FAD": SG.QUERY_FAD,
    }
    prepared = {name: eng.prepare(sql) for name, sql in queries.items()}
    rng = np.random.default_rng(0)

    # parameter samplers draw from the loaded graph's actual id domains —
    # the entity sizes in the schema, not whatever the default scale was
    n_authors = schema.entities["Author"].size
    n_docs = schema.entities["Document"].size
    n_terms = schema.entities["Term"].size

    def sample_params(kind: str) -> dict[str, int]:
        if kind == "AS":
            return {"a0": int(rng.integers(0, n_authors))}
        if kind in ("SD", "FSD"):
            return {"d0": int(rng.integers(0, n_docs))}
        return {"t1": int(rng.integers(0, n_terms)),
                "t2": int(rng.integers(0, n_terms))}

    reg = MetricsRegistry()

    def _open_out(path: str):
        import os

        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        return open(path, "w")

    def dump_metrics() -> None:
        if args.metrics_json:
            with _open_out(args.metrics_json) as fh:
                fh.write(reg.to_json(indent=2))

    bucket = batch_bucket(args.batch)
    names = list(queries)
    stream = [
        (i, names[int(rng.integers(0, len(names)))]) for i in range(args.requests)
    ]
    stream = [(i, kind, sample_params(kind)) for i, kind in stream]

    print(f"warmup (one batched compile per shape, bucket={bucket})…")
    t0 = time.time()
    for kind in names:
        p = sample_params(kind)
        prepared[kind](**p)  # single-query executable (baseline)
        prepared[kind].execute_batch(
            **{k: np.full(bucket, v) for k, v in p.items()}
        )
    print(f"  {time.time()-t0:.1f}s")

    if args.profile_json:
        # one EXPLAIN ANALYZE profile of the first query shape, for artifacts
        kind = names[0]
        prof = prepared[kind].profile(**sample_params(kind))
        with _open_out(args.profile_json) as fh:
            fh.write(prof.to_json(indent=2))
        print(f"  wrote QueryProfile({kind}) to {args.profile_json}")

    # sequential baseline: the same request mix served one query at a time
    base_n = min(args.requests, 25)
    t0 = time.perf_counter()
    for _, kind, params in stream[:base_n]:
        prepared[kind](**params)
    seq_dt = time.perf_counter() - t0
    seq_qps = base_n / seq_dt
    reg.gauge("serve.sequential_queries_per_sec").set(seq_qps)

    print(f"serving {args.requests} requests, micro-batch ≤ {args.batch}…")
    results: list = [None] * len(stream)
    queue = deque(stream)
    sizes: list[int] = []
    lat_all = reg.histogram("serve.request_latency_ms")
    t0 = time.perf_counter()
    while queue:
        tb = time.perf_counter()
        # collect: drain up to `batch` queued requests of the head's shape
        i0, kind, p0 = queue.popleft()
        group = [(i0, p0)]
        skipped: deque = deque()
        while queue and len(group) < args.batch:
            item = queue.popleft()
            if item[1] == kind:
                group.append((item[0], item[2]))
            else:
                skipped.append(item)
        queue.extendleft(reversed(skipped))
        # pad to the bucket (repeat the last binding; rows sliced off below)
        arrays = {
            k: np.asarray([p[k] for _, p in group] + [group[-1][1][k]] * (bucket - len(group)))
            for k in p0
        }
        out = prepared[kind].execute_batch(**arrays)  # one SpMM pass
        for row, (req_id, _) in enumerate(group):  # scatter to requests
            results[req_id] = out[row]
        sizes.append(len(group))
        # every request in the group completes when its batch does
        batch_ms = (time.perf_counter() - tb) * 1e3
        for _ in group:
            lat_all.observe(batch_ms)
        reg.histogram(f"serve.request_latency_ms.{kind}").observe(batch_ms)
        reg.counter("serve.requests_served").inc(len(group))
        reg.counter("serve.batches_executed").inc()
        reg.counter("serve.padded_rows").inc(bucket - len(group))
        reg.gauge("serve.batch_occupancy").set(float(np.mean(sizes)))
        reg.gauge("serve.bucket_padding_waste").set(
            1.0 - float(np.sum(sizes)) / (len(sizes) * bucket)
        )
        elapsed = time.perf_counter() - t0
        reg.gauge("serve.queries_per_sec").set(
            float(np.sum(sizes)) / elapsed if elapsed > 0 else 0.0
        )
        if args.metrics_every and len(sizes) % args.metrics_every == 0:
            dump_metrics()
    dt = time.perf_counter() - t0

    assert all(r is not None for r in results)
    qps = args.requests / dt
    reg.gauge("serve.queries_per_sec").set(qps)
    reg.gauge("serve.speedup_vs_sequential").set(qps / seq_qps)
    dump_metrics()
    snap = lat_all.snapshot()
    print(f"\n  {args.requests} requests in {dt:.2f}s over {len(sizes)} batched "
          f"passes (mean occupancy {np.mean(sizes):.1f}/{bucket})")
    print(f"  latency p50/p95/p99: {snap['p50']:.1f} / {snap['p95']:.1f} / "
          f"{snap['p99']:.1f} ms")
    print(f"  micro-batched: {qps:8.1f} queries/s")
    print(f"  sequential:    {seq_qps:8.1f} queries/s "
          f"(speedup ×{qps/seq_qps:.1f})")
    if args.metrics_json:
        print(f"  metrics written to {args.metrics_json}")
    if args.echo_metrics:
        print(json.dumps(reg.snapshot()["gauges"], indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["analytics", "lm"], default="analytics")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default: 256 analytics, 60 lm)")
    ap.add_argument("--batch", type=int, default=32,
                    help="analytics: max requests per micro-batch "
                         "(padded to the engine's bucket size)")
    ap.add_argument("--docs", type=int, default=20_000,
                    help="analytics: synthetic database scale")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="analytics: dump the metrics registry (latency "
                         "histograms, occupancy/padding gauges, qps) as JSON")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="analytics: rewrite --metrics-json every N batches "
                         "(0: only at exit)")
    ap.add_argument("--profile-json", default=None, metavar="PATH",
                    help="analytics: dump one QueryProfile as JSON after warmup")
    ap.add_argument("--echo-metrics", action="store_true",
                    help="analytics: print the gauge snapshot at exit")
    args = ap.parse_args()

    if args.workload == "analytics":
        if args.requests is None:
            args.requests = 256
        _serve_analytics(args)
        return
    if args.requests is None:
        args.requests = 60

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.models.transformer import decode_step, init_params, prefill

    arch = get_arch("qwen2.5-3b")
    cfg = arch.smoke_cfg
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    logits, cache, pos = prefill(params, toks, cfg, 128)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    out = [cur]
    for i in range(args.requests):
        logits, cache = step(params, cache, cur, jnp.int32(32 + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {args.requests} decode steps × batch 4: "
          f"{dt/args.requests*1e3:.1f} ms/step, {4*args.requests/dt:.1f} tok/s")
    print("sample tokens:", np.asarray(jnp.stack(out))[:10, 0].tolist())


if __name__ == "__main__":
    main()
