"""Synthetic Zipf-matched stand-ins for the paper's datasets (Tables 1-2).

PubMed is a public corpus but not shipped offline; these generators match the
statistics that drive GQ-Fast's behaviour — domain sizes, fanout, and Zipf skew
of term popularity / frequency measures — at a configurable scale factor.
"""
from __future__ import annotations

import numpy as np

from ..core.schema import EntityTable, RelationshipTable, Schema


def _zipf_choice(rng: np.random.Generator, n: int, size: int, s: float = 1.1) -> np.ndarray:
    """Zipf-distributed ids in [0, n) (popular ids are small)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


def _dedupe_pairs(a: np.ndarray, b: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    key = a.astype(np.int64) * (b.max() + 1) + b
    _, idx = np.unique(key, return_index=True)
    return a[idx], b[idx]


def make_pubmed(
    n_docs: int = 20_000,
    n_terms: int = 500,
    n_authors: int = 5_000,
    avg_terms_per_doc: float = 8.0,
    avg_authors_per_doc: float = 3.0,
    zipf_term: float = 1.1,
    fre_zipf: float = 1.5,
    seed: int = 0,
) -> Schema:
    """PubMed-M/MS-shaped schema: DT(Doc, Term, Fre), DA(Doc, Author),
    Document(ID, Year). Raise ``n_terms`` (lower term fanout) for the -MS flavor."""
    rng = np.random.default_rng(seed)

    e_dt = int(n_docs * avg_terms_per_doc)
    dt_doc = rng.integers(0, n_docs, size=e_dt)
    dt_term = _zipf_choice(rng, n_terms, e_dt, zipf_term)
    dt_doc, dt_term = _dedupe_pairs(dt_doc, dt_term)
    fre = 1 + _zipf_choice(rng, 50, dt_doc.shape[0], fre_zipf)

    e_da = int(n_docs * avg_authors_per_doc)
    da_doc = rng.integers(0, n_docs, size=e_da)
    da_author = _zipf_choice(rng, n_authors, e_da, 1.05)
    da_doc, da_author = _dedupe_pairs(da_doc, da_author)

    year = rng.integers(1990, 2016, size=n_docs)

    schema = Schema(
        entities={
            "Document": EntityTable("Document", n_docs, {"Year": year}),
            "Term": EntityTable("Term", n_terms),
            "Author": EntityTable("Author", n_authors),
        },
        relationships={
            "DT": RelationshipTable(
                "DT", "Doc", "Term", "Document", "Term",
                {"Doc": dt_doc, "Term": dt_term, "Fre": fre},
            ),
            "DA": RelationshipTable(
                "DA", "Doc", "Author", "Document", "Author",
                {"Doc": da_doc, "Author": da_author},
            ),
        },
    )
    schema.validate()
    return schema


def make_semmeddb(
    n_concepts: int = 4_000,
    n_csemtypes: int = 5_000,
    n_predications: int = 8_000,
    n_sentences: int = 30_000,
    seed: int = 1,
) -> Schema:
    """SemMedDB-shaped schema (paper Fig. 10 / Table 2 — low fanout):
    CS(CID, CSID), PA(CSID, PID), SP(PID, SID)."""
    rng = np.random.default_rng(seed)

    # CS: each concept has ~1.16 semtypes
    n_cs = int(n_csemtypes)
    cs_cid = rng.integers(0, n_concepts, size=n_cs)
    cs_csid = np.arange(n_csemtypes)  # concept_semtype ids are unique per row
    # PA: each predication links ~2.15 concept_semtypes
    n_pa = int(n_predications * 2.15)
    pa_csid = _zipf_choice(rng, n_csemtypes, n_pa, 1.05)
    pa_pid = rng.integers(0, n_predications, size=n_pa)
    pa_csid, pa_pid = _dedupe_pairs(pa_csid, pa_pid)
    # SP: sentences → predications, fanout ~1.61
    n_sp = int(n_sentences * 1.6)
    sp_pid = _zipf_choice(rng, n_predications, n_sp, 1.05)
    sp_sid = rng.integers(0, n_sentences, size=n_sp)
    sp_pid, sp_sid = _dedupe_pairs(sp_pid, sp_sid)

    schema = Schema(
        entities={
            "Concept": EntityTable("Concept", n_concepts),
            "ConceptSemtype": EntityTable("ConceptSemtype", n_csemtypes),
            "Predication": EntityTable("Predication", n_predications),
            "Sentence": EntityTable("Sentence", n_sentences),
        },
        relationships={
            "CS": RelationshipTable(
                "CS", "CID", "CSID", "Concept", "ConceptSemtype",
                {"CID": cs_cid, "CSID": cs_csid},
            ),
            "PA": RelationshipTable(
                "PA", "CSID", "PID", "ConceptSemtype", "Predication",
                {"CSID": pa_csid, "PID": pa_pid},
            ),
            "SP": RelationshipTable(
                "SP", "PID", "SID", "Predication", "Sentence",
                {"PID": sp_pid, "SID": sp_sid},
            ),
        },
    )
    schema.validate()
    return schema


# ---------------------------------------------------------------------------
# The paper's benchmark queries (§4), parameterized
# ---------------------------------------------------------------------------

QUERY_SD = """
SELECT dt2.Doc, COUNT(*)
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.Doc
"""

QUERY_FSD = """
SELECT dt2.Doc, SUM(dt1.Fre * dt2.Fre) / (abs(d1.Year - d2.Year) + 1)
FROM (((Document d1 JOIN DT dt1 ON d1.ID = dt1.Doc)
  JOIN DT dt2 ON dt1.Term = dt2.Term)
  JOIN Document d2 ON d2.ID = dt2.Doc)
WHERE d1.ID = :d0
GROUP BY dt2.Doc
"""

QUERY_AS = """
SELECT da2.Author, SUM(dt1.Fre * dt2.Fre) / (2017 - d.Year)
FROM ((((DA da1 JOIN DT dt1 ON da1.Doc = dt1.Doc)
  JOIN DT dt2 ON dt1.Term = dt2.Term)
  JOIN Document d ON dt2.Doc = d.ID)
  JOIN DA da2 ON dt2.Doc = da2.Doc)
WHERE da1.Author = :a0
GROUP BY da2.ID
"""

QUERY_AD = """
SELECT da.Author, COUNT(*)
FROM DA da
WHERE da.Doc IN
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t1)
  INTERSECT
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t2)
GROUP BY da.Author
"""

QUERY_FAD = """
SELECT dt2.Term, SUM(dt2.Fre)
FROM DT dt2
WHERE dt2.Doc IN
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t1)
  INTERSECT
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t2)
GROUP BY dt2.Term
"""

QUERY_RECENT_AUTHORS = """
SELECT da.Author
FROM DA da
WHERE da.Doc IN
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t1)
  INTERSECT
  (SELECT d.ID FROM Document d WHERE d.Year > :y)
  INTERSECT
  (SELECT da.Doc FROM DA da JOIN DT dt ON da.Doc = dt.Doc WHERE dt.Term = :t2)
"""

QUERY_CS = """
SELECT c2.CID, COUNT(*)
FROM CS c2, PA p2, SP s2
WHERE s2.PID = p2.PID AND p2.CSID = c2.CSID AND s2.SID IN (
  SELECT s1.SID
  FROM CS c1, PA p1, SP s1
  WHERE s1.PID = p1.PID AND p1.CSID = c1.CSID AND c1.CID = :c0)
GROUP BY CID
"""

PUBMED_QUERIES = {
    "SD": QUERY_SD,
    "FSD": QUERY_FSD,
    "AS": QUERY_AS,
    "AD": QUERY_AD,
    "FAD": QUERY_FAD,
}
