"""Semiring aggregate correctness: MIN/MAX/AVG/EXISTS (and the SUM/COUNT
baselines) against the materializing numpy oracle, under both the frontier and
the fragment-at-a-time strategies (DESIGN.md §3)."""
import numpy as np
import pytest

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.core.reference import run_sql
from repro.data import synth_graph as SG

# two-hop SD-shaped chain with a per-path score
Q_SCORE = """
SELECT dt2.Doc, {agg}(dt1.Fre * dt2.Fre)
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.Doc
"""

Q_EXISTS = """
SELECT dt2.Doc, EXISTS(*)
FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
WHERE dt1.Doc = :d0
GROUP BY dt2.Doc
"""

# mask-seeded (IN-INTERSECT) FAD-shaped chain
Q_FAD = """
SELECT dt2.Term, {agg}(dt2.Fre)
FROM DT dt2
WHERE dt2.Doc IN
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t1)
  INTERSECT
  (SELECT dt.Doc FROM DT dt WHERE dt.Term = :t2)
GROUP BY dt2.Term
"""


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=800, n_terms=60, n_authors=250, seed=2)


@pytest.fixture(scope="module")
def db(pubmed):
    return GQFastDatabase(pubmed, account_space=False)


@pytest.fixture(scope="module", params=["frontier", "fragment_loop"])
def engine(request, db):
    return GQFastEngine(db, strategy=request.param)


@pytest.mark.parametrize("agg", ["SUM", "MIN", "MAX", "AVG"])
def test_score_aggregates_match_reference(engine, pubmed, agg):
    q = Q_SCORE.format(agg=agg)
    got = engine.query(q, d0=5)
    ref = run_sql(pubmed, q, {"d0": 5})
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert (got != 0).sum() > 0, "degenerate test: empty result"


def test_exists_matches_reference(engine, pubmed):
    got = engine.query(Q_EXISTS, d0=5)
    ref = run_sql(pubmed, Q_EXISTS, {"d0": 5})
    np.testing.assert_allclose(got, ref)
    assert set(np.unique(got)) <= {0.0, 1.0}
    # EXISTS is COUNT collapsed to membership
    cnt = engine.query(Q_SCORE.format(agg="SUM").replace("SUM(dt1.Fre * dt2.Fre)", "COUNT(*)"), d0=5)
    np.testing.assert_allclose(got, (cnt > 0).astype(float))


@pytest.mark.parametrize("agg", ["MIN", "MAX", "AVG"])
def test_mask_seeded_aggregates(engine, pubmed, agg):
    q = Q_FAD.format(agg=agg)
    got = engine.query(q, t1=3, t2=9)
    ref = run_sql(pubmed, q, {"t1": 3, "t2": 9})
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert (got != 0).sum() > 0


def test_min_max_bracket_avg(engine):
    mn = engine.query(Q_SCORE.format(agg="MIN"), d0=5)
    mx = engine.query(Q_SCORE.format(agg="MAX"), d0=5)
    av = engine.query(Q_SCORE.format(agg="AVG"), d0=5)
    reached = mx > 0
    assert reached.any()
    assert (mn[reached] <= av[reached] + 1e-4).all()
    assert (av[reached] <= mx[reached] + 1e-4).all()


def test_avg_equals_sum_over_count(engine, pubmed):
    av = engine.query(Q_SCORE.format(agg="AVG"), d0=5)
    s = engine.query(Q_SCORE.format(agg="SUM"), d0=5)
    c = run_sql(pubmed, Q_SCORE.format(agg="SUM").replace(
        "SUM(dt1.Fre * dt2.Fre)", "COUNT(*)"), {"d0": 5})
    expect = np.divide(s, c, out=np.zeros_like(s), where=c > 0)
    np.testing.assert_allclose(av, expect, rtol=1e-4, atol=1e-4)


def test_prepared_aggregate_many_params(db, pubmed):
    eng = GQFastEngine(db)
    pq = eng.prepare(Q_SCORE.format(agg="MIN"))
    for d0 in (3, 5, 11):
        np.testing.assert_allclose(
            pq(d0=d0), run_sql(pubmed, Q_SCORE.format(agg="MIN"), {"d0": d0}),
            rtol=1e-4, atol=1e-4,
        )


def test_duplicate_seed_ids_accumulate(db, pubmed):
    """Two seed params resolving to the same id must double path multiplicity
    under the sum semiring (scatter-⊕ seeding, not set)."""
    q = """SELECT dt2.Doc, COUNT(*)
           FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
           WHERE dt1.Doc = :x AND dt1.Doc = :y
           GROUP BY dt2.Doc"""
    for strat in ("frontier", "fragment_loop"):
        eng = GQFastEngine(db, strategy=strat)
        got = eng.query(q, x=5, y=5)
        ref = run_sql(pubmed, q, {"x": 5, "y": 5})
        np.testing.assert_allclose(got, ref)
        single = eng.query(Q_SCORE.format(agg="SUM").replace(
            "SUM(dt1.Fre * dt2.Fre)", "COUNT(*)"), d0=5)
        np.testing.assert_allclose(got, 2 * single)


def test_rejects_multiple_aggregate_calls():
    """MIN(a)+MIN(b) must not silently merge into MIN(a+b)."""
    from repro.core.sql import parse

    for expr in ("MIN(dt1.Fre) + MIN(dt2.Fre)", "SUM(dt1.Fre) + MIN(dt2.Fre)",
                 "SUM(dt1.Fre) * SUM(dt2.Fre)"):
        with pytest.raises(SyntaxError):
            parse(f"""SELECT dt2.Doc, {expr}
                      FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
                      WHERE dt1.Doc = 1 GROUP BY dt2.Doc""")


def test_strategies_agree_on_aggregates(db):
    f = GQFastEngine(db, strategy="frontier")
    l = GQFastEngine(db, strategy="fragment_loop")
    for agg in ("MIN", "MAX", "AVG"):
        q = Q_SCORE.format(agg=agg)
        np.testing.assert_allclose(
            f.query(q, d0=7), l.query(q, d0=7), rtol=1e-4, atol=1e-4
        )
