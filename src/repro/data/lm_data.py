"""Synthetic LM token stream: Zipf-distributed tokens with local n-gram
structure (so the loss has signal to descend), deterministic by
(seed, step, shard) — the property the fault-tolerant loop relies on."""
from __future__ import annotations

import numpy as np


def lm_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0, shard: int = 0) -> dict:
    import jax.numpy as jnp

    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard]))
    # Zipf marginals
    u = rng.random((batch, seq))
    toks = np.minimum((vocab ** u).astype(np.int64), vocab - 1)
    # inject learnable bigram structure: token 2i+1 often follows 2i
    follow = rng.random((batch, seq)) < 0.5
    toks[:, 1:] = np.where(follow[:, 1:], (toks[:, :-1] + 1) % vocab, toks[:, 1:])
    t = jnp.asarray(toks.astype(np.int32))
    return {"tokens": t, "labels": t}
