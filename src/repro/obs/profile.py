"""Query profiling: per-IR-op timings + predicted-vs-observed hop fractions.

:func:`profile_prepared` turns one execution of a prepared query into a
:class:`QueryProfile` — the payload behind ``PreparedQuery.profile()`` and
``explain(analyze=True)`` (DESIGN.md §Observability):

  * **result** — produced by the query's own compiled executable with the same
    arguments ``__call__`` would pass, so it is bit-identical to plain
    execution by construction (profiling never re-derives results from an
    instrumented path).
  * **total_wall_ms** — median ``block_until_ready``-fenced end-to-end time.
  * **ops** — per-IR-op self wall / device-fenced kernel time. For the
    ``frontier`` and ``fragment_loop`` strategies these come from one eager
    (un-jitted) instrumented walk of the same interpreter the strategy
    compiles (``executor.walk_ir`` emits nested spans when a tracer is
    recording), then rescaled proportionally so the self-wall column sums to
    ``total_wall_ms`` (``timing_method: "eager-span-scaled"``; the raw eager
    walls are kept in each op's meta) — the eager walk is a *relative*
    attribution, while the compiled executable sets the absolute scale. Ops
    fused inside a traced region (the scalar strategy's nested
    loops) are marked ``fused`` and charge their time to the enclosing op. The
    ``distributed`` strategy cannot run its interpreter eagerly (collectives
    need the mesh), so per-op times are prefix deltas: the plan's k-op
    prefixes are compiled through the same shard_map entry and op k is charged
    ``t(k) − t(k−1)``.
  * **hops** — the engine's lower-time selectivity estimates
    (``_hop_fractions``) against *observed* fractions from a host-side numpy
    support propagation over the physical IR (structural reachability — the
    same quantity the estimate predicts). A hop whose observed fraction is off
    by more than :data:`MISPREDICT_FACTOR` in either direction increments the
    ``strategy_mispredict`` counter in :data:`repro.obs.metrics.REGISTRY`.
  * **memory** — ``storage.device_space_report`` of the query's device DB.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from . import trace as T
from .metrics import REGISTRY

#: observed/estimated active-fraction ratio beyond which (either direction)
#: a hop counts as a strategy-model mispredict
MISPREDICT_FACTOR = 2.0


@dataclass
class OpProfile:
    index: int
    name: str  # op_signature label, e.g. "Hop(DT.Term->Doc;measure)"
    wall_ms: float | None = None  # self wall (minus child ops); None if fused
    kernel_ms: float | None = None  # device-fenced own time
    calls: int = 1
    fused: bool = False  # time charged to an enclosing op (scalar loops)
    meta: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {"index": self.index, "name": self.name, "calls": self.calls}
        if self.wall_ms is not None:
            d["wall_ms"] = round(self.wall_ms, 4)
        if self.kernel_ms is not None:
            d["kernel_ms"] = round(self.kernel_ms, 4)
        if self.fused:
            d["fused"] = True
        if self.meta:
            d["meta"] = self.meta
        return d


@dataclass
class HopProfile:
    table: str
    src_key: str
    est_active_fraction: float | None
    observed_active_fraction: float | None
    mispredict: bool = False
    meta: dict = field(default_factory=dict)

    @property
    def ratio(self) -> float | None:
        if not self.est_active_fraction or self.observed_active_fraction is None:
            return None
        return self.observed_active_fraction / self.est_active_fraction

    def to_dict(self) -> dict:
        d: dict = {
            "table": self.table, "src_key": self.src_key,
            "est_active_fraction": self.est_active_fraction,
            "observed_active_fraction": self.observed_active_fraction,
            "mispredict": self.mispredict,
        }
        if self.ratio is not None:
            d["ratio"] = round(self.ratio, 4)
        d.update(self.meta)
        return d


@dataclass
class QueryProfile:
    sql: str
    strategy: str
    block_skipping: str
    agg: str | None
    params: dict
    total_wall_ms: float
    reps: int
    result: np.ndarray
    ops: list[OpProfile]
    hops: list[HopProfile]
    memory: dict | None = None
    spans: dict | None = None  # raw span tree from the instrumented walk
    timing_method: str = "eager-span"  # | "eager-span-scaled" | "prefix-delta"

    def to_dict(self) -> dict:
        return {
            "sql": " ".join(self.sql.split()),
            "strategy": self.strategy,
            "block_skipping": self.block_skipping,
            "agg": self.agg,
            "params": {k: _jsonable(v) for k, v in self.params.items()},
            "total_wall_ms": round(self.total_wall_ms, 4),
            "reps": self.reps,
            "timing_method": self.timing_method,
            "result_shape": list(self.result.shape),
            "result_nnz": int(np.count_nonzero(self.result)),
            "ops": [o.to_dict() for o in self.ops],
            "hops": [h.to_dict() for h in self.hops],
            "memory": self.memory,
            "spans": self.spans,
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **kw)

    def phase_summary(self) -> dict[str, float]:
        """op label → self wall ms (fused ops omitted) — the compact per-phase
        breakdown benchmarks embed next to their headline numbers."""
        return {
            f"[{o.index}] {o.name}": round(o.wall_ms, 4)
            for o in self.ops if o.wall_ms is not None
        }

    def render(self) -> str:
        """The EXPLAIN ANALYZE text block (appended to ``explain()``)."""
        out = [
            f"analyze: total {self.total_wall_ms:.3f} ms fenced "
            f"(median of {self.reps}; result shape {list(self.result.shape)}, "
            f"nnz {int(np.count_nonzero(self.result))}; "
            f"per-op via {self.timing_method})",
        ]
        for o in self.ops:
            if o.fused or o.wall_ms is None:
                timing = "(fused into enclosing op)" if o.fused else "(not measured)"
            else:
                timing = f"wall {o.wall_ms:8.3f} ms  kernel {o.kernel_ms or 0.0:8.3f} ms"
                if o.calls > 1:
                    timing += f"  calls={o.calls}"
            extras = "".join(
                f" {k}={o.meta[k]}"
                for k in ("active_blocks", "n_blocks", "skip_tier") if k in o.meta
            )
            out.append(f"  [{o.index}] {o.name:40s} {timing}{extras}")
        if self.hops:
            out.append("hops (predicted vs observed active fraction):")
            for h in self.hops:
                est = "n/a" if h.est_active_fraction is None else f"{h.est_active_fraction:.4g}"
                obs = "n/a" if h.observed_active_fraction is None else f"{h.observed_active_fraction:.4g}"
                line = f"  I_{h.table}.{h.src_key}: est={est} obs={obs}"
                if h.ratio is not None:
                    line += f" ratio={h.ratio:.2f}"
                if h.mispredict:
                    line += f"  MISPREDICT(>{MISPREDICT_FACTOR:g}x)"
                out.append(line)
        if self.memory:
            tot, dense = self.memory.get("total_bytes"), self.memory.get("dense_bytes")
            if tot:
                out.append(
                    f"memory: device {tot/2**20:.2f} MiB"
                    + (f" (decoded-CSR baseline {dense/2**20:.2f} MiB, "
                       f"ratio {dense/tot:.2f})" if dense else "")
                )
        return "\n".join(out)


def _jsonable(v):
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    a = np.asarray(v)
    return a.item() if a.ndim == 0 else a.tolist()


def mispredicted(est: float | None, obs: float | None,
                 factor: float = MISPREDICT_FACTOR) -> bool:
    """Is the observed active fraction off by more than ``factor`` in either
    direction from the estimate? (Both ~0 agree: a correctly-predicted dead
    hop is not a mispredict.)"""
    if est is None or obs is None:
        return False
    if est < 1e-12 and obs < 1e-12:
        return False
    if est <= 0.0:
        return True
    return not (est / factor <= obs <= est * factor)


# ---------------------------------------------------------------------------
# Observed hop fractions: host-side support propagation over the physical IR
# ---------------------------------------------------------------------------


def observed_hop_fractions(phys, params: dict) -> list[dict]:
    """Walk the lowered IR with a numpy boolean support vector and record, for
    every top-level HopOp, the fraction of its edges whose source is in the
    incoming support — the observed counterpart of the engine's
    ``_hop_fractions`` estimate (structural reachability; measure values do
    not affect it, exactly as in the estimate). Runs entirely on host."""
    hops: list[dict] = []
    _support_walk(phys, params, hops)
    return hops


def _support_walk(phys, params: dict, hops_out: list[dict] | None) -> np.ndarray:
    from ..core.lower import (
        DegreeFilterOp, EntityFilterOp, GroupOp, HopOp, LParam, SeedOp,
        iter_flat_ops,
    )

    np_col = lambda c: np.asarray(c.array)
    sup: np.ndarray | None = None
    # flattened: a FusedHopOp's member hops are observed individually — the
    # support propagation is structural, identical fused or not
    for op in iter_flat_ops(phys):
        if isinstance(op, SeedOp):
            if op.ids is not None:
                ids = [
                    int(params[i.name]) if isinstance(i, LParam) else int(i)
                    for i in op.ids
                ]
                sup = np.zeros(op.dom, bool)
                sup[np.asarray(ids, np.int64)] = True
            else:
                sup = np.ones(op.dom, bool)
                for prog in op.programs:  # sub-chain hops aren't top-level
                    sup &= _support_walk(prog, params, None)
                if op.const_mask is not None:
                    sup &= np.asarray(op.const_mask) > 0
                for c in op.param_conds:
                    sup &= np.asarray(c.mask(params, np_col))
        elif isinstance(op, HopOp):
            src = np.asarray(op.src_ids)
            E = int(src.shape[0])
            edge_active = sup[src] if E else np.zeros(0, bool)
            touched = int(edge_active.sum())
            reached = np.zeros(op.dom_dst, bool)
            if touched:
                reached[np.asarray(op.dst_ids)[edge_active]] = True
            if hops_out is not None:
                rec = {
                    "table": op.table, "src_key": op.src_key,
                    "observed_active_fraction": touched / max(E, 1),
                    "touched_edges": touched, "E": E,
                    "frontier_nnz": int(sup.sum()),
                    "reached": int(reached.sum()),
                }
                if op.block_src_min is not None:
                    from ..kernels.active import active_block_list_np

                    _, na, bf = active_block_list_np(
                        sup, op.block_src_min, op.block_src_max
                    )
                    rec["active_blocks"] = int(na[0])
                    rec["n_blocks"] = int(np.asarray(op.block_src_min).shape[0])
                    rec["active_block_fraction"] = round(float(bf), 6)
                hops_out.append(rec)
            sup = reached
        elif isinstance(op, DegreeFilterOp):
            sup = sup & (np.asarray(op.degrees) > 0)
        elif isinstance(op, EntityFilterOp):
            if op.const_mask is not None:
                sup = sup & (np.asarray(op.const_mask) > 0)
            for c in op.param_conds:
                sup = sup & np.asarray(c.mask(params, np_col))
        elif isinstance(op, GroupOp):
            pass
        else:  # pragma: no cover - new op kinds must be taught here
            raise TypeError(op)
    return sup


# ---------------------------------------------------------------------------
# Per-op timing
# ---------------------------------------------------------------------------


def _records_from_tracer(tracer: T.Tracer, phys) -> list[OpProfile]:
    """Aggregate the instrumented walk's op spans (matched to ``phys`` by the
    plan key) into one OpProfile per IR op; ops with no span were fused inside
    an enclosing traced region."""
    labels = phys.op_signature()
    plan_key = id(phys.ops)
    agg: dict[int, OpProfile] = {}
    for sp in tracer.iter_spans():
        if sp.meta.get("plan") != plan_key or "op_index" not in sp.meta:
            continue
        i = sp.meta["op_index"]
        rec = agg.get(i)
        if rec is None:
            rec = agg[i] = OpProfile(index=i, name=labels[i], wall_ms=0.0,
                                     kernel_ms=0.0, calls=0)
            rec.meta = {
                k: v for k, v in sp.meta.items() if k not in ("plan", "op_index")
            }
        # self time subtracts only same-plan op children: a mask seed's
        # sub-program walks are children too, but their cost belongs to the
        # seed op that evaluated them, not to ops of some other plan
        w = sp.wall_ms or 0.0
        for c in sp.children:
            if c.meta.get("plan") == plan_key and "op_index" in c.meta:
                w -= c.wall_ms or 0.0
        rec.wall_ms += max(w, 0.0)
        rec.kernel_ms += sp.kernel_ms or 0.0
        rec.calls += sp.meta.get("calls", 1)
        if sp.meta.get("fused_tail"):
            rec.meta["fused_tail"] = True
    out = []
    for i in range(len(phys.ops)):
        if i in agg:
            out.append(agg[i])
        else:
            out.append(OpProfile(index=i, name=labels[i], fused=True))
    return out


def _op_records_eager(pq, params: dict):
    """frontier / fragment_loop: one eager instrumented walk of the strategy's
    own interpreter (kernels run un-jitted; results are discarded — only the
    compiled executable's output is ever returned)."""
    import jax.numpy as jnp

    from ..core import executor as X

    phys = pq.phys
    jparams = {n: jnp.asarray(v) for n, v in params.items()}
    fusion = getattr(pq, "fusion", "auto")
    if pq.strategy == "fragment_loop":
        seed_op = phys.ops[0]
        scalar_ok = seed_op.ids is not None and not any(
            isinstance(op, X.HopOp) and op.semijoin
            for op in X.iter_flat_ops(phys)
        )
        if scalar_ok:
            phys = X.densify_plan(phys)
            mk = lambda sr, um: X._FragmentLoopInterp(
                jparams, sr, um, out_dom=phys.out_dom
            )
        else:  # compile_fragment_loop's documented frontier fallback
            mk = lambda sr, um: X._FrontierInterp(
                jparams, sr, um, block_skipping=pq.block_skipping,
                fusion=fusion,
            )
    else:
        mk = lambda sr, um: X._FrontierInterp(
            jparams, sr, um, block_skipping=pq.block_skipping, fusion=fusion,
        )
    with T.recording():  # warm the eager path (lax.cond/pallas caches)
        X.execute_ir(phys, mk)
    with T.recording() as tr:
        X.execute_ir(phys, mk)
    return _records_from_tracer(tr, phys), tr.to_dict()


def _rescale_eager_ops(ops: list[OpProfile], total_ms: float) -> list[OpProfile]:
    """Reconcile eager per-op times with the compiled end-to-end measurement.

    The eager instrumented walk runs un-jitted (and, on CPU, interpret-mode
    Pallas), so its absolute per-op walls can be orders of magnitude above the
    compiled executable's ``total_wall_ms`` — useful as *relative* attribution,
    nonsense as absolute numbers (per-op sums of seconds against a
    millisecond total). Rescale every measured op proportionally so the
    self-wall column sums to ``total_ms`` exactly; the raw eager measurements
    are preserved per op as ``meta.eager_wall_ms`` / ``meta.eager_kernel_ms``."""
    walls = [o.wall_ms for o in ops if o.wall_ms is not None]
    tot = float(sum(walls))
    if tot <= 0.0 or total_ms <= 0.0:
        return ops
    scale = total_ms / tot
    for o in ops:
        if o.wall_ms is None:
            continue
        o.meta["eager_wall_ms"] = round(o.wall_ms, 4)
        o.wall_ms = o.wall_ms * scale
        if o.kernel_ms is not None:
            o.meta["eager_kernel_ms"] = round(o.kernel_ms, 4)
            o.kernel_ms = min(o.kernel_ms * scale, o.wall_ms)
    return ops


def _op_records_prefix(pq, args: list, reps: int = 2):
    """distributed: compile each k-op prefix through the same shard_map entry
    and charge op k the fenced time delta t(k) − t(k−1)."""
    import jax

    from ..core import executor as X

    phys = pq.phys
    labels = phys.op_signature()
    cum: list[float] = []
    for k in range(1, len(phys.ops) + 1):
        fn = X.compile_frontier_distributed(
            pq.device_db, phys, pq.mesh, pq.shard_axes,
            sharded_db=pq.sharded_db, prefix=k,
        )
        jax.block_until_ready(fn(*args))  # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        cum.append(float(np.median(ts)) * 1e3)
    recs = []
    prev = 0.0
    for i, t in enumerate(cum):
        dt = max(t - prev, 0.0)
        recs.append(OpProfile(
            index=i, name=labels[i], wall_ms=dt, kernel_ms=dt,
            meta={"method": "prefix-delta", "cumulative_ms": round(t, 4)},
        ))
        prev = t
    return recs, None


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def profile_prepared(pq, params: dict, reps: int = 3) -> QueryProfile:
    """Build a :class:`QueryProfile` for one parameter binding of a
    ``PreparedQuery`` (the implementation behind ``PreparedQuery.profile``)."""
    import jax

    phys = pq.phys
    if phys is None:
        raise ValueError(
            "profile() needs the lowered physical plan; this PreparedQuery "
            "was built without one"
        )
    missing = [n for n in pq.param_names if n not in params]
    if missing:
        raise TypeError(f"profile() missing parameters: {missing}")
    args = [params[n] for n in pq.param_names]

    # result + end-to-end timing: the query's own compiled executable, same
    # args — bit-identical to __call__ by construction
    result = np.asarray(pq.fn(*args))
    ts = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(pq.fn(*args))
        ts.append(time.perf_counter() - t0)
    total_ms = float(np.median(ts)) * 1e3

    # predicted vs observed hop fractions (strategy-independent, host-side)
    observed = observed_hop_fractions(phys, params)
    estimates = pq.hop_estimates or []
    hops: list[HopProfile] = []
    for i, obs in enumerate(observed):
        est = estimates[i] if i < len(estimates) else {}
        est_f = est.get("est_active_fraction")
        obs_f = obs["observed_active_fraction"]
        mis = mispredicted(est_f, obs_f)
        if mis:
            REGISTRY.counter("strategy_mispredict").inc()
        hops.append(HopProfile(
            table=obs["table"], src_key=obs["src_key"],
            est_active_fraction=est_f, observed_active_fraction=obs_f,
            mispredict=mis,
            meta={k: v for k, v in obs.items()
                  if k not in ("table", "src_key", "observed_active_fraction")},
        ))
    REGISTRY.counter("profile_runs").inc()

    # feed the engine's calibration store: the next prepare of the same plan
    # shape picks its strategy from what this execution actually touched
    calib = getattr(pq, "calibration", None)
    if calib is not None and getattr(pq, "plan_sig", None):
        calib.record(
            pq.plan_sig, [h.observed_active_fraction for h in hops]
        )

    # per-op timings
    if pq.strategy == "distributed":
        if pq.mesh is None or pq.device_db is None:
            ops, spans = [], None
        else:
            ops, spans = _op_records_prefix(pq, args)
        method = "prefix-delta"
    else:
        ops, spans = _op_records_eager(pq, params)
        method = "eager-span"
        ops = _rescale_eager_ops(ops, total_ms)
        if any("eager_wall_ms" in o.meta for o in ops):
            method = "eager-span-scaled"

    # fold observed-fraction metadata onto the matching op records: a plain
    # HopOp consumes one HopProfile, a FusedHopOp consumes one per member hop
    # (its single span gets the first member's fractions)
    from ..core.lower import FusedHopOp, HopOp

    hop_iter = iter(hops)
    for i, op in enumerate(phys.ops):
        if isinstance(op, FusedHopOp):
            member_hops = op.hops
        elif isinstance(op, HopOp):
            member_hops = (op,)
        else:
            continue
        hs = [next(hop_iter, None) for _ in member_hops]
        h = hs[0]
        if h is not None and i < len(ops):
            ops[i].meta.setdefault("est_active_fraction", h.est_active_fraction)
            ops[i].meta.setdefault(
                "observed_active_fraction", h.observed_active_fraction
            )
            for k in ("active_blocks", "n_blocks"):
                if k in h.meta:
                    ops[i].meta.setdefault(k, h.meta[k])

    memory = None
    if pq.device_db is not None:
        from ..storage import device_space_report

        memory = device_space_report(pq.device_db)

    return QueryProfile(
        sql=pq.sql, strategy=pq.strategy, block_skipping=pq.block_skipping,
        agg=phys.agg, params=dict(params), total_wall_ms=total_ms,
        reps=max(reps, 1), result=result, ops=ops, hops=hops,
        memory=memory, spans=spans, timing_method=method,
    )
