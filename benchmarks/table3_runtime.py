"""Paper Table 3: end-to-end query runtime — GQ-Fast (compiled frontier) vs
OMC (two-copy sorted, vectorized materializing) vs OMC-binary (binary-search
lookups) vs PMC (whole-column scans). Synthetic Zipf datasets at CPU scale;
the *ratios* are the reproduction target."""
from __future__ import annotations

import numpy as np

from repro.core.engine import GQFastEngine
from repro.core.planner import plan_query
from repro.core.reference import NumpyQueryEngine
from repro.core.sql import parse
from repro.data import synth_graph as SG

from .common import emit, gqfast_db, pubmed_m, pubmed_ms, semmeddb, timeit

# head (popular, zipf-rank ≈ top) and tail seeds: the paper's observation that
# speedups are fanout-sensitive (§7.2 "high fanout is favorable to GQ-Fast")
CASES = [
    ("SD_head", SG.QUERY_SD, {"d0": 11}),
    ("SD_tail", SG.QUERY_SD, {"d0": 997}),
    ("FSD_head", SG.QUERY_FSD, {"d0": 11}),
    ("AD_head", SG.QUERY_AD, {"t1": 3, "t2": 9}),
    ("FAD_head", SG.QUERY_FAD, {"t1": 3, "t2": 9}),
    ("AS_head", SG.QUERY_AS, {"a0": 17}),
    ("AS_tail", SG.QUERY_AS, {"a0": 900}),
]


def run() -> None:
    for ds_name, schema_fn, db_key, cases in [
        ("pubmed-m", pubmed_m, "m", CASES),
        ("pubmed-ms", pubmed_ms, "ms", CASES),
        ("semmeddb", semmeddb, "sem", [("CS_head", SG.QUERY_CS, {"c0": 2}), ("CS_tail", SG.QUERY_CS, {"c0": 230})]),
    ]:
        schema = schema_fn()
        db = gqfast_db(db_key)
        gq = GQFastEngine(db, strategy="auto")  # the engine's real behavior
        omc = NumpyQueryEngine(schema, lookup="index")
        omc_bin = NumpyQueryEngine(schema, lookup="binary")
        pmc = NumpyQueryEngine(schema, lookup="scan")
        for qname, sql, params in cases:
            plan = plan_query(schema, parse(sql))
            pq = gq.prepare(sql)
            t_gq = timeit(lambda: np.asarray(pq(**params)))
            t_omc = timeit(omc.execute_plan, plan, params, iters=3)
            t_bin = timeit(omc_bin.execute_plan, plan, params, iters=3)
            t_pmc = timeit(pmc.execute_plan, plan, params, iters=3, warmup=1)
            emit(f"table3/{ds_name}/{qname}/gqfast", t_gq * 1e6,
                 f"omc_ratio={t_omc/t_gq:.1f} pmc_ratio={t_pmc/t_gq:.1f}")
            emit(f"table3/{ds_name}/{qname}/omc", t_omc * 1e6, "")
            emit(f"table3/{ds_name}/{qname}/omc_binary", t_bin * 1e6, "")
            emit(f"table3/{ds_name}/{qname}/pmc", t_pmc * 1e6, "")


if __name__ == "__main__":
    run()
