"""SQL parser + RQNA normalizer/verifier tests."""
import pytest

from repro.core.algebra import EntityStep, RelHop, SeedIds, SeedMask
from repro.core.planner import NotRelationshipQuery, plan_query
from repro.core.sql import parse
from repro.data import synth_graph as SG
from repro.robust.errors import ParseError, PlanError, QueryError


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=100, n_terms=20, n_authors=50)


def test_parse_as(pubmed):
    q = parse(SG.QUERY_AS)
    assert len(q.tables) == 5
    assert len(q.join_conds) == 4
    assert q.group_by is not None


def test_plan_as_chain(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_AS))
    kinds = [type(s).__name__ for s in p.steps]
    assert kinds == ["RelHop", "RelHop", "RelHop", "EntityStep", "RelHop"]
    assert isinstance(p.seed, SeedIds) and p.seed.entity == "Author"
    assert p.group_entity == "Author" and p.agg == "sum"
    # measures attached to the two DT hops; year factor on the entity step
    dt_hops = [s for s in p.steps if isinstance(s, RelHop) and s.table == "DT"]
    assert all(h.measure_expr is not None for h in dt_hops)
    ent = [s for s in p.steps if isinstance(s, EntityStep)][0]
    assert ent.factor_expr is not None


def test_plan_ad_semijoin_mask(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_AD))
    assert isinstance(p.seed, SeedMask) and p.seed.entity == "Document"
    assert len(p.seed.chains) == 2
    assert p.steps[0].semijoin and p.agg == "count"


def test_plan_recent_authors_degree_filter(pubmed):
    p = plan_query(pubmed, parse(SG.QUERY_RECENT_AUTHORS))
    assert p.group_entity is None and p.output_ref.attr == "Author"
    assert isinstance(p.seed, SeedMask) and len(p.seed.chains) == 2
    assert p.seed.entity_conds, "Year > :y must become an entity condition"
    # third chain projects da.Doc → degree-filter hop
    sub = p.seed.chains[-1]
    assert sub.steps[-1].degree_filter


def test_plan_cs_comma_joins():
    sem = SG.make_semmeddb(50, 60, 80, 200)
    p = plan_query(sem, parse(SG.QUERY_CS))
    assert [s.table for s in p.steps] == ["SP", "PA", "CS"]
    assert p.steps[0].semijoin
    assert p.group_entity == "Concept"


def test_group_by_relationship_id_quirk(pubmed):
    # the paper writes GROUP BY da2.ID on a relationship variable
    p = plan_query(pubmed, parse(SG.QUERY_AS))
    assert p.group_ref.attr == "Author"


def test_rejects_non_key_join(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt JOIN Document d ON dt.Fre = d.Year WHERE dt.Doc = 1 GROUP BY dt.Doc"
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_rejects_unknown_table(pubmed):
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse("SELECT x.A FROM Nope x WHERE x.A = 1"))


def test_rejects_no_seed(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt GROUP BY dt.Doc"
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_rejects_nonmultiplicative_score(pubmed):
    bad = """SELECT dt2.Doc, SUM(dt1.Fre + dt2.Fre)
             FROM DT dt1 JOIN DT dt2 ON dt1.Term = dt2.Term
             WHERE dt1.Doc = 1 GROUP BY dt2.Doc"""
    with pytest.raises(NotRelationshipQuery):
        plan_query(pubmed, parse(bad))


def test_parse_intersect_inside_parens(pubmed):
    q = """SELECT da.Author, COUNT(*) FROM DA da WHERE da.Doc IN
           ((SELECT dt.Doc FROM DT dt WHERE dt.Term = 1)
            INTERSECT (SELECT dt.Doc FROM DT dt WHERE dt.Term = 2))
           GROUP BY da.Author"""
    p = plan_query(pubmed, parse(q))
    assert len(p.seed.chains) == 2


def test_parse_errors():
    with pytest.raises(SyntaxError):
        parse("SELECT FROM x")
    with pytest.raises(SyntaxError):
        parse("SELECT a.b FROM T t WHERE a.b ~ 3")


# ---------------------------------------------------------------------------
# Typed-error sweep: every front-door failure must surface as a QueryError
# subclass with machine-readable context — never a raw KeyError/IndexError.
# ---------------------------------------------------------------------------


def test_parse_error_taxonomy_and_position():
    err = pytest.raises(ParseError, parse, "SELECT FROM x").value
    assert isinstance(err, QueryError) and isinstance(err, SyntaxError)
    assert err.code == "PARSE" and err.retryable is False
    assert isinstance(err.context["position"], int)
    assert err.context["near"] in err.context["query"]
    d = err.to_dict()
    assert d["error"] == "ParseError" and d["code"] == "PARSE"


def test_parse_error_bad_character_has_position():
    err = pytest.raises(ParseError, parse,
                        "SELECT a.b FROM T t WHERE a.b ~ 3").value
    q = "SELECT a.b FROM T t WHERE a.b ~ 3"
    pos = err.context["position"]
    assert "~" in q[pos:pos + 4], (pos, err.context["near"])


@pytest.mark.parametrize("sql", [
    "SELECT",                                  # truncated
    "SELECT a.b FROM",                         # missing table
    "SELECT a.b FROM T t WHERE",               # dangling WHERE
    "SELECT a.b FROM T t WHERE a.b = ",        # dangling comparison
    "SELECT a.b FROM T t GROUP BY",            # dangling GROUP BY
    "SELECT a.b, FROM T t WHERE a.b = 1",      # trailing comma
    "SELECT a.b FROM T t WHERE a.b IN (1",     # unclosed paren
])
def test_malformed_sql_never_raw_errors(sql):
    with pytest.raises(ParseError):
        parse(sql)


def test_unknown_table_is_typed(pubmed):
    err = pytest.raises(
        QueryError, plan_query, pubmed,
        parse("SELECT x.A FROM Nope x WHERE x.A = 1"),
    ).value
    assert isinstance(err, PlanError) and err.code == "PLAN"
    assert err.retryable is False


def test_unknown_where_variable_is_typed(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt WHERE zz.Doc = 1 GROUP BY dt.Doc"
    with pytest.raises(QueryError):
        plan_query(pubmed, parse(bad))


def test_unknown_group_by_variable_is_typed(pubmed):
    # used to escape the planner as a raw KeyError on the alias map
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt WHERE dt.Doc = 1 GROUP BY zz.Doc"
    with pytest.raises(QueryError):
        plan_query(pubmed, parse(bad))


def test_unknown_column_is_typed(pubmed):
    bad = ("SELECT dt.Nope, COUNT(*) FROM DT dt WHERE dt.Doc = 1"
           " GROUP BY dt.Nope")
    with pytest.raises(QueryError):
        plan_query(pubmed, parse(bad))


def test_not_relationship_query_is_plan_error(pubmed):
    bad = "SELECT dt.Doc, COUNT(*) FROM DT dt GROUP BY dt.Doc"
    err = pytest.raises(NotRelationshipQuery, plan_query,
                        pubmed, parse(bad)).value
    # the rejection class slots into the taxonomy (and stays a ValueError)
    assert isinstance(err, PlanError) and isinstance(err, ValueError)
    assert err.code == "PLAN"
