"""Plan execution — the JAX analogue of the paper's code generator (§6.2).

Strategies (DESIGN.md §2):
  * ``frontier`` — bottom-up fully pipelined execution, TPU-native: the chain of
    hops becomes a chain of gather ⊙ measure → ``segment_sum`` SpMV steps over
    dense per-entity-domain vectors. JAX tracing fuses the whole plan into one
    XLA executable; intermediates are vectors, never materialized join tables.
  * ``fragment_loop`` — paper-faithful port of the generated C++ (Fig. 3): nested
    ``lax.fori_loop``s walk one fragment at a time, scalar accumulator updates.
    The §Perf baseline demonstrating why the vectorized rewrite is needed on TPU.
  * distributed variant — edge-sharded shard_map with one psum per hop
    (the paper's multi-thread shared-accumulator design, contention-free).

All strategies return the dense γ accumulator ℛ over the group-by entity domain
(the paper's aggregation array; size = domain of the group key).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .algebra import (
    ChainPlan,
    ConstCond,
    EntityStep,
    Param,
    RelHop,
    SeedIds,
    SeedMask,
    eval_expr,
    expr_refs,
)
from .fragments import FragmentIndex
from .schema import Schema


@dataclass
class DeviceIndex:
    """Device-resident form of one FragmentIndex (CSR + expanded COO)."""

    indptr: jnp.ndarray  # int32[h+1]
    src_ids: jnp.ndarray  # int32[E]  (CSR row ids expanded; sorted)
    dst_ids: jnp.ndarray  # int32[E]
    measures: dict[str, jnp.ndarray] = field(default_factory=dict)  # float32[E]
    degrees: jnp.ndarray | None = None
    packed: dict[str, tuple[jnp.ndarray, int]] = field(default_factory=dict)


@dataclass
class DeviceDB:
    schema: Schema
    indexes: dict[tuple[str, str], DeviceIndex]
    entity_attrs: dict[tuple[str, str], jnp.ndarray]
    host_indexes: dict[tuple[str, str], FragmentIndex]

    def index(self, table: str, key: str) -> DeviceIndex:
        return self.indexes[(table, key)]


def build_device_db(
    schema: Schema,
    host_indexes: dict[tuple[str, str], FragmentIndex],
    keep_packed: bool = False,
) -> DeviceDB:
    dev: dict[tuple[str, str], DeviceIndex] = {}
    for (table, key), idx in host_indexes.items():
        other = next(c for c in idx.columns if c != key and _is_fk(schema, table, c))
        di = DeviceIndex(
            indptr=jnp.asarray(idx.indptr, dtype=jnp.int32),
            src_ids=jnp.asarray(idx.src_ids(), dtype=jnp.int32),
            dst_ids=jnp.asarray(idx.columns[other].values, dtype=jnp.int32),
            degrees=jnp.asarray(np.diff(idx.indptr), dtype=jnp.int32),
        )
        for m, cf in idx.columns.items():
            if m == other:
                continue
            di.measures[m] = jnp.asarray(cf.values, dtype=jnp.float32)
            if keep_packed and cf.packed is not None:
                di.packed[m] = (jnp.asarray(cf.packed), cf.packed_width)
        dev[(table, key)] = di
    attrs = {
        (e.name, a): jnp.asarray(col, dtype=jnp.float32)
        for e in schema.entities.values()
        for a, col in e.attributes.items()
    }
    return DeviceDB(schema, dev, attrs, host_indexes)


def _is_fk(schema: Schema, table: str, attr: str) -> bool:
    rel = schema.relationships[table]
    return attr in (rel.fk1, rel.fk2)


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------


def collect_params(plan: ChainPlan) -> list[str]:
    names: list[str] = []

    def add(v):
        if isinstance(v, Param) and v.name not in names:
            names.append(v.name)

    def walk(p: ChainPlan):
        if isinstance(p.seed, SeedIds):
            ids = p.seed.ids if isinstance(p.seed.ids, list) else [p.seed.ids]
            for i in ids:
                add(i)
        else:
            for c in p.seed.chains:
                walk(c)
            for cc in p.seed.entity_conds:
                add(cc.value)
        for s in p.steps:
            if isinstance(s, EntityStep):
                for cc in s.conds:
                    add(cc.value)

    walk(plan)
    return names


def _resolve(v, params: dict[str, Any]):
    return params[v.name] if isinstance(v, Param) else v


# ---------------------------------------------------------------------------
# Frontier strategy
# ---------------------------------------------------------------------------


def _seed_scalars(db: DeviceDB, seed: SeedIds, refs_needed: set, params) -> dict:
    """Entity attributes of the seeded id, as traced scalars (e.g. d1.Year)."""
    env = {}
    sid = None
    ids = seed.ids if isinstance(seed.ids, list) else [seed.ids]
    if len(ids) == 1:
        sid = _resolve(ids[0], params)
    for (var, attr) in refs_needed:
        if var == seed.var:
            assert sid is not None, "seed scalar needs a single seed id"
            env[(var, attr)] = db.entity_attrs[(seed.entity, attr)][sid]
    return env


def _cond_mask(db: DeviceDB, entity: str, conds: list[ConstCond], params) -> jnp.ndarray:
    dom = db.schema.domain_size(entity)
    mask = jnp.ones(dom, dtype=jnp.float32)
    for c in conds:
        col = db.entity_attrs[(entity, c.ref.attr)]
        v = _resolve(c.value, params)
        m = {
            "=": col == v, ">": col > v, "<": col < v,
            ">=": col >= v, "<=": col <= v,
        }[c.op]
        mask = mask * m.astype(jnp.float32)
    return mask


def _frontier_eval(db: DeviceDB, plan: ChainPlan, params: dict[str, Any]) -> jnp.ndarray:
    """Trace the chain; returns the dense accumulator over the final domain."""
    # --- seed ---
    if isinstance(plan.seed, SeedIds):
        dom = db.schema.domain_size(plan.seed.entity)
        ids = plan.seed.ids if isinstance(plan.seed.ids, list) else [plan.seed.ids]
        idx = jnp.asarray([_resolve(i, params) for i in ids], dtype=jnp.int32)
        w = jnp.zeros(dom, dtype=jnp.float32).at[idx].add(1.0)
        seed_env_src = plan.seed
    else:
        w = _mask_eval(db, plan.seed, params)
        seed_env_src = None

    # seed scalars needed anywhere downstream
    needed = set()
    for s in plan.steps:
        e = s.measure_expr if isinstance(s, RelHop) else s.factor_expr
        if e is not None:
            needed |= {(r.var, r.attr) for r in expr_refs(e)}
    scalars = (
        _seed_scalars(db, seed_env_src, needed, params) if seed_env_src else {}
    )

    # --- steps ---
    for s in plan.steps:
        if isinstance(s, RelHop):
            di = db.index(s.table, s.src_key)
            if s.semijoin:
                w = (w > 0).astype(jnp.float32)
            if s.degree_filter:
                w = w * (di.degrees > 0).astype(jnp.float32)
                continue
            ew = jnp.take(w, di.src_ids)
            if s.measure_expr is not None:
                env = dict(scalars)
                for r in expr_refs(s.measure_expr):
                    if r.var == s.var:
                        env[(r.var, r.attr)] = di.measures[r.attr]
                ew = ew * eval_expr(s.measure_expr, env, params, jnp)
            dom_dst = db.schema.domain_size(s.dst_entity)
            w = jax.ops.segment_sum(ew, di.dst_ids, num_segments=dom_dst)
        else:  # EntityStep
            if s.factor_expr is not None:
                env = dict(scalars)
                for r in expr_refs(s.factor_expr):
                    if r.var == s.var:
                        env[(r.var, r.attr)] = db.entity_attrs[(s.entity, r.attr)]
                w = w * eval_expr(s.factor_expr, env, params, jnp).astype(jnp.float32)
            if s.conds:
                w = w * _cond_mask(db, s.entity, s.conds, params)
    if plan.group_entity is None:
        return (w > 0).astype(jnp.float32)  # mask-producing chain
    return w


def _mask_eval(db: DeviceDB, seed: SeedMask, params) -> jnp.ndarray:
    dom = db.schema.domain_size(seed.entity)
    mask = jnp.ones(dom, dtype=jnp.float32)
    for chain in seed.chains:
        mask = mask * _frontier_eval(db, chain, params)
    if seed.entity_conds:
        mask = mask * _cond_mask(db, seed.entity, seed.entity_conds, params)
    return mask


def compile_frontier(db: DeviceDB, plan: ChainPlan) -> Callable[..., jnp.ndarray]:
    names = collect_params(plan)

    @jax.jit
    def run(*args):
        params = dict(zip(names, args))
        return _frontier_eval(db, plan, params)

    return run


# ---------------------------------------------------------------------------
# Paper-faithful fragment-at-a-time strategy (Fig. 3 port)
# ---------------------------------------------------------------------------


def compile_fragment_loop(db: DeviceDB, plan: ChainPlan) -> Callable[..., jnp.ndarray]:
    """Nested fori_loops over fragments, scalar per-edge accumulator updates —
    a direct port of the generated C++. Only SeedIds chains (SD/FSD/AS shapes);
    mask seeds fall back to the frontier strategy."""
    if not isinstance(plan.seed, SeedIds):
        return compile_frontier(db, plan)
    names = collect_params(plan)
    hops = [s for s in plan.steps if isinstance(s, RelHop)]
    esteps = {id(s): s for s in plan.steps}
    dom_out = db.schema.domain_size(plan.group_entity or _last_entity(plan))

    def run(*args):
        params = dict(zip(names, args))
        ids = plan.seed.ids if isinstance(plan.seed.ids, list) else [plan.seed.ids]
        seed_id = jnp.asarray(_resolve(ids[0], params), dtype=jnp.int32)

        needed = set()
        for s in plan.steps:
            e = s.measure_expr if isinstance(s, RelHop) else s.factor_expr
            if e is not None:
                needed |= {(r.var, r.attr) for r in expr_refs(e)}
        scalars = _seed_scalars(db, plan.seed, needed, params)

        R0 = jnp.zeros(dom_out, dtype=jnp.float32)

        def emit(step_i: int, cur_id, weight, R):
            """Recursively emit the nested loop for steps[step_i:]."""
            if step_i == len(plan.steps):
                return R.at[cur_id].add(weight)
            s = plan.steps[step_i]
            if isinstance(s, EntityStep):
                f = jnp.float32(1)
                if s.factor_expr is not None:
                    env = dict(scalars)
                    for r in expr_refs(s.factor_expr):
                        if r.var == s.var:
                            env[(r.var, r.attr)] = db.entity_attrs[(s.entity, r.attr)][cur_id]
                    f = eval_expr(s.factor_expr, env, params, jnp)
                return emit(step_i + 1, cur_id, weight * f, R)
            di = db.index(s.table, s.src_key)
            start = di.indptr[cur_id]
            n = di.indptr[cur_id + 1] - start

            def body(k, Rc):
                e = start + k
                nxt = di.dst_ids[e]
                wgt = weight
                if s.measure_expr is not None:
                    env = dict(scalars)
                    for r in expr_refs(s.measure_expr):
                        if r.var == s.var:
                            env[(r.var, r.attr)] = di.measures[r.attr][e]
                    wgt = wgt * eval_expr(s.measure_expr, env, params, jnp)
                return emit(step_i + 1, nxt, wgt, Rc)

            return jax.lax.fori_loop(0, n, body, R)

        return emit(0, seed_id, jnp.float32(1), R0)

    return jax.jit(run)


def _last_entity(plan: ChainPlan) -> str:
    hops = [s for s in plan.steps if isinstance(s, RelHop) and not s.degree_filter]
    return hops[-1].dst_entity if hops else plan.seed.entity


# ---------------------------------------------------------------------------
# Distributed (edge-sharded shard_map, one psum per hop)
# ---------------------------------------------------------------------------


def shard_edges(db: DeviceDB, mesh: Mesh, axes: tuple[str, ...]) -> DeviceDB:
    """Pad every index's edge arrays to a multiple of the shard count and place
    them edge-sharded on ``axes``; padding edges carry measure 0 (⇒ no effect:
    every hop multiplies by an explicit per-edge weight, ones for real edges)."""
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    out: dict[tuple[str, str], DeviceIndex] = {}
    for key, di in db.indexes.items():
        E = di.src_ids.shape[0]
        pad = (-E) % nshards
        ew = jnp.concatenate([jnp.ones(E, jnp.float32), jnp.zeros(pad, jnp.float32)])
        pd = lambda a, fill: jnp.concatenate([a, jnp.full(pad, fill, a.dtype)])
        sharding = NamedSharding(mesh, P(axes))
        nd = DeviceIndex(
            indptr=di.indptr,
            src_ids=jax.device_put(pd(di.src_ids, 0), sharding),
            dst_ids=jax.device_put(pd(di.dst_ids, 0), sharding),
            degrees=di.degrees,
        )
        nd.measures = {m: jax.device_put(pd(v, 0), sharding) for m, v in di.measures.items()}
        nd.measures["__valid__"] = jax.device_put(ew, sharding)
        out[key] = nd
    return DeviceDB(db.schema, out, db.entity_attrs, db.host_indexes)


def compile_frontier_distributed(
    db: DeviceDB, plan: ChainPlan, mesh: Mesh, axes: tuple[str, ...] = ("data",),
    batched: bool = False, frontier_dtype=jnp.float32,
) -> Callable[..., jnp.ndarray]:
    """shard_map execution: frontier vectors replicated, edges sharded; each hop
    computes a local partial accumulator and psums it — the paper's parallel
    design (§6 "Parallel Computing") with the collective replacing spinlocks.

    Edge arrays flow through shard_map *arguments* (in_specs=P(axes)) so each
    device sees only its shard; small arrays (indptr, degrees, entity attrs,
    frontier vectors) are closure constants, i.e. replicated.
    """
    try:
        from jax import shard_map as _shard_map_mod  # jax >= 0.5 style

        shard_map = jax.shard_map
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    names = collect_params(plan)
    sdb = shard_edges(db, mesh, axes)

    edge_tree = {
        f"{t}::{k}": {
            "src": di.src_ids,
            "dst": di.dst_ids,
            **{f"m::{m}": v for m, v in di.measures.items()},
        }
        for (t, k), di in sdb.indexes.items()
    }
    edge_specs = jax.tree.map(lambda _: P(axes), edge_tree)
    # replicated side tables: entity attributes + per-index degrees — arguments
    # (not closures) so the dry-run can substitute full-scale ShapeDtypeStructs
    side_tree = {
        **{f"attr::{e}::{a}": v for (e, a), v in sdb.entity_attrs.items()},
        **{f"deg::{t}::{k}": di.degrees for (t, k), di in sdb.indexes.items()},
    }
    side_specs = jax.tree.map(lambda _: P(), side_tree)

    def run(edges, side, *args):
        import types

        params = dict(zip(names, args))
        view = types.SimpleNamespace(
            schema=sdb.schema,
            entity_attrs={
                (e, a): side[f"attr::{e}::{a}"] for (e, a) in db.entity_attrs
            },
        )

        def get(table: str, key: str, name: str):
            return edges[f"{table}::{key}"][name]

        def eval_chain(plan: ChainPlan) -> jnp.ndarray:
            if isinstance(plan.seed, SeedIds):
                dom = sdb.schema.domain_size(plan.seed.entity)
                ids = plan.seed.ids if isinstance(plan.seed.ids, list) else [plan.seed.ids]
                idx = jnp.asarray([_resolve(i, params) for i in ids], dtype=jnp.int32)
                w = jnp.zeros(dom, dtype=jnp.float32).at[idx].add(1.0)
                seed_src = plan.seed
            else:
                w = jnp.ones(sdb.schema.domain_size(plan.seed.entity), jnp.float32)
                for chain in plan.seed.chains:
                    w = w * eval_chain(chain)
                if plan.seed.entity_conds:
                    w = w * _cond_mask(view, plan.seed.entity, plan.seed.entity_conds, params)
                seed_src = None
            needed = set()
            for s in plan.steps:
                e = s.measure_expr if isinstance(s, RelHop) else s.factor_expr
                if e is not None:
                    needed |= {(r.var, r.attr) for r in expr_refs(e)}
            scalars = _seed_scalars(view, seed_src, needed, params) if seed_src else {}
            for s in plan.steps:
                if isinstance(s, RelHop):
                    if s.semijoin:
                        w = (w > 0).astype(jnp.float32)
                    if s.degree_filter:
                        w = w * (side[f"deg::{s.table}::{s.src_key}"] > 0).astype(jnp.float32)
                        continue
                    ew = get(s.table, s.src_key, "m::__valid__")
                    if s.measure_expr is not None:
                        env = dict(scalars)
                        for r in expr_refs(s.measure_expr):
                            if r.var == s.var:
                                env[(r.var, r.attr)] = get(s.table, s.src_key, f"m::{r.attr}")
                        ew = ew * eval_expr(s.measure_expr, env, params, jnp)
                    part = jax.ops.segment_sum(
                        jnp.take(w, get(s.table, s.src_key, "src")) * ew,
                        get(s.table, s.src_key, "dst"),
                        num_segments=sdb.schema.domain_size(s.dst_entity),
                    )
                    # frontier_dtype=bf16 halves every per-hop all-reduce
                    w = jax.lax.psum(part.astype(frontier_dtype), axes).astype(jnp.float32)
                else:
                    if s.factor_expr is not None:
                        env = dict(scalars)
                        for r in expr_refs(s.factor_expr):
                            if r.var == s.var:
                                env[(r.var, r.attr)] = view.entity_attrs[(s.entity, r.attr)]
                        w = w * eval_expr(s.factor_expr, env, params, jnp).astype(jnp.float32)
                    if s.conds:
                        w = w * _cond_mask(view, s.entity, s.conds, params)
            if plan.group_entity is None:
                return (w > 0).astype(jnp.float32)
            return w

        if batched:
            # batched OLAP serving: vmap over parameter vectors inside the
            # shard_map body — frontier becomes [B, dom], hops become SpMM
            def scalar_eval(*scalar_args):
                nonlocal params
                saved = params
                params = dict(zip(names, scalar_args))
                out = eval_chain(plan)
                params = saved
                return out

            return jax.vmap(scalar_eval)(*args)
        return eval_chain(plan)

    smapped = shard_map(
        run,
        mesh=mesh,
        in_specs=(edge_specs, side_specs) + tuple(P() for _ in names),
        out_specs=P(),
        check_vma=False,
    )
    jitted = jax.jit(smapped)

    def call(*args):
        return jitted(edge_tree, side_tree, *args)

    call.lowerable = (jitted, edge_tree, side_tree, edge_specs, side_specs)  # dry-run hook
    return call


STRATEGIES = {
    "frontier": compile_frontier,
    "fragment_loop": compile_fragment_loop,
}
