"""GNN substrate: graph batches, segment-op message passing, radial bases.

Message passing *is* the paper's fragment join-aggregate (DESIGN.md §5): the
edge list in CSR order + gather → transform → ``segment_sum`` is exactly one
RelHop of the GQ-Fast executor, so GNN layers share that kernel regime
(kernel_taxonomy §B.3: "SpMM/SDDMM via segment ops").

Non-molecular shapes (citation/products graphs) carry synthesized 3D positions
so the equivariant architectures exercise their kernel regime at the assigned
graph sizes; node features project into the hidden width (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..common import shard_hint

EDGE_AXES = ("data",)  # edge-space sharding for full-graph workloads


@dataclass
class GraphBatch:
    """Padded, fixed-shape graph batch (dry-run friendly)."""

    pos: jnp.ndarray  # [N, 3]
    z: jnp.ndarray  # [N] atom types / node categories
    node_feat: jnp.ndarray | None  # [N, d_feat] or None
    edge_src: jnp.ndarray  # [E]
    edge_dst: jnp.ndarray  # [E]
    node_mask: jnp.ndarray  # [N] float {0,1}
    edge_mask: jnp.ndarray  # [E] float {0,1}
    graph_ids: jnp.ndarray | None = None  # [N] for batched small graphs
    n_graphs: int = 1
    labels: jnp.ndarray | None = None  # node labels or graph energies

    def as_inputs(self) -> dict:
        out = {
            "pos": self.pos, "z": self.z,
            "edge_src": self.edge_src, "edge_dst": self.edge_dst,
            "node_mask": self.node_mask, "edge_mask": self.edge_mask,
        }
        if self.node_feat is not None:
            out["node_feat"] = self.node_feat
        if self.graph_ids is not None:
            out["graph_ids"] = self.graph_ids
        if self.labels is not None:
            out["labels"] = self.labels
        return out


EDGE_HINTS = True  # toggled by the 'naive' dry-run variant (§Perf before/after)


def edge_hint(x: jnp.ndarray) -> jnp.ndarray:
    """Per-edge tensors: edge dim over 'data', channel dim over 'model' (GNN
    tensor parallelism — channels are independent through gathers/segment ops,
    so the TP axis never communicates in message passing). Without these hints
    the SPMD partitioner replicates [E, C, irreps] tensors (dry-run:
    mace×ogb_products hit 771 GB/device)."""
    if not EDGE_HINTS:
        return x
    if x.ndim >= 2:
        return shard_hint(x, "data", "model", *([None] * (x.ndim - 2)))
    return shard_hint(x, "data")


def node_hint(x: jnp.ndarray) -> jnp.ndarray:
    """Per-node tensors: replicated over nodes (gathers by edge src stay
    local), channel dim over 'model' — [N, C, irreps] at ogb_products scale is
    11.3 GB unsharded."""
    if not EDGE_HINTS:
        return x
    if x.ndim >= 2:
        return shard_hint(x, None, "model", *([None] * (x.ndim - 2)))
    return x


def aggregate(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """segment_sum into destination nodes (one RelHop): channel-sharded message
    partials reduce over the 'data' axis only (XLA inserts the all-reduce /
    reduce-scatter); the 'model' axis stays communication-free."""
    out = jax.ops.segment_sum(edge_hint(messages), dst, num_segments=n_nodes)
    return node_hint(out)


def edge_vectors(pos: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    vec = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)
    vec = edge_hint(vec)
    r = jnp.sqrt(jnp.sum(vec**2, axis=-1) + 1e-12)
    return vec, r


def gaussian_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (r[..., None] - centers) ** 2)


def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    n = jnp.arange(1, n_rbf + 1)
    rr = jnp.maximum(r[..., None], 1e-6)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rr / cutoff) / rr


def cosine_cutoff(r: jnp.ndarray, cutoff: float) -> jnp.ndarray:
    return jnp.where(r < cutoff, 0.5 * (jnp.cos(jnp.pi * r / cutoff) + 1.0), 0.0)


# ---------------------------------------------------------------------------
# Tiny MLP helper
# ---------------------------------------------------------------------------


def mlp_init(key, sizes: list[int], dtype=jnp.float32) -> list[dict]:
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        {
            "w": (jax.random.normal(ks[i], (sizes[i], sizes[i + 1]), jnp.float32)
                  / jnp.sqrt(sizes[i])).astype(dtype),
            "b": jnp.zeros((sizes[i + 1],), dtype),
        }
        for i in range(len(sizes) - 1)
    ]


def mlp_apply(params: list[dict], x: jnp.ndarray, act=jax.nn.silu, final_act: bool = False) -> jnp.ndarray:
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def readout(node_out: jnp.ndarray, batch: dict, n_graphs: int) -> jnp.ndarray:
    """Per-graph sum readout (energies) honoring padding."""
    vals = node_out * batch["node_mask"][:, None]
    if "graph_ids" in batch:
        return jax.ops.segment_sum(vals, batch["graph_ids"], num_segments=n_graphs)
    return vals.sum(axis=0, keepdims=True)
