"""End-to-end driver (the paper's kind: OLAP serving): load a PubMed-scale-
shaped synthetic database, prepare the dashboard queries once, then serve
batched interactive requests — the paper's demo dashboard workload — and
report latency percentiles + throughput.

    PYTHONPATH=src python examples/serve_analytics.py [--requests 200]
"""
import argparse
import time

import numpy as np

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG


class AnalyticsServer:
    """Prepared-query server (paper §3: prepare once / execute many)."""

    def __init__(self, engine: GQFastEngine, queries: dict[str, str]):
        self.engine = engine
        self.prepared = {name: engine.prepare(sql) for name, sql in queries.items()}
        self.latencies: dict[str, list[float]] = {n: [] for n in queries}

    def serve(self, name: str, **params) -> np.ndarray:
        t0 = time.perf_counter()
        out = self.prepared[name](**params)
        self.latencies[name].append(time.perf_counter() - t0)
        return out

    def serve_batch(self, name: str, **param_arrays) -> np.ndarray:
        t0 = time.perf_counter()
        out = self.prepared[name].execute_batch(**param_arrays)
        self.latencies[name].append(time.perf_counter() - t0)
        return out

    def report(self) -> None:
        print(f"\n{'query':10s} {'n':>5s} {'p50 ms':>9s} {'p99 ms':>9s} {'qps':>9s}")
        for name, ls in self.latencies.items():
            if not ls:
                continue
            arr = np.asarray(ls) * 1e3
            print(f"{name:10s} {len(ls):5d} {np.percentile(arr,50):9.2f} "
                  f"{np.percentile(arr,99):9.2f} {1000.0/arr.mean():9.1f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--docs", type=int, default=40_000)
    args = ap.parse_args()

    print("loading database…")
    t0 = time.time()
    schema = SG.make_pubmed(n_docs=args.docs, n_terms=1_200, n_authors=9_000, seed=5)
    db = GQFastDatabase(schema, account_space=False)
    eng = GQFastEngine(db)
    print(f"  {time.time()-t0:.1f}s "
          f"(DT {schema.relationships['DT'].num_rows} rows, "
          f"DA {schema.relationships['DA'].num_rows} rows)")

    server = AnalyticsServer(eng, {
        "AS": SG.QUERY_AS, "SD": SG.QUERY_SD, "FSD": SG.QUERY_FSD,
        "AD": SG.QUERY_AD, "FAD": SG.QUERY_FAD,
    })

    print("warmup (compilation)…")
    server.serve("AS", a0=1)
    server.serve("SD", d0=1)
    server.serve("FSD", d0=1)
    server.serve("AD", t1=1, t2=2)
    server.serve("FAD", t1=1, t2=2)
    for ls in server.latencies.values():
        ls.clear()

    # sample bindings from the loaded graph's actual id domains
    n_authors = schema.entities["Author"].size
    n_docs = schema.entities["Document"].size
    n_terms = schema.entities["Term"].size

    print(f"serving {args.requests} mixed requests…")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        kind = ["AS", "SD", "FSD", "AD", "FAD"][i % 5]
        if kind == "AS":
            server.serve("AS", a0=int(rng.integers(0, n_authors)))
        elif kind in ("SD", "FSD"):
            server.serve(kind, d0=int(rng.integers(0, n_docs)))
        else:
            server.serve(kind, t1=int(rng.integers(0, n_terms)),
                         t2=int(rng.integers(0, n_terms)))

    # batched dashboard refresh: 32 author panels in one call — the SpMM
    # serving path streams each edge block once for the whole batch
    server.serve_batch("AS", a0=rng.integers(0, n_authors, size=32))
    server.report()
    bt = server.latencies["AS"][-1]
    print(f"\nbatched AS ×32: {bt*1e3:.1f} ms total = {bt/32*1e3:.2f} ms/query "
          f"(amortized, batched frontier SpMM)")


if __name__ == "__main__":
    main()
