"""§Perf paper-faithful baseline vs TPU-native adaptation (DESIGN.md §2).

``fragment_loop`` ports the paper's generated C++ (Fig. 3) with nested lax
loops — the faithful reproduction. ``frontier`` is the vectorized whole-
relation SpMV chain. Identical results; the gap is the beyond-paper win from
re-expressing the execution for vector hardware."""
from __future__ import annotations

import numpy as np

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG
from repro.storage import device_space_report

from .common import emit, emit_trace, timeit


def run() -> None:
    schema = SG.make_pubmed(n_docs=8_000, n_terms=400, n_authors=2_000, seed=21)
    db = GQFastDatabase(schema, account_space=False)  # auto → packed device store
    db_dense = GQFastDatabase(schema, account_space=False, device_encodings="dense")
    frontier = GQFastEngine(db, strategy="frontier")
    floop = GQFastEngine(db, strategy="fragment_loop")
    auto = GQFastEngine(db, strategy="auto")

    # §Storage: decode-fused packed storage vs the decoded-CSR baseline —
    # device bytes drop while the frontier hot path stays bit-identical
    sp = device_space_report(db.device)
    sd = device_space_report(db_dense.device)
    dense_eng = GQFastEngine(db_dense, strategy="frontier")
    for qname, sql, params in [
        ("SD", SG.QUERY_SD, {"d0": 11}),
        ("AS", SG.QUERY_AS, {"a0": 17}),
    ]:
        pp, pd = frontier.prepare(sql), dense_eng.prepare(sql)
        identical = bool(np.array_equal(pp(**params), pd(**params)))
        t_p = timeit(lambda: np.asarray(pp(**params)), iters=5)
        t_d = timeit(lambda: np.asarray(pd(**params)), iters=5)
        emit(
            f"perf/{qname}/frontier_packed", t_p * 1e6,
            f"vs_decoded={t_p/t_d:.2f} bit_identical={identical} "
            f"space_ratio={sp['dense_bytes']/sp['total_bytes']:.2f}",
            device_bytes=sp["total_bytes"],
            decoded_device_bytes=sd["total_bytes"],
        )

    for qname, sql, params in [
        ("SD", SG.QUERY_SD, {"d0": 11}),
        ("AS", SG.QUERY_AS, {"a0": 17}),
    ]:
        pf, pl = frontier.prepare(sql), floop.prepare(sql)
        # fragment_loop accumulates sequentially in fp32 → larger rounding
        # error than segment_sum's tree reductions; semantics identical
        a, b = pf(**params), pl(**params)
        assert np.allclose(a, b, rtol=5e-3, atol=1e-2 * max(np.abs(a).max(), 1.0))
        t_f = timeit(lambda: np.asarray(pf(**params)), iters=5)
        t_l = timeit(lambda: np.asarray(pl(**params)), iters=2, warmup=1)
        emit(f"perf/{qname}/frontier_tpu_native", t_f * 1e6,
             f"faithful_ratio={t_l/t_f:.1f}")
        # per-op observability summary, embedded into BENCH_perf.json
        prof = pf.profile(**params)
        emit_trace(f"perf/{qname}/frontier_tpu_native", {
            "timing_method": prof.timing_method,
            "total_wall_ms": round(prof.total_wall_ms, 4),
            "per_op_self_wall_ms": prof.phase_summary(),
            "hops": [
                {"table": h.table,
                 "est_active_fraction": h.est_active_fraction,
                 "observed_active_fraction": h.observed_active_fraction,
                 "mispredict": h.mispredict}
                for h in prof.hops
            ],
        })
        emit(f"perf/{qname}/fragment_loop_paper_faithful", t_l * 1e6, "")
        pa = auto.prepare(sql)
        t_a = timeit(lambda: np.asarray(pa(**params)), iters=3)
        chosen = auto._pick_strategy(pa.plan)
        emit(f"perf/{qname}/auto", t_a * 1e6,
             f"picked={chosen} best_of_both={min(t_f, t_l)/t_a:.2f}")


if __name__ == "__main__":
    run()
