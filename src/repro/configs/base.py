"""Config protocol: every architecture exposes cells (arch × shape) that the
dry-run lowers and the smoke tests run reduced."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Cell:
    """One (arch × shape) lowering unit."""

    arch_id: str
    shape_id: str
    fn: Callable  # pure function to jit
    args: tuple  # pytrees of jax.ShapeDtypeStruct (no allocation)
    in_shardings: tuple  # matching pytrees of NamedSharding
    out_shardings: Any = None
    kind: str = "train"  # train | prefill | decode | serve
    model_flops: float | None = None  # 6·N·D convention (see EXPERIMENTS.md)
    notes: str = ""


class ArchConfig:
    arch_id: str = ""
    kind: str = ""
    shape_ids: list[str] = []

    def skip_reason(self, shape_id: str) -> str | None:
        return None

    def make_cell(self, shape_id: str, mesh, variant: str = "") -> Cell:
        """variant='' is the optimized default; 'naive' disables the
        beyond-baseline optimizations (§Perf before/after)."""
        raise NotImplementedError

    def smoke(self) -> dict:
        """Run a reduced config end-to-end on CPU; returns metrics to assert."""
        raise NotImplementedError
