"""GQ-Fast engine facade (paper Fig. 4 architecture).

``GQFastDatabase`` = Loader: builds both fragment indices per relationship table
(+ metadata: encodings, space). ``GQFastEngine`` = Query Processor: SQL → RQNA
(parse + normalize/verify) → physical chain plan → compiled executable
(prepare once / execute many, as JDBC-style prepared statements)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from ..obs import trace as T
from ..robust import faults as _faults
from ..robust.admission import PreparedCache
from ..robust.errors import QueryError, ValidationError
from . import executor as X
from .algebra import ChainPlan
from .fragments import FragmentIndex, build_index
from .fuse import fuse_plan, fusion_groups, has_fused
from .lower import PhysicalPlan, lower
from .planner import plan_query
from .schema import RelationshipTable, Schema
from .sql import parse


class GQFastDatabase:
    """In-memory GQ-Fast database: both directions of every relationship table.

    ``keep_packed`` (default True, matching ``fragments.build_index``) keeps
    the host-side bit-packed words on each ``ColumnFragments`` — the kernel
    wire layout the device column store reuses. Setting it False only trades
    host memory for a re-pack when a packed device encoding is chosen; the
    device representation is governed solely by ``device_encodings``
    (``"auto"`` | ``"dense"`` | ``"packed"`` | per-column dict keyed by
    ``(table, key, column)`` — see ``executor.build_device_db``). Deployments
    that only run the fallback strategies (``fragment_loop`` / a mesh) should
    pass ``device_encodings="dense"``: their prepares materialize every packed
    column anyway, so packed storage would cost packed *plus* dense bytes
    (visible as ``space_report()["device"]["materialized_bytes"]``)."""

    def __init__(
        self,
        schema: Schema,
        encodings: dict[tuple[str, str, str], str] | None = None,
        account_space: bool = True,
        keep_packed: bool = True,
        device_encodings: str | dict | None = "auto",
    ):
        schema.validate()
        self.schema = schema
        self.host_indexes: dict[tuple[str, str], FragmentIndex] = {}
        for rel in schema.relationships.values():
            for key in (rel.fk1, rel.fk2):
                enc = {
                    col: e
                    for (t, k, col), e in (encodings or {}).items()
                    if t == rel.name and k == key
                }
                self.host_indexes[(rel.name, key)] = build_index(
                    schema, rel, key, enc or None,
                    keep_packed=keep_packed, account_space=account_space,
                )
        self.device = X.build_device_db(schema, self.host_indexes, device_encodings)

    @classmethod
    def from_parts(cls, schema: Schema, host_indexes, device) -> "GQFastDatabase":
        """Assemble a database from already-built parts without re-running
        index construction or device encoding — the snapshot restore path
        (``storage/snapshot.py``), which rebuilds host indexes and device
        columns directly from verified stored bytes."""
        schema.validate()
        db = cls.__new__(cls)
        db.schema = schema
        db.host_indexes = host_indexes
        db.device = device
        return db

    def space_report(self) -> dict[str, Any]:
        """Host byte-array accounting (paper §5 analytic model) plus the
        ``device`` section: real bytes the device column store holds, per
        column, with the decoded-CSR baseline for the compression ratio."""
        from ..storage import device_space_report

        rep: dict[str, Any] = {"indexes": {}, "total_bytes": 0}
        for (t, k), idx in self.host_indexes.items():
            cols = {
                c: {"encoding": cf.encoding, "bytes": cf.encoded_bytes}
                for c, cf in idx.columns.items()
            }
            b = idx.total_bytes()
            rep["indexes"][f"I_{t}.{k}"] = {"columns": cols, "lookup_bytes": idx.lookup_bytes(), "bytes": b}
            rep["total_bytes"] += b
        rep["device"] = device_space_report(self.device)
        return rep


#: Ragged batches pad up to one of these sizes so the batched executable
#: compiles a bounded number of times: powers of two up to 64, then
#: multiples of 64 (a B=65 burst compiles the 128 bucket, not its own).
BATCH_BUCKET_CAP = 64


def batch_bucket(b: int) -> int:
    """Smallest bucket ≥ b: next power of two up to BATCH_BUCKET_CAP, then
    the next multiple of BATCH_BUCKET_CAP."""
    if b <= BATCH_BUCKET_CAP:
        return 1 << (b - 1).bit_length()
    return -(-b // BATCH_BUCKET_CAP) * BATCH_BUCKET_CAP


@dataclass
class PreparedQuery:
    sql: str
    plan: ChainPlan
    fn: Callable[..., Any]
    param_names: list[str]
    group_entity: str | None
    phys: PhysicalPlan | None = None  # lowered IR (None only for legacy callers)
    batched_fn: Callable[..., Any] | None = None  # SpMM batch entry (frontier)
    strategy: str = "frontier"  # resolved (auto → the picked one)
    block_skipping: str = "auto"  # frontier-sparsity mode baked into fn
    fusion: str = "auto"  # multi-hop fusion mode baked into fn
    hop_estimates: list[dict] | None = None  # per-hop selectivity estimates
    plan_sig: str | None = None  # unfused op-signature (calibration key)
    calibration: Any = None  # engine's CalibrationStore (shared, may be None)
    # observability handles (DESIGN.md §Observability): the device DB for
    # memory reports and the mesh/sharded-DB triple the distributed profiler
    # needs to rebuild prefix executables against the same placement
    device_db: Any = None
    mesh: Any = None
    shard_axes: tuple = ("data",)
    sharded_db: Any = None

    def validate_params(self, params: dict) -> None:
        """Typed parameter-binding validation: every declared parameter bound,
        no unknown names — callers get a :class:`ValidationError` instead of a
        raw KeyError out of the argument zip."""
        missing = [n for n in self.param_names if n not in params]
        if missing:
            raise ValidationError(
                f"missing parameters: {missing}",
                missing=missing, expected=list(self.param_names),
                query=" ".join(self.sql.split()),
            )
        extra = [n for n in params if n not in self.param_names]
        if extra:
            raise ValidationError(
                f"unknown parameters: {extra}",
                unknown=extra, expected=list(self.param_names),
                query=" ".join(self.sql.split()),
            )

    def __call__(self, **params) -> np.ndarray:
        self.validate_params(params)
        args = [params[n] for n in self.param_names]
        if T.current() is None:  # the zero-overhead default path
            return np.asarray(self.fn(*args))
        with T.span("execute", strategy=self.strategy,
                    query=" ".join(self.sql.split())) as sp:
            out = sp.fence(self.fn(*args))  # kernel_ms: device-done
            return np.asarray(out)

    def profile(self, reps: int = 3, **params) -> Any:
        """Execute once under instrumentation and return a
        :class:`repro.obs.profile.QueryProfile`: per-IR-op wall/kernel times,
        predicted-vs-observed per-hop active fractions (mispredictions beyond
        2× increment the ``strategy_mispredict`` counter), device-memory
        report, and the fenced end-to-end median of ``reps`` runs. The profile
        ``result`` comes from the same compiled executable ``__call__`` runs,
        so it is bit-identical to plain execution."""
        from ..obs.profile import profile_prepared

        return profile_prepared(self, params, reps=reps)

    def explain(self, analyze: bool = False, **params) -> str:
        """Human-readable execution summary: the op pipeline, the resolved
        strategy, the block-skipping mode, and per-hop estimated active
        fractions (the selectivity model behind strategy choice and the
        skip-vs-scan heuristic, DESIGN.md §Sparsity).

        ``analyze=True`` additionally executes the query once with the given
        parameter bindings and appends the :meth:`profile` report: per-IR-op
        wall/kernel time, predicted-vs-observed hop fractions (mispredicts
        flagged), and the device-memory footprint — EXPLAIN ANALYZE."""
        lines = [
            f"query: {' '.join(self.sql.split())}",
            f"strategy: {self.strategy}",
            f"block_skipping: {self.block_skipping}",
            f"fusion: {self.fusion}",
            f"params: {self.param_names}",
        ]
        if self.phys is not None:
            sig = " -> ".join(type(op).__name__ for op in self.phys.ops)
            lines.append(f"ops: {sig}")
            for g in fusion_groups(self.phys):
                lines.append(f"  fused region: {g}")
        for h in self.hop_estimates or []:
            lines.append(
                f"  hop I_{h['table']}.{h['src_key']}: "
                f"est_active_fraction={h['est_active_fraction']:.4g}"
            )
        if analyze:
            lines.append(self.profile(**params).render())
        return "\n".join(lines)

    def _batch_args(self, param_arrays: dict) -> tuple[list[np.ndarray], int]:
        """Validate one [B] array (or Python list) per parameter: every
        parameter present, none scalar, all the same length."""
        if not self.param_names:
            raise ValidationError(
                "execute_batch needs a parameterized query (this one has none);"
                " call the prepared query directly instead"
            )
        missing = [n for n in self.param_names if n not in param_arrays]
        if missing:
            raise ValidationError(
                f"execute_batch missing parameter arrays: {missing}",
                missing=missing, expected=list(self.param_names),
            )
        args, B = [], None
        for n in self.param_names:
            a = np.asarray(param_arrays[n])
            if a.ndim == 0:
                raise ValidationError(
                    f"execute_batch parameter {n!r} is a scalar; pass a list or"
                    " 1-D array with one value per query (a scalar would"
                    " silently broadcast to every query in the batch)",
                    param=n,
                )
            if a.ndim != 1:
                raise ValidationError(
                    f"execute_batch parameter {n!r} must be 1-D, got shape {a.shape}",
                    param=n, shape=a.shape,
                )
            if B is None:
                B = a.shape[0]
            elif a.shape[0] != B:
                raise ValidationError(
                    f"ragged batch: parameter {n!r} has length {a.shape[0]} but"
                    f" {self.param_names[0]!r} has length {B}; all parameter"
                    " arrays must have one entry per query",
                    param=n,
                )
            args.append(a)
        if B == 0:
            raise ValidationError("execute_batch got empty parameter arrays")
        return args, B

    def execute_batch(self, **param_arrays) -> np.ndarray:
        """Serve B parameter bindings of this query in one pass → [B, out_dom].

        On the frontier strategy this runs the batched SpMM executable
        (``compile_frontier_batched``): each hop streams the edge arrays once
        for the whole batch. Ragged B pads up to a bucket size (repeating the
        last row; the pad rows are sliced off) so recompiles are bounded.
        Strategies without a batched interpreter (fragment_loop, distributed
        meshes) fall back to ``jax.vmap`` over the single-query executable —
        same results, no edge-stream reuse."""
        args, B = self._batch_args(param_arrays)
        bucket = batch_bucket(B)
        if bucket != B:  # bound recompiles on the fallback path too
            args = [
                np.concatenate([a, np.repeat(a[-1:], bucket - B, axis=0)])
                for a in args
            ]
        if self.batched_fn is None:
            import jax

            return np.asarray(jax.vmap(self.fn)(*args))[:B]
        return np.asarray(self.batched_fn(*args))[:B]


class CalibrationStore:
    """Observed per-hop active fractions keyed by (unfused) plan signature.

    ``profile_prepared`` records what a real execution actually touched; the
    next ``prepare`` of any query lowering to the same op shape consults the
    observation in :meth:`GQFastEngine._pick_strategy` instead of trusting the
    lower-time fanout model alone — profiling a workload once recalibrates
    strategy choice for its whole plan family. Bounded (LRU-ish: dict
    insertion order, oldest evicted) so long-lived engines cannot grow it
    without limit."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._obs: dict[str, list[float]] = {}

    def record(self, plan_sig: str, fractions: list) -> None:
        vals = [float(f) for f in fractions if f is not None]
        if not vals:
            return
        self._obs.pop(plan_sig, None)
        self._obs[plan_sig] = vals
        while len(self._obs) > self.max_entries:
            self._obs.pop(next(iter(self._obs)))

    def get(self, plan_sig: str) -> list[float] | None:
        return self._obs.get(plan_sig)

    def __len__(self) -> int:
        return len(self._obs)


class GQFastEngine:
    def __init__(self, db: GQFastDatabase, strategy: str = "frontier",
                 mesh=None, shard_axes: tuple[str, ...] = ("data",),
                 max_prepared: int = 64):
        self.db = db
        self.strategy = strategy
        self.mesh = mesh
        self.shard_axes = shard_axes
        # fixed-size LRU: each entry pins a traced executable pair, so the
        # prepare cache must not grow without bound under many query shapes
        self._cache: PreparedCache = PreparedCache(max_prepared)
        # per-plan-signature observed active fractions (fed by profile runs)
        self.calibration = CalibrationStore()

    def invalidate_prepared(self) -> int:
        """Drop every cached prepared query. Required after the device arrays
        under the executables change in place — a scrubber heal or a snapshot
        generation swap — because traced executables close over the old
        buffers. Returns the number of entries dropped."""
        return self._cache.clear()

    def prepare(self, sql: str, block_skipping: str = "auto",
                fusion: str = "auto") -> PreparedQuery:
        """Compile ``sql`` once for repeated execution. ``block_skipping``
        ('auto' | 'on' | 'off') sets the frontier-sparsity mode baked into the
        executable (DESIGN.md §Sparsity): 'auto' skips inactive edge blocks
        when the estimated/observed active fraction is small, 'on' forces the
        scalar-prefetch kernels, 'off' always full-scans. ``fusion`` ('auto' |
        'on' | 'off') controls multi-hop region fusion (DESIGN.md §Pipelined
        fusion): adjacent HopOp chains execute as one kernel pass with the
        intermediate frontier resident in VMEM scratch; 'auto' additionally
        falls back per-region when the intermediate would overflow the VMEM
        budget. Frontier strategy only — fragment_loop and meshes always run
        the unfused plan."""
        from ..kernels.ops import BLOCK_SKIPPING_MODES, FUSION_MODES

        if block_skipping not in BLOCK_SKIPPING_MODES:
            raise ValidationError(
                f"block_skipping must be one of {BLOCK_SKIPPING_MODES}, "
                f"got {block_skipping!r}",
                block_skipping=block_skipping, valid=BLOCK_SKIPPING_MODES,
            )
        if fusion not in FUSION_MODES:
            raise ValidationError(
                f"fusion must be one of {FUSION_MODES}, got {fusion!r}",
                fusion=fusion, valid=FUSION_MODES,
            )
        key = (sql, self.strategy, block_skipping, fusion)
        cached = self._cache.get(key)
        if cached is not None:
            return cached
        _faults.fire("engine.prepare", query=" ".join(sql.split()))
        with T.span("prepare", query=" ".join(sql.split())):
            try:
                with T.span("parse"):
                    ast = parse(sql)
                with T.span("plan"):
                    plan = plan_query(self.db.schema, ast)
                # lower once: every strategy interprets the same physical IR,
                # and the per-execute mask/ref-resolution work is hoisted out
                # of the hot path
                with T.span("lower"):
                    phys = lower(self.db.device, plan)
            except QueryError as e:
                # every prepare-stage failure carries the query text
                raise e.with_context(query=" ".join(sql.split()))
            # the UNFUSED signature keys the calibration store, so a fused
            # and an unfused prepare of the same shape share observations
            plan_sig = " -> ".join(phys.op_signature())
            names = list(phys.param_names)
            bfn, sdb = None, None
            # the compile span covers executable construction; jax traces and
            # XLA-compiles lazily, so the first execute span absorbs that cost
            with T.span("compile") as csp:
                if self.mesh is not None:
                    strategy = "distributed"  # skipping n/a: sharded XLA hops
                    sdb = X.shard_edges(self.db.device, self.mesh, self.shard_axes)
                    fn = X.compile_frontier_distributed(
                        self.db.device, phys, self.mesh, self.shard_axes,
                        sharded_db=sdb,
                    )
                    if names:  # shard_map body vmaps over the parameter vectors
                        bfn = X.compile_frontier_distributed(
                            self.db.device, phys, self.mesh, self.shard_axes,
                            batched=True, sharded_db=sdb,
                        )
                else:
                    strategy = self.strategy
                    if strategy == "auto":
                        strategy = self._pick_strategy(plan, plan_sig)
                    if strategy == "frontier" and fusion != "off":
                        with T.span("fuse"):
                            phys = fuse_plan(phys, fusion)
                    if strategy == "frontier":
                        fn = X.compile_frontier(
                            self.db.device, phys,
                            block_skipping=block_skipping, fusion=fusion,
                        )
                    else:
                        fn = X.STRATEGIES[strategy](
                            self.db.device, phys, block_skipping=block_skipping
                        )
                    if strategy == "frontier" and names:
                        # the SpMM serving path: one edge stream per hop for
                        # the whole batch. fragment_loop keeps the vmap
                        # fallback so its batched results stay bit-identical
                        # to its own single-query calls.
                        bfn = X.compile_frontier_batched(
                            self.db.device, phys,
                            block_skipping=block_skipping, fusion=fusion,
                        )
                csp.annotate(strategy=strategy, n_ops=len(phys.ops),
                             fused=has_fused(phys))
            pq = PreparedQuery(
                sql, plan, fn, names, plan.group_entity, phys, bfn,
                strategy=strategy, block_skipping=block_skipping,
                fusion=fusion, hop_estimates=self._hop_fractions(plan),
                plan_sig=plan_sig, calibration=self.calibration,
                device_db=self.db.device, mesh=self.mesh,
                shard_axes=self.shard_axes, sharded_db=sdb,
            )
        self._cache.put(key, pq)
        return pq

    def _hop_fractions(self, plan: ChainPlan) -> list[dict]:
        """Per-hop estimated active fraction: seed cardinality pushed through
        p90 fanouts. ``frontier_est × p90(degree)`` edges are expected to be
        touched out of E — the 90th-percentile fragment length rather than
        the mean, because graph degree distributions are heavy-tailed and a
        seed that lands on a hub makes the *average* a serious
        under-prediction of touched work (the mispredict pattern the profile
        counter kept flagging); p90 over-predicts the median seed slightly,
        which only errs toward the throughput-safe frontier strategy. The
        reached-destination count caps at the dst domain, and a mask seed
        starts whole-domain (fraction 1). This is the shared selectivity
        model behind ``_pick_strategy`` and the explain() report; the runtime
        skip heuristic measures the real support instead (kernels/ops.py)."""
        from .algebra import RelHop, SeedIds

        if isinstance(plan.seed, SeedIds):
            ids = plan.seed.ids if isinstance(plan.seed.ids, list) else [plan.seed.ids]
            frontier_est: float | None = float(len(ids))
        else:
            frontier_est = None  # mask seed: whole-domain support
        hops = []
        for s in plan.steps:
            if not isinstance(s, RelHop) or s.degree_filter:
                continue
            idx = self.db.host_indexes[(s.table, s.src_key)]
            E = max(idx.num_edges, 1)
            h = max(idx.indptr.shape[0] - 1, 1)
            degrees = np.diff(np.asarray(idx.indptr))
            fanout = float(np.percentile(degrees, 90)) if degrees.size else 0.0
            fanout = max(fanout, E / h)  # p90 never below the mean edge share
            if frontier_est is None:
                frontier_est = float(h)
            touched = min(frontier_est * fanout, float(E))
            hops.append({
                "table": s.table,
                "src_key": s.src_key,
                "est_active_fraction": touched / E,
            })
            frontier_est = min(touched, float(self.db.schema.domain_size(s.dst_entity)))
        return hops

    def _pick_strategy(self, plan: ChainPlan, plan_sig: str | None = None) -> str:
        """Beyond-paper: cost-based strategy choice. The paper's fragment-at-a-
        time execution is *work-efficient* (touches only reachable fragments);
        the vectorized frontier pass is *throughput-efficient* (whole-relation
        SpMV). The seed-cardinality × fanout selectivity estimate
        (:meth:`_hop_fractions`) decides: if every hop touches a small
        fraction of its relation, the scalar fragment walk wins; once any hop
        goes dense, the vectorized frontier does (EXPERIMENTS.md §Perf).
        When the calibration store holds *observed* fractions for this plan
        signature (a prior profile run of the same op shape), those replace
        the model — measured reality beats the fanout estimate."""
        from .algebra import SeedIds

        if not isinstance(plan.seed, SeedIds):
            return "frontier"  # mask seeds are whole-domain already
        fracs = None
        if plan_sig is not None:
            fracs = self.calibration.get(plan_sig)
        if fracs is None:
            fracs = [h["est_active_fraction"] for h in self._hop_fractions(plan)]
        worst_fraction = max(fracs, default=1.0)
        # crossover measured on this host (benchmarks/perf_baseline): the scalar
        # loop wins while < ~15% of the relation is touched; on TPU the vector
        # path's advantage is larger, so deployments should retune this knob
        return "fragment_loop" if worst_fraction < 0.15 else "frontier"

    def query(self, sql: str, **params) -> np.ndarray:
        return self.prepare(sql)(**params)

    def query_topk(self, sql: str, k: int = 10, **params) -> list[tuple[int, float]]:
        scores = self.query(sql, **params)
        return self._topk(scores, k)

    def query_topk_batch(
        self, sql: str, k: int = 10, **param_arrays
    ) -> list[list[tuple[int, float]]]:
        """Batched form of :meth:`query_topk`: one [B]-array per parameter,
        one SpMM pass, one top-k list per query (dashboard panels)."""
        scores = self.prepare(sql).execute_batch(**param_arrays)
        return [self._topk(row, k) for row in scores]

    @staticmethod
    def _topk(scores: np.ndarray, k: int) -> list[tuple[int, float]]:
        idx = np.argsort(-scores)[:k]
        return [(int(i), float(scores[i])) for i in idx if scores[i] != 0]
