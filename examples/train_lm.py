"""Train a small LM for a few hundred steps with the fault-tolerant loop
(checkpoints, resume, straggler telemetry). CPU-sized model, real substrate.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--moe]
"""
import argparse

import jax

from repro.data.lm_data import lm_batch
from repro.models.transformer import MoEConfig, TransformerConfig, init_params, loss_fn
from repro.optim.adamw import AdamWConfig, cosine_warmup
from repro.train.loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=128, dense_residual=False) if args.moe else None
    cfg = TransformerConfig(
        "lm-small", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=512, vocab=2048, d_head=32, remat=False, attn_kv_chunk=128, moe=moe,
    )
    params = init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params ({'MoE' if args.moe else 'dense'})")

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir, ckpt_keep=2,
    )
    opt_cfg = AdamWConfig(lr=cosine_warmup(3e-3, 20, args.steps), weight_decay=0.01)

    def data(step: int):
        return lm_batch(step, batch=16, seq=128, vocab=cfg.vocab, seed=42)

    params, res = train(
        params, lambda p, b: loss_fn(p, b, cfg), data, loop_cfg, opt_cfg, resume=True,
    )
    if res.resumed_from:
        print(f"resumed from checkpoint at step {res.resumed_from}")
    hist = res.history
    for rec in hist[:: max(1, len(hist) // 10)]:
        print(f"  step {rec['step']:4d} loss {rec['loss']:.4f} "
              f"({rec['step_time']*1e3:.0f} ms{' STRAGGLER' if rec['straggler'] else ''})")
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
