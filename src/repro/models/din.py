"""DIN — Deep Interest Network [arXiv:1706.06978].

Target attention over the user behaviour sequence: for candidate item c and
history h_1..h_T, attention MLP scores a(h_t, c) over [h, c, h−c, h⊙c]
(the paper's activation unit, 80-40 MLP), weighted-sum pooled, concatenated
with user/context features into the 200-80 output MLP.

Shapes served: train_batch (65k), serve_p99 (512), serve_bulk (262k),
retrieval_cand (1 user × 10⁶ candidates — batched dot scoring, no loop).
Embedding lookups are the hot path: EmbeddingBag over sharded tables
(DESIGN.md §5 — directly the paper's fragment lookup + γ).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import shard_hint
from .gnn.common import mlp_apply, mlp_init


@dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: tuple[int, ...] = (80, 40)
    mlp_hidden: tuple[int, ...] = (200, 80)
    n_items: int = 10_000_000
    n_users: int = 1_000_000
    n_cates: int = 100_000

    def param_count(self) -> int:
        d = self.embed_dim
        emb = (self.n_items + self.n_users + self.n_cates) * d
        attn = 4 * d * 80 + 80 * 40 + 40 * 1 + 121
        mlp = (4 * d) * 200 + 200 * 80 + 80 * 1 + 281
        return emb + attn + mlp

    def active_param_count(self) -> int:
        """Params touched per example: MLPs + the (T+2) embedding rows gathered
        (embedding tables are lookup-sparse — DESIGN.md roofline convention)."""
        d = self.embed_dim
        attn = 4 * d * 80 + 80 * 40 + 40 * 1 + 121
        mlp = (4 * d) * 200 + 200 * 80 + 80 * 1 + 281
        return attn * self.seq_len + mlp + (self.seq_len + 2) * d


def din_init(cfg: DINConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, d), jnp.float32) * 0.01,
        "cate_emb": jax.random.normal(ks[1], (cfg.n_cates, d), jnp.float32) * 0.01,
        "user_emb": jax.random.normal(ks[2], (cfg.n_users, d), jnp.float32) * 0.01,
        "attn": mlp_init(ks[3], [4 * d, *cfg.attn_hidden, 1]),
        "mlp": mlp_init(ks[4], [4 * d, *cfg.mlp_hidden, 1]),
    }


def _target_attention(p, hist: jnp.ndarray, hist_mask: jnp.ndarray, cand: jnp.ndarray) -> jnp.ndarray:
    """hist [B,T,D], cand [B,D] → pooled interest [B,D] (DIN activation unit)."""
    B, T, D = hist.shape
    c = jnp.broadcast_to(cand[:, None, :], (B, T, D))
    feats = jnp.concatenate([hist, c, hist - c, hist * c], axis=-1)
    logits = mlp_apply(p["attn"], feats, act=jax.nn.sigmoid)[..., 0]  # [B,T]
    w = jnp.where(hist_mask > 0, logits, 0.0)  # paper: no softmax, masked weights
    return jnp.einsum("bt,btd->bd", w, hist)


def din_forward(p: dict, batch: dict, cfg: DINConfig) -> jnp.ndarray:
    """batch: user [B], hist_items [B,T], hist_mask [B,T], cand_item [B] → logits [B]."""
    hist = jnp.take(p["item_emb"], batch["hist_items"], axis=0)  # [B,T,D]
    hist = shard_hint(hist, ("pod", "data"), None, None)
    cand = jnp.take(p["item_emb"], batch["cand_item"], axis=0)  # [B,D]
    user = jnp.take(p["user_emb"], batch["user"], axis=0)
    interest = _target_attention(p, hist, batch["hist_mask"], cand)
    x = jnp.concatenate([user, interest, cand, interest * cand], axis=-1)
    return mlp_apply(p["mlp"], x, act=jax.nn.relu)[..., 0]


def din_retrieval_scores(p: dict, batch: dict, cfg: DINConfig) -> jnp.ndarray:
    """One user/history against n_candidates items: the pooled interest must be
    re-computed per candidate (DIN's point), but batched — [N] scores with the
    candidate dimension as the batch axis, no loop."""
    hist = jnp.take(p["item_emb"], batch["hist_items"], axis=0)  # [1,T,D]
    cands = jnp.take(p["item_emb"], batch["cand_items"], axis=0)  # [N,D]
    N = cands.shape[0]
    T, D = hist.shape[1], hist.shape[2]
    hist_b = jnp.broadcast_to(hist, (N, T, D))
    mask_b = jnp.broadcast_to(batch["hist_mask"], (N, T))
    user = jnp.broadcast_to(jnp.take(p["user_emb"], batch["user"], axis=0), (N, D))
    interest = _target_attention(p, hist_b, mask_b, cands)
    x = jnp.concatenate([user, interest, cands, interest * cands], axis=-1)
    return mlp_apply(p["mlp"], x, act=jax.nn.relu)[..., 0]


def din_loss(p: dict, batch: dict, cfg: DINConfig):
    logits = din_forward(p, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}
