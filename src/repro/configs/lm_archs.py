"""The five assigned LM architectures, exact configs from the assignment."""
from __future__ import annotations

import jax.numpy as jnp

from ..models.transformer import MoEConfig, TransformerConfig
from ..optim.adamw import AdamWConfig
from .lm_family import make_lm_arch

# codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: 32L d4096 32H (GQA kv=32 = MHA)
# d_ff=13440 vocab=92416, QKV bias (qwen1.5 arch)
CODEQWEN15_7B = make_lm_arch(
    "codeqwen1.5-7b",
    TransformerConfig(
        "codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab=92416, d_head=128, qkv_bias=True, rope_theta=1_000_000.0,
    ),
)

# qwen2.5-3b [hf]: 36L d2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias
QWEN25_3B = make_lm_arch(
    "qwen2.5-3b",
    TransformerConfig(
        "qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab=151936, d_head=128, qkv_bias=True, rope_theta=1_000_000.0,
        tie_embeddings=True,
    ),
)

# llama3-8b [arXiv:2407.21783]: 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=128256
LLAMA3_8B = make_lm_arch(
    "llama3-8b",
    TransformerConfig(
        "llama3-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, d_head=128, rope_theta=500_000.0,
    ),
)

# arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d7168 56H (GQA kv=8)
# dense-residual d_ff=4864 ∥ MoE 128e top-2. Optimizer state: bf16 moments +
# bf16 params — keeps the param-tree layout (FSDP sharding propagates; the
# int8 blocked layout forces replicating reshapes at 512 devices, see
# EXPERIMENTS.md §Perf #6) while halving state HBM: ~7.5 GB/chip total.
ARCTIC_480B = make_lm_arch(
    "arctic-480b",
    TransformerConfig(
        "arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab=32000, d_head=128, param_dtype=jnp.bfloat16,
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
    ),
    opt=AdamWConfig(lr=1e-4, moment_dtype=jnp.bfloat16),
)

# olmoe-1b-7b [arXiv:2409.02060]: 16L d2048 16H (kv=16) MoE 64e top-8 d_ff=1024
OLMOE_1B_7B = make_lm_arch(
    "olmoe-1b-7b",
    TransformerConfig(
        "olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304, d_head=128,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, dense_residual=False),
    ),
)
