"""Pallas TPU kernel: fused fragment join-aggregate (one relationship hop).

y[dst] ⊕= w[src] ⊗ m over the edge list of a GQ-Fast index — the frontier SpMV
that every ⋈/⋉+γ hop lowers to (DESIGN.md §4). The combine op ⊕ is a parameter
(``op``: 'sum' | 'min' | 'max' | 'bool'), matching the executor's semiring
plug-in point, so SUM/COUNT, MIN/MAX and EXISTS hops all run through this one
kernel. The frontier vector ``w`` and the dense accumulator ``y`` live in VMEM
for the whole pass (entity domains up to a few M fit v5e's 16 MB VMEM in fp32
tiles); the edge arrays stream through in blocks. The output BlockSpec maps
every grid step to the same block — the canonical Pallas accumulate-over-grid
pattern — so the scatter-⊕ stays on-chip instead of bouncing to HBM per block
(the paper's "spinlocked shared array", contention-free).

Gather (jnp.take) and scatter-⊕ (segment_sum/min/max) inside the body lower to
Mosaic dynamic-gather / scatter; on TPU generations without scatter support,
``ops.fragment_spmv`` falls back to the pure-XLA path (same math, same layout).
Edges arrive sorted by src (CSR order) which makes the gather quasi-sequential.

Padding edges point src past the frontier so the gather fills the ⊕-identity,
and carry measure 0 — under every op they contribute the identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .params import EDGE_BLOCK  # shared block geometry (kernels/params.py)

# ⊕-identity per combine op ("no path reaches this entity")
IDENTITY = {
    "sum": 0.0,
    "min": float("inf"),
    "max": float("-inf"),
    "bool": 0.0,
}


def _edge_product(w, src, m, op: str):
    """w[src] ⊗ m with the identity guard non-sum lattices need (∞·0 = NaN)."""
    zero = IDENTITY[op]
    ws = jnp.take(w, src, fill_value=zero)
    if op == "sum":
        return ws * m
    if op == "bool":
        return ((ws > 0) & (m != 0)).astype(jnp.float32)
    return jnp.where(ws == zero, zero, ws * m)


def _segment_combine(prod, dst, n_dst: int, op: str):
    if op == "sum":
        return jax.ops.segment_sum(prod, dst, num_segments=n_dst)
    if op == "min":
        return jax.ops.segment_min(prod, dst, num_segments=n_dst)
    return jax.ops.segment_max(prod, dst, num_segments=n_dst)  # max | bool


def _combine(a, b, op: str):
    if op == "sum":
        return a + b
    if op == "min":
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _kernel(n_dst: int, op: str, w_ref, src_ref, dst_ref, m_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    prod = _edge_product(w_ref[...], src_ref[...], m_ref[...], op)
    blk = _segment_combine(prod, dst_ref[...], n_dst, op)
    out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(jax.jit, static_argnames=("n_dst", "op", "interpret"))
def fragment_spmv(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst_ids: jnp.ndarray,
    measures: jnp.ndarray,
    n_dst: int,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    E = src_ids.shape[0]
    if E == 0:  # empty relation: no edge contributes, everything is ⊕-identity
        return jnp.full((n_dst,), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    if pad:
        # padding edges: src points past the frontier (gather fills the
        # ⊕-identity), measure 0 ⇒ identity contribution under every op
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, weights.shape[0], jnp.int32)])
        dst_ids = jnp.concatenate([dst_ids, jnp.zeros(pad, jnp.int32)])
        measures = jnp.concatenate([measures, jnp.zeros(pad, jnp.float32)])
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)

    return pl.pallas_call(
        functools.partial(_kernel, n_dst, op),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(weights.shape, lambda i: (0,)),  # frontier resident
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_dst,), lambda i: (0,)),  # accumulate over grid
        out_shape=jax.ShapeDtypeStruct((n_dst,), jnp.float32),
        interpret=interpret,
    )(weights, src_ids, dst_ids, measures)


# ---------------------------------------------------------------------------
# Active-block (frontier-sparsity) variant — scalar-prefetch block skipping
# ---------------------------------------------------------------------------


def _kernel_active(n_dst: int, op: str, na_ref, bi_ref,
                   w_ref, src_ref, dst_ref, m_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    @pl.when(i < na_ref[0])
    def _compute():
        prod = _edge_product(w_ref[...], src_ref[...], m_ref[...], op)
        blk = _segment_combine(prod, dst_ref[...], n_dst, op)
        out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(jax.jit, static_argnames=("n_dst", "op", "interpret"))
def fragment_spmv_active(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst_ids: jnp.ndarray,
    measures: jnp.ndarray,
    block_idx: jnp.ndarray,  # int32[C] — surviving block ids, tail repeats last
    n_active: jnp.ndarray,  # int32[1]
    n_dst: int,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    """Frontier-sparsity SpMV: only the blocks named by ``block_idx`` are ever
    DMA'd from HBM. ``block_idx``/``n_active`` ride in SMEM via
    ``pltpu.PrefetchScalarGridSpec`` and drive the edge-array ``index_map``;
    grid steps past ``n_active`` revisit the last active block (no new DMA) and
    skip the compute under ``pl.when``. Per-block math and ⊕-combine order are
    identical to :func:`fragment_spmv`, and every skipped block's contribution
    is the ⊕-identity, so results are bit-identical to the full scan
    (see kernels/active.py)."""
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    E = src_ids.shape[0]
    if E == 0:
        return jnp.full((n_dst,), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    if pad:
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, weights.shape[0], jnp.int32)])
        dst_ids = jnp.concatenate([dst_ids, jnp.zeros(pad, jnp.int32)])
        measures = jnp.concatenate([measures, jnp.zeros(pad, jnp.float32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (n_active, block_idx) land in SMEM
        grid=(block_idx.shape[0],),
        in_specs=[
            pl.BlockSpec(weights.shape, lambda i, na, bi: (0,)),  # resident
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)),
        ],
        out_specs=pl.BlockSpec((n_dst,), lambda i, na, bi: (0,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_active, n_dst, op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst,), jnp.float32),
        interpret=interpret,
    )(n_active, block_idx, weights, src_ids, dst_ids, measures)
