"""Train EGNN (or any assigned GNN) on synthetic molecule energies.

    PYTHONPATH=src python examples/gnn_molecules.py [--arch egnn|schnet|mace|equiformer_v2]
"""
import argparse

import jax

from repro.data.graphs import make_molecule_batch
from repro.models.gnn.models import GNNConfig, gnn_init, gnn_loss
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train

CFGS = {
    "egnn": GNNConfig("egnn", "egnn", n_layers=4, d_hidden=64),
    "schnet": GNNConfig("schnet", "schnet", n_layers=3, d_hidden=64, n_rbf=32, cutoff=8.0),
    "mace": GNNConfig("mace", "mace", n_layers=2, d_hidden=32, l_max=2,
                      correlation=3, n_rbf=8, cutoff=6.0),
    "equiformer_v2": GNNConfig("eqv2", "equiformer_v2", n_layers=2, d_hidden=32,
                               l_max=3, m_max=2, n_heads=4, n_rbf=8, cutoff=6.0),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="egnn", choices=list(CFGS))
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = CFGS[args.arch]
    params = gnn_init(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch}: {n/1e3:.0f}k params")

    batches = [make_molecule_batch(batch=16, n_nodes=12, n_edges=32, seed=s).as_inputs()
               for s in range(8)]

    params, res = train(
        params,
        lambda p, b: gnn_loss(p, b, cfg, 16),
        lambda step: batches[step % len(batches)],
        TrainLoopConfig(total_steps=args.steps, ckpt_every=1000,
                        ckpt_dir="/tmp/repro_gnn_ckpt"),
        AdamWConfig(lr=3e-3, weight_decay=0.0),
        resume=False,
    )
    hist = res.history
    for rec in hist[:: max(1, len(hist) // 8)]:
        print(f"  step {rec['step']:3d} loss {rec['loss']:.4f}")
    print(f"final {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
