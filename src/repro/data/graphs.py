"""Graph data pipeline: synthetic graph generators + a real CSR neighbor sampler.

Shapes follow the assigned grid: full_graph_sm (Cora-like), minibatch_lg
(Reddit-like, sampled via the fanout sampler), ogb_products (large full-batch),
molecule (batched small graphs). Non-molecular graphs get synthesized 3D
positions (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..models.gnn.common import GraphBatch


def make_molecule_batch(
    batch: int = 128, n_nodes: int = 30, n_edges: int = 64, seed: int = 0
) -> GraphBatch:
    rng = np.random.default_rng(seed)
    N = batch * n_nodes
    pos = rng.normal(size=(batch, n_nodes, 3)) * 2.0
    z = rng.integers(1, 10, size=(batch, n_nodes))
    # per-graph edges: nearest pairs (undirected → both directions), capped
    srcs, dsts = [], []
    for b in range(batch):
        d = np.linalg.norm(pos[b, :, None] - pos[b, None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        order = np.argsort(d, axis=None)[: n_edges // 2]
        i, j = np.unravel_index(order, d.shape)
        srcs.append(np.concatenate([i, j]) + b * n_nodes)
        dsts.append(np.concatenate([j, i]) + b * n_nodes)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    energies = (z.sum(axis=1) * 0.1 + rng.normal(size=batch) * 0.01).astype(np.float32)
    import jax.numpy as jnp

    return GraphBatch(
        pos=jnp.asarray(pos.reshape(N, 3), jnp.float32),
        z=jnp.asarray(z.reshape(N), jnp.int32),
        node_feat=None,
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        node_mask=jnp.ones(N, jnp.float32),
        edge_mask=jnp.ones(src.shape[0], jnp.float32),
        graph_ids=jnp.asarray(graph_ids),
        n_graphs=batch,
        labels=jnp.asarray(energies),
    )


def make_feature_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 40, seed: int = 0
) -> GraphBatch:
    """Citation/products-like graph: power-law degrees, features, class labels,
    synthesized 3D layout."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish edge list
    src = rng.integers(0, n_nodes, size=n_edges)
    w = rng.zipf(1.6, size=n_edges).astype(np.int64) % n_nodes
    dst = w
    import jax.numpy as jnp

    return GraphBatch(
        pos=jnp.asarray(rng.normal(size=(n_nodes, 3)), jnp.float32),
        z=jnp.asarray(rng.integers(0, 10, n_nodes), jnp.int32),
        node_feat=jnp.asarray(rng.normal(size=(n_nodes, d_feat)) * 0.1, jnp.float32),
        edge_src=jnp.asarray(src.astype(np.int32)),
        edge_dst=jnp.asarray(dst.astype(np.int32)),
        node_mask=jnp.ones(n_nodes, jnp.float32),
        edge_mask=jnp.ones(n_edges, jnp.float32),
        labels=jnp.asarray(rng.integers(0, n_classes, n_nodes), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Neighbor sampler (minibatch_lg: batch_nodes=1024, fanout 15-10)
# ---------------------------------------------------------------------------


@dataclass
class CSRGraph:
    indptr: np.ndarray
    indices: np.ndarray
    feat: np.ndarray | None
    labels: np.ndarray | None

    @staticmethod
    def random(n_nodes: int, n_edges: int, d_feat: int, n_classes: int = 41, seed: int = 0):
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
        order = np.argsort(src, kind="stable")
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(np.bincount(src, minlength=n_nodes), out=indptr[1:])
        feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32) * 0.1 if d_feat else None
        labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
        return CSRGraph(indptr, dst[order].astype(np.int64), feat, labels)


class NeighborSampler:
    """GraphSAGE-style layered uniform fanout sampling over a CSR graph.

    Produces fixed-shape padded subgraph batches (jit/dry-run friendly): for
    fanouts [f1, f2] the node budget is b·(1 + f1 + f1·f2) and the edge budget
    b·f1·(1 + f2); missing neighbors are masked out."""

    def __init__(self, graph: CSRGraph, fanouts: list[int], batch_nodes: int, seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.batch_nodes = batch_nodes
        self.rng = np.random.default_rng(seed)
        self.n_nodes = graph.indptr.shape[0] - 1

    def sample(self) -> GraphBatch:
        import jax.numpy as jnp

        g, rng = self.g, self.rng
        seeds = rng.integers(0, self.n_nodes, self.batch_nodes)
        layer = seeds
        all_src, all_dst, all_mask = [], [], []
        nodes = [seeds]
        for f in self.fanouts:
            deg = g.indptr[layer + 1] - g.indptr[layer]
            # sample f neighbors per node (with replacement; mask deg==0)
            offs = rng.integers(0, 2**31, size=(layer.shape[0], f)) % np.maximum(deg, 1)[:, None]
            nbrs = g.indices[g.indptr[layer][:, None] + offs]
            mask = (deg > 0)[:, None] & np.ones((1, f), bool)
            all_src.append(nbrs.reshape(-1))
            all_dst.append(np.repeat(layer, f))
            all_mask.append(mask.reshape(-1))
            layer = nbrs.reshape(-1)
            nodes.append(layer)
        # relabel nodes to a compact padded id space
        flat = np.concatenate(nodes)
        uniq, inv = np.unique(flat, return_inverse=True)
        remap = {}
        n_sub = uniq.shape[0]
        src = np.searchsorted(uniq, np.concatenate(all_src))
        dst = np.searchsorted(uniq, np.concatenate(all_dst))
        mask = np.concatenate(all_mask)
        feat = g.feat[uniq] if g.feat is not None else None
        labels = g.labels[uniq] if g.labels is not None else None
        return GraphBatch(
            pos=jnp.asarray(rng.normal(size=(n_sub, 3)), jnp.float32),
            z=jnp.asarray(uniq % 10, jnp.int32),
            node_feat=jnp.asarray(feat) if feat is not None else None,
            edge_src=jnp.asarray(src.astype(np.int32)),
            edge_dst=jnp.asarray(dst.astype(np.int32)),
            node_mask=jnp.ones(n_sub, jnp.float32),
            edge_mask=jnp.asarray(mask.astype(np.float32)),
            labels=jnp.asarray(labels) if labels is not None else None,
        )
