"""The paper's own workload as a dry-run architecture: relationship queries on
PubMed-M-scale data (Table 1: DT 901M rows, DA 61M rows, 23.3M docs, 27.9k MeSH
terms, 6.3M authors) executed by the distributed frontier engine on the
production mesh — edges sharded over (data, model), one psum per hop.

Cells carry full-scale ShapeDtypeStruct edge/attr trees; the chain plan is
built from a tiny same-schema instance (plans depend on the schema + domain
sizes, not on edge values)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import executor as X
from ..core.engine import GQFastDatabase
from ..core.planner import plan_query
from ..core.sql import parse
from ..data import synth_graph as SG
from .base import ArchConfig, Cell

# PubMed-M full-scale statistics (paper Table 1)
FULL = dict(
    n_docs=23_326_299,
    n_terms=27_883,
    n_authors=6_301_521,
    dt_edges=901_388_401,
    da_edges=61_329_130,
)

EDGE_AXES = ("data", "model")


def _pad(n: int, shards: int) -> int:
    return -(-n // shards) * shards


GQFAST_SHAPES = {
    "as_b1": dict(query="AS", batch=0),
    "as_b8": dict(query="AS", batch=8),
    "ad_b8": dict(query="AD", batch=8),
    "fad_b8": dict(query="FAD", batch=8),
}

_QUERIES = {"AS": SG.QUERY_AS, "AD": SG.QUERY_AD, "FAD": SG.QUERY_FAD}


class GQFastArch(ArchConfig):
    kind = "gqfast"
    shape_ids = list(GQFAST_SHAPES)

    def __init__(self):
        self.arch_id = "gqfast-pubmed"
        self._tiny = None

    def _tiny_db(self) -> GQFastDatabase:
        if self._tiny is None:
            # tiny edge sets, FULL entity domain sizes (plans bake domain sizes)
            schema = SG.make_pubmed(
                n_docs=FULL["n_docs"], n_terms=FULL["n_terms"],
                n_authors=FULL["n_authors"],
                avg_terms_per_doc=3e-4, avg_authors_per_doc=1e-4, seed=0,
            )
            self._tiny = GQFastDatabase(schema, account_space=False)
        return self._tiny

    def make_cell(self, shape_id: str, mesh, variant: str = "") -> Cell:
        sh = GQFAST_SHAPES[shape_id]
        db = self._tiny_db()
        plan = plan_query(db.schema, parse(_QUERIES[sh["query"]]))
        batched = sh["batch"] > 0
        axes = ("data",) if variant == "data_only" else EDGE_AXES
        fdt = jnp.bfloat16 if variant == "bf16_frontier" else jnp.float32
        call = X.compile_frontier_distributed(
            db.device, plan, mesh, axes, batched=batched, frontier_dtype=fdt
        )
        jitted, edge_tree, side_tree, edge_specs, side_specs = call.lowerable
        nshards = int(np.prod([mesh.shape[a] for a in axes]))

        # full-scale abstract trees with the same structure
        def edge_abs(key: str, leafname: str, leaf):
            table = key.split("::")[0]
            E = _pad(FULL["dt_edges" if table == "DT" else "da_edges"], nshards)
            return jax.ShapeDtypeStruct((E,), leaf.dtype)

        edges_abs = {
            k: {n: edge_abs(k, n, v) for n, v in sub.items()}
            for k, sub in edge_tree.items()
        }
        side_abs = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), side_tree
        )
        names = X.collect_params(plan)
        if batched:
            p_abs = tuple(jax.ShapeDtypeStruct((sh["batch"],), jnp.int32) for _ in names)
        else:
            p_abs = tuple(jax.ShapeDtypeStruct((), jnp.int32) for _ in names)

        edge_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), edge_specs,
                               is_leaf=lambda x: isinstance(x, P))
        side_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), side_specs,
                               is_leaf=lambda x: isinstance(x, P))
        p_sh = tuple(NamedSharding(mesh, P()) for _ in names)

        def fn(edges, side, *params):
            return jitted.__wrapped__(edges, side, *params) if hasattr(jitted, "__wrapped__") else jitted(edges, side, *params)

        # total work ≈ 2 flops/edge/hop over touched edge space; report the
        # dense-equivalent convention: 6·(edges)·(batch or 1)
        b = max(sh["batch"], 1)
        mf = 2.0 * (FULL["dt_edges"] * 2 + FULL["da_edges"] * 2) * b
        return Cell(self.arch_id, shape_id, fn, (edges_abs, side_abs) + p_abs,
                    (edge_sh, side_sh) + p_sh, None, "serve", mf,
                    notes=f"query={sh['query']} frontier-SpMV chain")

    def smoke(self) -> dict:
        schema = SG.make_pubmed(n_docs=500, n_terms=50, n_authors=200)
        db = GQFastDatabase(schema, account_space=False)
        from ..core.engine import GQFastEngine
        from ..core.reference import run_sql

        eng = GQFastEngine(db)
        got = eng.query(SG.QUERY_AS, a0=7)
        ref = run_sql(schema, SG.QUERY_AS, {"a0": 7})
        return {
            "match": bool(np.allclose(got, ref, rtol=1e-4, atol=1e-4)),
            "nnz": int((got != 0).sum()),
            "finite": bool(np.isfinite(got).all()),
        }


GQFAST = GQFastArch()
