"""RQNA normalizer + physical planner (paper §3 "RQNA Normalizer", §6.1, Appendix 9.2).

Transforms the SQL AST into the left-deep normalized chain plan:
seed (σ on a key constant, or an intersection mask) → alternating relationship
hops / entity factor steps → single-key γ. Also the *verifier*: raises
``NotRelationshipQuery`` when the input falls outside the class (paper: the
normalizer "verifies whether an input SQL query is a relationship query").
"""
from __future__ import annotations

from dataclasses import dataclass

from .algebra import (
    BinOp,
    ChainPlan,
    Const,
    ConstCond,
    EntityStep,
    Expr,
    JoinCond,
    Param,
    Query,
    Ref,
    RelHop,
    SeedIds,
    SeedMask,
    SelectItem,
    Subquery,
    expr_refs,
    multiplicative_factors,
)
from ..robust.errors import PlanError
from .schema import Schema


class NotRelationshipQuery(PlanError):
    """The input falls outside the relationship-query class (or references
    unknown tables/columns/variables). A :class:`repro.robust.errors.PlanError`
    — and therefore still the ``ValueError`` it has always been."""


@dataclass
class _VarInfo:
    var: str
    table: str  # canonical schema name
    is_rel: bool


def _resolve_table(schema: Schema, name: str) -> str:
    for t in list(schema.entities) + list(schema.relationships):
        if t.lower() == name.lower():
            return t
    raise NotRelationshipQuery(
        f"unknown table {name}", table=name,
        known=sorted(list(schema.entities) + list(schema.relationships)),
    )


def plan_query(schema: Schema, q: Query) -> ChainPlan:
    vars: dict[str, _VarInfo] = {}
    for t in q.tables:
        tname = _resolve_table(schema, t.table)
        if t.var in vars:
            raise NotRelationshipQuery(f"duplicate variable {t.var}")
        vars[t.var] = _VarInfo(t.var, tname, schema.is_relationship(tname))

    def key_entity(ref: Ref) -> str:
        info = vars.get(ref.var)
        if info is None:
            raise NotRelationshipQuery(
                f"unknown variable {ref.var} (in {ref.var}.{ref.attr})",
                var=ref.var, attr=ref.attr, known=sorted(vars),
            )
        try:
            return schema.entity_of(info.table, ref.attr)
        except KeyError:
            raise NotRelationshipQuery(
                f"{ref.var}.{ref.attr} is not a key attribute of {info.table}",
                var=ref.var, attr=ref.attr, table=info.table,
            )

    # ---- classify constant conditions --------------------------------------
    seed_eq: list[ConstCond] = []  # key = const/param
    in_conds: list[ConstCond] = []
    attr_conds: list[ConstCond] = []  # entity attribute predicates
    for c in q.const_conds:
        info = vars.get(c.ref.var)
        if info is None:
            raise NotRelationshipQuery(
                f"unknown variable {c.ref.var} in WHERE predicate",
                var=c.ref.var, known=sorted(vars),
            )
        is_key = _is_key_attr(schema, info, c.ref.attr)
        if c.op == "in" and is_key:
            in_conds.append(c)
        elif c.op == "=" and is_key:
            seed_eq.append(c)
        elif not info.is_rel:
            attr_conds.append(c)
        else:
            raise NotRelationshipQuery(f"unsupported predicate {c}")

    # ---- find seed ----------------------------------------------------------
    steps: list[RelHop | EntityStep] = []
    bound: set[str] = set()
    domain: str  # current entity domain of the chain
    seed: SeedIds | SeedMask
    seed_var: str | None = None

    if seed_eq:
        c0 = seed_eq[0]
        ids = c0.value if len(seed_eq) == 1 else [cc.value for cc in seed_eq]
        if len(seed_eq) > 1 and any(cc.ref != c0.ref for cc in seed_eq):
            raise NotRelationshipQuery("multiple seeds on different attributes")
        ent = key_entity(c0.ref)
        seed = SeedIds(ent, ids, c0.ref.var)
        # only an entity-table seed exports per-seed scalar attributes (d1.Year);
        # a relationship-var seed's measures are per-edge, never scalars
        seed_var = c0.ref.var if not vars[c0.ref.var].is_rel else None
        domain = ent
        info = vars[c0.ref.var]
        if info.is_rel:
            # σ on a relationship FK: the seeded var itself is the first hop
            rel = schema.relationships[info.table]
            steps.append(
                RelHop(info.table, c0.ref.attr, rel.other_fk(c0.ref.attr), ent,
                       schema.entity_of(info.table, rel.other_fk(c0.ref.attr)),
                       c0.ref.var)
            )
            domain = steps[-1].dst_entity
        bound.add(c0.ref.var)
    elif in_conds:
        c0 = in_conds[0]
        ent = key_entity(c0.ref)
        chains, econds = _plan_subquery(schema, c0.value, ent)
        seed = SeedMask(ent, chains, econds)
        domain = ent
        info = vars[c0.ref.var]
        if not info.is_rel:
            raise NotRelationshipQuery("IN on entity variables not supported")
        rel = schema.relationships[info.table]
        steps.append(
            RelHop(info.table, c0.ref.attr, rel.other_fk(c0.ref.attr), ent,
                   schema.entity_of(info.table, rel.other_fk(c0.ref.attr)),
                   c0.ref.var, semijoin=True)
        )
        domain = steps[-1].dst_entity
        bound.add(c0.ref.var)
        in_conds = in_conds[1:]
    elif attr_conds and len(vars) == 1 and not q.join_conds:
        # pure entity predicate subquery, e.g. SELECT d.ID FROM Document d WHERE ...
        v = next(iter(vars.values()))
        if v.is_rel:
            raise NotRelationshipQuery("predicate on relationship measure")
        seed = SeedMask(v.table, [], attr_conds)
        domain = v.table
        bound.add(v.var)
        attr_conds = []
        seed_var = v.var
    else:
        raise NotRelationshipQuery("no seed selection found")

    if in_conds:
        raise NotRelationshipQuery("only one IN context supported per block")

    # ---- walk join conditions left-deep (fixpoint over SQL order) ----------
    remaining = list(q.join_conds)
    while remaining:
        progressed = False
        for jc in list(remaining):
            lb, rb = jc.left.var in bound, jc.right.var in bound
            if lb and rb:
                remaining.remove(jc)  # redundant/cycle edge: already navigated
                progressed = True
                continue
            if not (lb or rb):
                continue
            old, new = (jc.left, jc.right) if lb else (jc.right, jc.left)
            ent = key_entity(old)
            if key_entity(new) != ent:
                raise NotRelationshipQuery(f"join on mismatched domains {jc}")
            if ent != domain:
                raise NotRelationshipQuery(
                    f"non-left-deep join via {old.var}.{old.attr} (domain {domain}, need {ent})"
                )
            info = vars[new.var]
            if info.is_rel:
                rel = schema.relationships[info.table]
                dst = rel.other_fk(new.attr)
                steps.append(
                    RelHop(info.table, new.attr, dst, ent,
                           schema.entity_of(info.table, dst), new.var)
                )
                domain = steps[-1].dst_entity
            else:
                if new.attr.lower() != "id":
                    raise NotRelationshipQuery(f"entity join must be on ID: {jc}")
                steps.append(EntityStep(info.table, new.var))
            bound.add(new.var)
            remaining.remove(jc)
            progressed = True
        if not progressed:
            raise NotRelationshipQuery(f"disconnected join graph: {remaining}")

    # remaining entity-attribute predicates attach to the matching entity step
    for c in attr_conds:
        step = next(
            (s for s in steps
             if isinstance(s, EntityStep) and s.var == c.ref.var), None
        )
        if step is None:
            raise NotRelationshipQuery(f"predicate on unjoined variable {c}")
        step.conds.append(c)

    # ---- output / group ----------------------------------------------------
    group_ref = q.group_by
    plain_refs = [s.ref for s in q.select if s.ref is not None]
    aggs = [s for s in q.select if s.agg]
    if group_ref is not None:
        group_ref = _resolve_group_ref(schema, vars, group_ref, plain_refs)
        if len(aggs) != 1:
            raise NotRelationshipQuery("exactly one aggregate required with GROUP BY")
        agg_item = aggs[0]
        out_entity = key_entity(group_ref)
        _maybe_degree_filter(steps, group_ref, domain, out_entity, schema, vars)
        _attach_factors(schema, vars, steps, seed_var, agg_item)
        return ChainPlan(seed, steps, out_entity, group_ref, agg_item.agg)
    # mask-producing plan (subquery or non-aggregating top level)
    if len(plain_refs) != 1 or aggs:
        raise NotRelationshipQuery("subquery must project exactly one key column")
    out = plain_refs[0]
    out_entity = key_entity(out)
    _maybe_degree_filter(steps, out, domain, out_entity, schema, vars)
    return ChainPlan(seed, steps, None, None, None, output_ref=out)


def _is_key_attr(schema: Schema, info: _VarInfo, attr: str) -> bool:
    try:
        schema.entity_of(info.table, attr)
        return True
    except KeyError:
        return False


def _resolve_group_ref(schema, vars, group_ref: Ref, plain_refs: list[Ref]) -> Ref:
    """Handle the paper's loose GROUP BY forms: unqualified attr (CS: GROUP BY CID)
    and ``var.ID`` on a relationship variable (AS: GROUP BY da2.ID)."""
    if group_ref.var == "":
        cands = [r for r in plain_refs if r.attr.lower() == group_ref.attr.lower()]
        if len(cands) != 1:
            cands = [
                Ref(v.var, group_ref.attr) for v in vars.values()
                if _is_key_attr(schema, v, group_ref.attr)
            ]
        if len(cands) != 1:
            raise NotRelationshipQuery(
                f"ambiguous GROUP BY {group_ref.attr}", attr=group_ref.attr
            )
        return cands[0]
    info = vars.get(group_ref.var)
    if info is None:
        raise NotRelationshipQuery(
            f"GROUP BY references unknown variable {group_ref.var}",
            var=group_ref.var, known=sorted(vars),
        )
    if info.is_rel and not _is_key_attr(schema, info, group_ref.attr):
        cands = [r for r in plain_refs if r.var == group_ref.var]
        if len(cands) != 1:
            raise NotRelationshipQuery(f"cannot resolve GROUP BY {group_ref}")
        return cands[0]
    return group_ref


def _maybe_degree_filter(steps, out_ref: Ref, domain: str, out_entity: str,
                         schema, vars) -> None:
    """If the projected/group key is the *source* side of the variable's hop
    (e.g. ``SELECT da.Doc FROM DA da JOIN DT dt ON da.Doc = dt.Doc``), the hop
    is an existence filter: mask ∧ degree>0 (paper's semijoin-as-join)."""
    if not steps:
        return
    last = steps[-1]
    if (
        isinstance(last, RelHop)
        and last.var == out_ref.var
        and out_ref.attr == last.src_key
        and out_entity == last.src_entity
    ):
        last.degree_filter = True


def _attach_factors(schema, vars, steps, seed_var, agg_item: SelectItem) -> None:
    # COUNT(*) / EXISTS(*) carry no score expression: every path weighs 1̄
    if agg_item.agg in ("count", "exists") or agg_item.expr is None:
        return
    factors = multiplicative_factors(agg_item.expr)
    for f, inverted in factors:
        expr: Expr = BinOp("/", Const(1.0), f) if inverted else f
        fvars = {r.var for r in expr_refs(f)}
        non_seed = fvars - ({seed_var} if seed_var else set())
        if not fvars or not non_seed:
            # constant (or seed-only) factor: fold into the first hop
            target = next(s for s in steps if isinstance(s, RelHop))
            target.measure_expr = _mul(target.measure_expr, expr)
            continue
        if len(non_seed) != 1:
            raise NotRelationshipQuery(
                f"score factor mixes variables {non_seed}: not multiplicative per hop"
            )
        v = next(iter(non_seed))
        target = next((s for s in steps if s.var == v), None)
        if target is None:
            raise NotRelationshipQuery(f"score references unjoined variable {v}")
        if isinstance(target, RelHop):
            target.measure_expr = _mul(target.measure_expr, expr)
        else:
            target.factor_expr = _mul(target.factor_expr, expr)


def _mul(a: Expr | None, b: Expr) -> Expr:
    return b if a is None else BinOp("*", a, b)


def _plan_subquery(schema: Schema, sub: Subquery, expect_entity: str):
    chains: list[ChainPlan] = []
    econds: list[ConstCond] = []
    for qq in [sub.query] + sub.intersect:
        p = plan_query(schema, qq)
        if p.group_entity is not None:
            raise NotRelationshipQuery("aggregating subquery in IN context")
        ent = p.seed.entity if not p.steps else _chain_out_entity(p)
        if ent != expect_entity:
            raise NotRelationshipQuery(
                f"IN subquery domain {ent} != {expect_entity}"
            )
        if isinstance(p.seed, SeedMask) and not p.steps and not p.seed.chains:
            econds.extend(p.seed.entity_conds)  # pure predicate child
        else:
            chains.append(p)
    return chains, econds


def _chain_out_entity(p: ChainPlan) -> str:
    last_rel = [s for s in p.steps if isinstance(s, RelHop)]
    if not last_rel:
        return p.seed.entity
    h = last_rel[-1]
    return h.src_entity if h.degree_filter else h.dst_entity
