"""Column integrity: CRC32C digests over the device column store.

The engine's entire value proposition rests on carefully encoded device
columns (§5 dense IDs, BCA/dictionary-packed words); a flipped bit in one
packed word silently poisons every query that streams it. This module gives
every device-resident column a verifiable identity:

  * :func:`crc32c` — CRC-32C (Castagnoli), the storage-industry checksum
    (iSCSI, ext4, Kudu/Parquet pages). Hardware-accelerated via
    ``google_crc32c`` when importable; otherwise a table-driven pure-Python
    fallback (identical values, slower — fine for test-sized columns).
  * :func:`column_digest` — per-column digest of both physical layers:
    ``encoded_crc`` over the stored device arrays exactly as HBM holds them
    (packed words / dense array / dictionary), and ``decoded_crc`` over the
    decoded view ``materialize()`` serves to the engine.
  * :func:`build_manifest` / :func:`attach_manifest` — the host-side
    manifest mapping ``I_<table>.<key>/<column>`` → digest, and its
    attachment to a live DB: once attached, ``materialize()`` verifies every
    concrete decode against ``decoded_crc`` (storage/columns.py) and the
    scrubber (robust/scrub.py) re-hashes encoded bytes against
    ``encoded_crc`` a few columns per tick.

Digest addresses are strings (JSON-manifest friendly): ``I_DT.doc/__dst__``
for the hop's destination column, ``I_DT.doc/<measure>`` for measures.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from .columns import DenseColumn, DeviceColumn, DictPackedColumn, PackedColumn

try:  # hardware CRC32C when the wheel is present
    import google_crc32c as _gcrc
except ImportError:  # pragma: no cover - environment-dependent
    _gcrc = None

#: CRC-32C (Castagnoli) reflected polynomial.
_POLY = 0x82F63B78

_TABLE: list[int] | None = None


def _table() -> list[int]:
    global _TABLE
    if _TABLE is None:
        t = []
        for i in range(256):
            c = i
            for _ in range(8):
                c = (c >> 1) ^ _POLY if c & 1 else c >> 1
            t.append(c)
        _TABLE = t
    return _TABLE


def _as_bytes(data: Any) -> bytes:
    if isinstance(data, (bytes, bytearray, memoryview)):
        return bytes(data)
    return np.ascontiguousarray(np.asarray(data)).tobytes()


def crc32c(data: Any, value: int = 0) -> int:
    """CRC-32C of ``data`` (bytes or array), continuing from ``value`` so
    multi-part digests (packed words + dictionary) chain one checksum."""
    buf = _as_bytes(data)
    if _gcrc is not None:
        return int(_gcrc.extend(value, buf))
    crc = value ^ 0xFFFFFFFF
    tab = _table()
    for b in buf:
        crc = tab[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


def crc32c_parts(parts: Iterable[Any]) -> int:
    """One chained CRC over an ordered sequence of buffers/arrays."""
    crc = 0
    for p in parts:
        crc = crc32c(p, crc)
    return crc


# ---------------------------------------------------------------------------
# Column digests
# ---------------------------------------------------------------------------


def encoded_parts(col: DeviceColumn) -> list[np.ndarray]:
    """The stored device arrays of ``col`` in digest order — exactly what HBM
    holds, no decode. The scrubber re-reads these."""
    if isinstance(col, DenseColumn):
        return [np.asarray(col.array)]
    if isinstance(col, DictPackedColumn):
        return [np.asarray(col.words), np.asarray(col.dictionary)]
    if isinstance(col, PackedColumn):
        return [np.asarray(col.words)]
    raise TypeError(f"not a device column: {type(col).__name__}")


def decode_fresh(col: DeviceColumn) -> np.ndarray:
    """The decoded view of ``col`` computed directly from the encoded arrays —
    byte-identical to ``materialize()`` output but bypassing the memo and the
    ``storage.materialize`` fault site, so it is usable as the trusted
    baseline while a corrupt-mode fault plan is live."""
    import jax.numpy as jnp

    from ..kernels import ops as K

    if isinstance(col, DenseColumn):
        return np.asarray(col.array)
    if isinstance(col, DictPackedColumn):
        return np.asarray(
            jnp.take(col.dictionary, K.bitunpack(col.words, col.width, col.count))
        )
    if isinstance(col, PackedColumn):
        return np.asarray(
            K.bitunpack(col.words, col.width, col.count).astype(col.out_dtype)
        )
    raise TypeError(f"not a device column: {type(col).__name__}")


def column_digest(col: DeviceColumn) -> dict[str, Any]:
    """Both-layer digest of one column: the encoded bytes as stored and the
    decoded view as served."""
    return {
        "kind": col.kind,
        "count": int(col.count),
        "encoded_crc": crc32c_parts(encoded_parts(col)),
        "decoded_crc": crc32c(decode_fresh(col)),
    }


def iter_columns(device_db) -> list[tuple[str, tuple[str, str], str, DeviceColumn]]:
    """Every device column as ``(addr, (table, key), column_name, col)``;
    ``addr`` is the manifest key ``I_<t>.<k>/<col>``."""
    out = []
    for (t, k), di in device_db.indexes.items():
        for name, col in [("__dst__", di.dst_col), *di.measure_cols.items()]:
            out.append((f"I_{t}.{k}/{name}", (t, k), name, col))
    return out


def build_manifest(device_db) -> dict[str, dict[str, Any]]:
    """Digest every device column of a (trusted, freshly built or freshly
    verified) DB. This is the host-side source of truth the verified-read
    path and the scrubber check against."""
    return {addr: column_digest(col) for addr, _, _, col in iter_columns(device_db)}


def attach_manifest(device_db, manifest: dict[str, dict[str, Any]] | None = None,
                    verify_reads: bool = True) -> dict[str, dict[str, Any]]:
    """Install ``manifest`` (built fresh when None) on ``device_db`` and on
    each column. With ``verify_reads`` every subsequent concrete
    ``materialize()`` of a packed/dict/dense column checks its decoded bytes
    against the digest (storage/columns.py) — corruption is detected at the
    read that would otherwise poison a trace, healed from the memo when
    transient, raised as :class:`repro.robust.errors.IntegrityError` when
    persistent."""
    if manifest is None:
        manifest = build_manifest(device_db)
    device_db.integrity = manifest
    for addr, (t, k), name, col in iter_columns(device_db):
        dig = manifest.get(addr)
        if dig is None:
            continue
        col._addr = (t, k, name)
        col._expected_crc = int(dig["decoded_crc"]) if verify_reads else None
    return manifest


def detach_manifest(device_db) -> None:
    """Remove integrity state — columns return to zero-overhead reads."""
    if getattr(device_db, "integrity", None) is not None:
        device_db.integrity = None
    for _, _, _, col in iter_columns(device_db):
        col._expected_crc = None
        col._addr = None
        col._quarantined = False
