"""End-to-end behaviour tests: the paper's six benchmark queries on synthetic
Zipf data — GQ-Fast frontier engine vs the materializing numpy oracle vs
hand-computed brute force."""
import numpy as np
import pytest

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.core.reference import NumpyQueryEngine, run_sql
from repro.core.planner import plan_query
from repro.core.sql import parse
from repro.data import synth_graph as SG


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=2000, n_terms=100, n_authors=500, seed=3)


@pytest.fixture(scope="module")
def pubmed_db(pubmed):
    return GQFastDatabase(pubmed, account_space=False)


@pytest.fixture(scope="module")
def engine(pubmed_db):
    return GQFastEngine(pubmed_db)


CASES = [
    ("SD", SG.QUERY_SD, {"d0": 5}),
    ("FSD", SG.QUERY_FSD, {"d0": 5}),
    ("AS", SG.QUERY_AS, {"a0": 7}),
    ("AD", SG.QUERY_AD, {"t1": 3, "t2": 9}),
    ("FAD", SG.QUERY_FAD, {"t1": 3, "t2": 9}),
    ("RECENT", SG.QUERY_RECENT_AUTHORS, {"t1": 3, "t2": 9, "y": 2005}),
]


@pytest.mark.parametrize("name,q,params", CASES, ids=[c[0] for c in CASES])
def test_query_matches_reference(engine, pubmed, name, q, params):
    got = engine.query(q, **params)
    ref = run_sql(pubmed, q, params)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert (got != 0).sum() > 0, "degenerate test: empty result"


def test_cs_query_semmeddb():
    sem = SG.make_semmeddb(400, 500, 800, 3000)
    db = GQFastDatabase(sem, account_space=False)
    eng = GQFastEngine(db)
    got = eng.query(SG.QUERY_CS, c0=11)
    ref = run_sql(sem, SG.QUERY_CS, {"c0": 11})
    np.testing.assert_allclose(got, ref, rtol=1e-4)
    assert (got != 0).sum() > 0


def test_sd_brute_force(engine, pubmed):
    dt = pubmed.relationships["DT"]
    doc, term = dt.columns["Doc"], dt.columns["Term"]
    d0 = 5
    terms0 = set(term[doc == d0].tolist())
    expect = np.zeros(pubmed.entities["Document"].size)
    for d, t in zip(doc.tolist(), term.tolist()):
        if t in terms0:
            expect[d] += 1
    np.testing.assert_allclose(engine.query(SG.QUERY_SD, d0=d0), expect)


def test_fragment_loop_strategy(pubmed_db, engine):
    floop = GQFastEngine(pubmed_db, strategy="fragment_loop")
    for name, q, params in CASES[:3]:
        np.testing.assert_allclose(
            floop.query(q, **params), engine.query(q, **params), rtol=1e-4, atol=1e-4
        )


def test_lookup_strategies_agree(pubmed):
    plan = plan_query(pubmed, parse(SG.QUERY_AS))
    outs = []
    for lookup in ("index", "binary", "scan"):
        eng = NumpyQueryEngine(pubmed, lookup=lookup)
        outs.append(eng.execute_plan(plan, {"a0": 7}))
    np.testing.assert_allclose(outs[0], outs[1])
    np.testing.assert_allclose(outs[0], outs[2])


def test_agg_strategies_agree(pubmed):
    plan = plan_query(pubmed, parse(SG.QUERY_SD))
    a = NumpyQueryEngine(pubmed, agg="dense").execute_plan(plan, {"d0": 5})
    b = NumpyQueryEngine(pubmed, agg="hash").execute_plan(plan, {"d0": 5})
    np.testing.assert_allclose(a, b)


def test_batched_serving(engine):
    pq = engine.prepare(SG.QUERY_AS)
    batch = pq.execute_batch(a0=np.arange(4))
    for i in range(4):
        np.testing.assert_allclose(
            batch[i], engine.query(SG.QUERY_AS, a0=i), rtol=1e-4, atol=1e-4
        )


def test_prepare_once_execute_many(engine):
    pq = engine.prepare(SG.QUERY_SD)
    r1, r2 = pq(d0=5), pq(d0=6)
    assert not np.allclose(r1, r2), "parameter change must change the result"


def test_space_report(pubmed):
    db = GQFastDatabase(pubmed, account_space=True)
    rep = db.space_report()
    assert rep["total_bytes"] > 0
    assert "I_DT.Doc" in rep["indexes"] and "I_DT.Term" in rep["indexes"]
    for idx in rep["indexes"].values():
        for col in idx["columns"].values():
            assert col["encoding"] in ("UA", "BCA", "BB", "UB", "Huffman", "DictBCA")


def test_auto_strategy_picks_by_touched_fraction(pubmed_db, engine):
    """Beyond-paper adaptive execution: sparse-seed queries use the paper's
    work-efficient fragment walk; dense traversals use the vectorized frontier
    (crossover measured in benchmarks/perf_baseline)."""
    from repro.data import synth_graph as SG

    auto = GQFastEngine(pubmed_db, strategy="auto")
    sd = auto._pick_strategy(auto.prepare(SG.QUERY_SD).plan)
    as_ = auto._pick_strategy(auto.prepare(SG.QUERY_AS).plan)
    assert as_ == "frontier"
    # results match the default engine either way
    np.testing.assert_allclose(
        auto.query(SG.QUERY_SD, d0=5), engine.query(SG.QUERY_SD, d0=5),
        rtol=5e-3, atol=1e-2,
    )
