"""Durability layer: checksummed snapshots, integrity scrubbing, hot swap.

Covers DESIGN.md §Durability end to end:

  * snapshot round trips — every device encoding × both strategies produce
    bit-identical query results after restore, without re-encoding;
  * detection — ANY single flipped byte in ANY snapshot array file makes
    restore raise IntegrityError (naming the offending table/column), never
    return data;
  * verified reads — a corrupted materialize is healed from the memo when
    transient, raised as IntegrityError when persistent;
  * scrubbing — at-rest corruption is detected, quarantined, healed from
    snapshot, and queries are bit-identical afterwards;
  * hot swap — load_generation warms a new generation; a corrupted
    generation rolls back without touching serving state;
  * the shared atomic writer and the thread-safety hardening under it all.
"""
from __future__ import annotations

import glob
import json
import os
import shutil
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data import synth_graph as SG
from repro.obs.metrics import MetricsRegistry
from repro.robust import IntegrityError, QueryError, Scrubber, faults
from repro.robust.faults import FaultPlan, FaultSpec
from repro.storage import (
    attach_manifest,
    build_manifest,
    crc32c,
    detach_manifest,
    latest_generation,
    list_generations,
    restore_db,
    snapshot_db,
)
from repro.storage.snapshot import load_column_arrays

SQL = ("SELECT d2.Term, COUNT(*) FROM DT d1 JOIN DT d2 ON d1.Doc = d2.Doc "
       "WHERE d1.Term = :t GROUP BY d2.Term")
SQL_SUM = ("SELECT dt.Doc, SUM(dt.Fre) FROM DT dt WHERE dt.Term = :t "
           "GROUP BY dt.Doc")


@pytest.fixture(scope="module")
def schema():
    return SG.make_pubmed(n_docs=250, n_terms=40, n_authors=80, seed=11)


def _db(schema, enc):
    return GQFastDatabase(schema, device_encodings=enc, account_space=False)


# ---------------------------------------------------------------------------
# CRC32C
# ---------------------------------------------------------------------------


def test_crc32c_vector():
    # the RFC 3720 check value every CRC-32C implementation must produce
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0


def test_crc32c_chaining():
    whole = crc32c(b"hello world")
    assert crc32c(b" world", crc32c(b"hello")) == whole


def test_crc32c_pure_python_fallback_matches():
    from repro.storage import integrity as I

    data = np.random.default_rng(0).integers(0, 2**32, 4096, np.uint32)
    got = I.crc32c(data)
    # force the table fallback and compare
    gcrc, I._gcrc = I._gcrc, None
    try:
        assert I.crc32c(data) == got
    finally:
        I._gcrc = gcrc


# ---------------------------------------------------------------------------
# Snapshot round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("enc", ["dense", "packed", "auto"])
@pytest.mark.parametrize("strategy", ["frontier", "fragment_loop"])
def test_roundtrip_bit_identical(schema, enc, strategy, tmp_path):
    db = _db(schema, enc)
    eng = GQFastEngine(db, strategy=strategy)
    refs = [np.asarray(eng.prepare(sql)(t=7)) for sql in (SQL, SQL_SUM)]

    snapshot_db(db, str(tmp_path))
    db2 = restore_db(str(tmp_path))
    eng2 = GQFastEngine(db2, strategy=strategy)
    for sql, ref in zip((SQL, SQL_SUM), refs):
        got = np.asarray(eng2.prepare(sql)(t=7))
        assert got.dtype == ref.dtype
        assert np.array_equal(got, ref), f"{enc}/{strategy}: not bit-identical"


@pytest.mark.parametrize("enc", ["dense", "packed", "auto"])
def test_roundtrip_preserves_encodings(schema, enc, tmp_path):
    """Restore rebuilds columns from stored encoded bytes — same kinds, same
    words, no re-encode."""
    db = _db(schema, enc)
    snapshot_db(db, str(tmp_path))
    db2 = restore_db(str(tmp_path))
    for (t, k), di in db.device.indexes.items():
        di2 = db2.device.indexes[(t, k)]
        cols = [("__dst__", di.dst_col, di2.dst_col)] + [
            (m, c, di2.measure_cols[m]) for m, c in di.measure_cols.items()
        ]
        for name, a, b in cols:
            assert a.kind == b.kind, (t, k, name)
            if a.kind in ("packed", "dict"):
                assert np.array_equal(np.asarray(a.words), np.asarray(b.words))
                assert a.width == b.width and a.count == b.count
            if a.kind == "dict":
                assert np.array_equal(
                    np.asarray(a.dictionary), np.asarray(b.dictionary)
                )


def test_roundtrip_host_indexes_and_schema(schema, tmp_path):
    db = _db(schema, "auto")
    snapshot_db(db, str(tmp_path))
    db2 = restore_db(str(tmp_path))
    assert set(db2.host_indexes) == set(db.host_indexes)
    for key, idx in db.host_indexes.items():
        idx2 = db2.host_indexes[key]
        assert np.array_equal(idx.indptr, idx2.indptr)
        assert set(idx.columns) == set(idx2.columns)
        for c, cf in idx.columns.items():
            cf2 = idx2.columns[c]
            assert np.array_equal(cf.values, cf2.values)
            assert cf.encoding == cf2.encoding
            assert cf.encoded_bytes == cf2.encoded_bytes
    for e in schema.entities.values():
        e2 = db2.schema.entities[e.name]
        assert e2.size == e.size
        for a, col in e.attributes.items():
            assert np.array_equal(col, e2.attributes[a])
    db2.schema.validate()


def test_restored_db_has_manifest_and_verified_reads(schema, tmp_path):
    db = _db(schema, "packed")
    snapshot_db(db, str(tmp_path))
    db2 = restore_db(str(tmp_path))
    assert db2.device.integrity  # manifest attached…
    col = db2.device.indexes[("DT", "Doc")].dst_col
    assert col._expected_crc is not None  # …and reads are verified


def test_generations_and_retention(schema, tmp_path):
    db = _db(schema, "dense")
    for _ in range(3):
        snapshot_db(db, str(tmp_path))
    assert list_generations(str(tmp_path)) == [1, 2, 3]
    snapshot_db(db, str(tmp_path), keep=2)
    assert list_generations(str(tmp_path)) == [3, 4]
    assert latest_generation(str(tmp_path)) == 4
    # restore a specific, non-latest generation
    db2 = restore_db(str(tmp_path), generation=3)
    assert np.array_equal(
        np.asarray(db.device.indexes[("DT", "Doc")].indptr),
        np.asarray(db2.device.indexes[("DT", "Doc")].indptr),
    )


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_db(str(tmp_path))


# ---------------------------------------------------------------------------
# Corruption detection: every flipped byte raises IntegrityError
# ---------------------------------------------------------------------------


def test_every_single_byte_flip_detected(schema, tmp_path):
    """Flip one byte in the middle of EVERY array file in turn: restore must
    raise IntegrityError each time, with the offending table/column named —
    a corrupted snapshot never yields a database object."""
    db = _db(schema, "auto")
    gen_path = snapshot_db(db, str(tmp_path))
    files = sorted(glob.glob(os.path.join(gen_path, "arrays", "*.npy")))
    assert len(files) > 10
    manifest = json.load(open(os.path.join(gen_path, "MANIFEST.json")))
    by_file = {spec["file"]: name for name, spec in manifest["arrays"].items()}
    for f in files:
        shutil.copy(f, f + ".bak")
        raw = bytearray(open(f, "rb").read())
        raw[len(raw) // 2] ^= 0x20
        open(f, "wb").write(bytes(raw))
        try:
            with pytest.raises(IntegrityError) as ei:
                restore_db(str(tmp_path))
            err = ei.value
            assert err.code == "INTEGRITY"
            assert not err.retryable
            logical = by_file[os.path.basename(f)]
            assert err.context.get("array") == logical
            # dev/host arrays must name their table; attrs their entity
            assert err.context.get("table"), logical
        finally:
            shutil.move(f + ".bak", f)
    restore_db(str(tmp_path))  # intact again → restores clean


def test_header_flip_detected(schema, tmp_path):
    """A flip in the .npy header (dtype/shape region, before the data bytes)
    must also surface as IntegrityError, not a numpy crash."""
    db = _db(schema, "dense")
    gen_path = snapshot_db(db, str(tmp_path))
    f = sorted(glob.glob(os.path.join(gen_path, "arrays", "*.npy")))[0]
    raw = bytearray(open(f, "rb").read())
    raw[9] ^= 0xFF  # inside the header dict
    open(f, "wb").write(bytes(raw))
    with pytest.raises(IntegrityError):
        restore_db(str(tmp_path))


def test_truncated_manifest_detected(schema, tmp_path):
    db = _db(schema, "dense")
    gen_path = snapshot_db(db, str(tmp_path))
    mpath = os.path.join(gen_path, "MANIFEST.json")
    open(mpath, "w").write(open(mpath).read()[:100])
    with pytest.raises(IntegrityError):
        restore_db(str(tmp_path))


def test_snapshot_load_fault_sites(schema, tmp_path):
    db = _db(schema, "dense")
    snapshot_db(db, str(tmp_path))
    # raise-mode: typed injected fault at restore entry
    plan = FaultPlan(seed=0, specs=[FaultSpec("snapshot.load", mode="raise",
                                              max_fires=1)])
    with faults.active(plan):
        with pytest.raises(QueryError):
            restore_db(str(tmp_path))
        restore_db(str(tmp_path))  # fires exhausted → succeeds
    # corrupt-mode: the loaded bytes are transformed pre-verification → caught
    plan = FaultPlan(seed=0, specs=[FaultSpec("snapshot.load", mode="corrupt",
                                              max_fires=1)])
    with faults.active(plan):
        with pytest.raises(IntegrityError):
            restore_db(str(tmp_path))


# ---------------------------------------------------------------------------
# Verified reads
# ---------------------------------------------------------------------------


def test_verified_read_transient_heals(schema):
    db = _db(schema, "packed")
    reg_before = None
    attach_manifest(db.device)
    col = db.device.indexes[("DT", "Doc")].dst_col
    truth = np.asarray(col.materialize())
    from repro.obs.metrics import REGISTRY

    heals0 = REGISTRY.counter("robust.integrity.read_heals").value
    plan = FaultPlan(seed=0, specs=[FaultSpec(
        "storage.materialize", mode="corrupt", max_fires=1)])
    with faults.active(plan):
        out = np.asarray(col.materialize())  # corrupted once → healed
    assert np.array_equal(out, truth)
    assert REGISTRY.counter("robust.integrity.read_heals").value == heals0 + 1
    detach_manifest(db.device)
    assert reg_before is None


def test_verified_read_persistent_raises(schema):
    db = _db(schema, "packed")
    attach_manifest(db.device)
    di = db.device.indexes[("DT", "Doc")]
    col = di.dst_col
    bad = np.asarray(col.words).copy()
    bad[0] ^= 1
    col.words = jnp.asarray(bad)
    col._dense = None
    with pytest.raises(IntegrityError) as ei:
        col.materialize()
    assert ei.value.context["table"] == "DT"
    assert ei.value.context["column"] == "__dst__"
    assert not ei.value.retryable


def test_quarantined_read_raises(schema):
    db = _db(schema, "packed")
    attach_manifest(db.device)
    col = db.device.indexes[("DT", "Doc")].dst_col
    col._quarantined = True
    with pytest.raises(IntegrityError):
        col.materialize()
    col._quarantined = False


def test_manifest_detach_restores_zero_overhead(schema):
    db = _db(schema, "packed")
    attach_manifest(db.device)
    detach_manifest(db.device)
    col = db.device.indexes[("DT", "Doc")].dst_col
    assert col._expected_crc is None and not col._quarantined


# ---------------------------------------------------------------------------
# Scrubber: detect → quarantine → heal from snapshot → bit-identical
# ---------------------------------------------------------------------------


def _corrupt_in_place(col):
    bad = np.asarray(col.words).copy()
    bad[bad.shape[0] // 2] ^= 0x01000000
    col.words = jnp.asarray(bad)
    col._dense = None


def test_scrub_detects_and_heals(schema, tmp_path):
    db = _db(schema, "packed")
    eng = GQFastEngine(db)
    ref = np.asarray(eng.prepare(SQL)(t=3))
    snapshot_db(db, str(tmp_path))
    attach_manifest(db.device)

    reg = MetricsRegistry()
    healed_addrs: list[str] = []
    s = Scrubber(db, snapshot_dir=str(tmp_path), cols_per_tick=2,
                 registry=reg, on_heal=healed_addrs.append)
    assert s.scrub_full()["failed"] == 0  # clean pass

    _corrupt_in_place(db.device.indexes[("DT", "Doc")].dst_col)
    stats = s.scrub_full()
    assert stats["healed"] == 1 and stats["failed"] == 0
    assert healed_addrs == ["I_DT.Doc/__dst__"]
    assert reg.counter("robust.integrity.scrub_detected").value == 1
    assert reg.counter("robust.integrity.scrub_repairs").value == 1

    # post-heal: executables must be re-prepared, then results bit-identical
    eng.invalidate_prepared()
    got = np.asarray(eng.prepare(SQL)(t=3))
    assert np.array_equal(got, ref)


def test_scrub_without_snapshot_quarantines(schema):
    """No snapshot to heal from: the column stays quarantined — reads raise
    typed errors instead of serving corrupted data."""
    db = _db(schema, "packed")
    attach_manifest(db.device)
    col = db.device.indexes[("DT", "Doc")].dst_col
    _corrupt_in_place(col)
    reg = MetricsRegistry()
    s = Scrubber(db, snapshot_dir=None, registry=reg)
    stats = s.scrub_full()
    assert stats["failed"] == 1
    assert reg.counter("robust.integrity.scrub_failures").value == 1
    assert col._quarantined
    with pytest.raises(IntegrityError):
        col.materialize()


def test_scrub_memo_corruption_healed_by_drop(schema):
    """A flipped decode memo needs no snapshot: drop it and re-decode."""
    db = _db(schema, "packed")
    attach_manifest(db.device)
    col = db.device.indexes[("DT", "Doc")].dst_col
    truth = np.asarray(col.materialize())
    bad = truth.copy()
    bad[0] ^= 1
    col._dense = jnp.asarray(bad)
    reg = MetricsRegistry()
    s = Scrubber(db, registry=reg)
    s.scrub_full()
    assert reg.counter("robust.integrity.memo_drops").value == 1
    assert col._dense is None
    assert np.array_equal(np.asarray(col.materialize()), truth)


def test_scrub_verify_fault_site_drives_heal(schema, tmp_path):
    """The chaos-lane recipe: a corrupt-mode scrub.verify spec that outlasts
    the scrubber's re-read retries forces a full detect→heal→re-verify cycle
    against truly-intact storage."""
    db = _db(schema, "packed")
    snapshot_db(db, str(tmp_path))
    reg = MetricsRegistry()
    s = Scrubber(db, snapshot_dir=str(tmp_path), registry=reg)
    plan = FaultPlan(seed=5, specs=[FaultSpec("scrub.verify", mode="corrupt",
                                              max_fires=3)])
    with faults.active(plan):
        stats = s.scrub_full()
    assert stats["healed"] == 1 and stats["failed"] == 0
    assert reg.counter("robust.integrity.scrub_repairs").value == 1
    assert s.scrub_full()["failed"] == 0  # clean afterwards


def test_corrupt_scrub_heal_end_to_end(schema, tmp_path):
    """The full durability story on one DB: corrupt two columns in place,
    scrub, and require bit-identical answers afterwards for both encodings'
    query paths."""
    db = _db(schema, "auto")
    eng = GQFastEngine(db)
    refs = {sql: np.asarray(eng.prepare(sql)(t=9)) for sql in (SQL, SQL_SUM)}
    snapshot_db(db, str(tmp_path))
    attach_manifest(db.device)

    di = db.device.indexes[("DT", "Doc")]
    _corrupt_in_place(di.dst_col)
    for col in di.measure_cols.values():
        if hasattr(col, "words"):
            _corrupt_in_place(col)
            break
    reg = MetricsRegistry()
    s = Scrubber(db, snapshot_dir=str(tmp_path), registry=reg)
    stats = s.scrub_full()
    assert stats["healed"] >= 2 and stats["failed"] == 0
    eng.invalidate_prepared()
    for sql, ref in refs.items():
        assert np.array_equal(np.asarray(eng.prepare(sql)(t=9)), ref)


def test_load_column_arrays_verified(schema, tmp_path):
    db = _db(schema, "packed")
    gen_path = snapshot_db(db, str(tmp_path))
    arrays, meta = load_column_arrays(str(tmp_path), 1, "DT", "Doc", "__dst__")
    assert meta["kind"] == "packed"
    assert np.array_equal(
        arrays["words"], np.asarray(db.device.indexes[("DT", "Doc")].dst_col.words)
    )
    # heal reads verify too: flip the words file → IntegrityError
    manifest = json.load(open(os.path.join(gen_path, "MANIFEST.json")))
    spec = manifest["arrays"]["dev/DT.Doc/__dst__/words"]
    f = os.path.join(gen_path, "arrays", spec["file"])
    raw = bytearray(open(f, "rb").read())
    raw[-1] ^= 0x80
    open(f, "wb").write(bytes(raw))
    with pytest.raises(IntegrityError):
        load_column_arrays(str(tmp_path), 1, "DT", "Doc", "__dst__")


# ---------------------------------------------------------------------------
# Hot swap (load_generation)
# ---------------------------------------------------------------------------


def _mini_queries():
    return {"Q": SQL}


def _sample_params(_kind):
    return {"t": 4}


def test_load_generation_warms_and_serves(schema, tmp_path):
    from repro.launch.serve import load_generation

    db = _db(schema, "packed")
    eng = GQFastEngine(db)
    ref = np.asarray(eng.prepare(SQL)(t=4))
    snapshot_db(db, str(tmp_path))
    eng2, prepared, gen = load_generation(
        str(tmp_path), _mini_queries(), _sample_params, bucket=4
    )
    assert gen == 1 and set(prepared) == {"Q"}
    assert np.array_equal(np.asarray(prepared["Q"](t=4)), ref)


def test_load_generation_corrupted_rolls_back(schema, tmp_path):
    """A bad generation raises before any serving state could change — the
    rollback contract is that the caller simply keeps its old references."""
    from repro.launch.serve import load_generation

    db = _db(schema, "packed")
    gen_path = snapshot_db(db, str(tmp_path))
    f = sorted(glob.glob(os.path.join(gen_path, "arrays", "*.npy")))[3]
    raw = bytearray(open(f, "rb").read())
    raw[len(raw) // 2] ^= 0x10
    open(f, "wb").write(bytes(raw))
    with pytest.raises(IntegrityError):
        load_generation(str(tmp_path), _mini_queries(), _sample_params, bucket=4)


# ---------------------------------------------------------------------------
# Atomic writer + retention
# ---------------------------------------------------------------------------


def test_publish_dir_atomic_on_failure(tmp_path):
    from repro.ckpt.atomic import publish_dir

    final = str(tmp_path / "out")

    def bad_write(tmp):
        open(os.path.join(tmp, "partial"), "w").write("x")
        raise RuntimeError("crash mid-write")

    with pytest.raises(RuntimeError):
        publish_dir(final, bad_write)
    assert not os.path.exists(final)  # nothing partially visible
    assert os.listdir(str(tmp_path)) == []  # tmp dir cleaned up

    publish_dir(final, lambda t: open(os.path.join(t, "ok"), "w").write("y"))
    assert os.path.exists(os.path.join(final, "ok"))


def test_retain_stamped(tmp_path):
    from repro.ckpt.atomic import retain_stamped, stamped_name

    for n in (1, 2, 5, 9):
        os.makedirs(tmp_path / stamped_name("gen_", n))
    removed = retain_stamped(str(tmp_path), "gen_", 2)
    assert removed == [1, 2]
    assert sorted(os.listdir(tmp_path)) == [
        stamped_name("gen_", 5), stamped_name("gen_", 9)
    ]


def test_checkpoint_manager_uses_shared_writer(tmp_path):
    """ckpt/manager.py rides the same atomic helper (the refactor half of
    this layer): saves are stamped, retained, and restorable."""
    from repro.ckpt.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": np.arange(6, dtype=np.float32)}
    for step in (1, 2, 3):
        mgr.save(step, tree)
    assert mgr.list_steps() == [2, 3]
    restored, meta = mgr.restore({"w": np.zeros(6, np.float32)})
    assert np.array_equal(np.asarray(restored["w"]), tree["w"])
    assert meta["step"] == 3


# ---------------------------------------------------------------------------
# Thread safety
# ---------------------------------------------------------------------------


def test_counter_concurrent_increments_exact():
    reg = MetricsRegistry()
    c = reg.counter("t.c")
    N, T = 5_000, 8

    def work():
        for _ in range(N):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(T)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert c.value == N * T  # lost updates would make this flaky-low


def test_histogram_concurrent_observe_exact_count():
    reg = MetricsRegistry()
    h = reg.histogram("t.h")
    N, T = 2_000, 8

    def work():
        for i in range(N):
            h.observe(float(i % 50))

    threads = [threading.Thread(target=work) for _ in range(T)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert h.count == N * T
    assert int(h.counts.sum()) == N * T


def test_registry_concurrent_get_or_create():
    reg = MetricsRegistry()
    out = []

    def work():
        out.append(id(reg.counter("same.name")))

    threads = [threading.Thread(target=work) for _ in range(16)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert len(set(out)) == 1  # everyone got the same object


def test_prepared_cache_concurrent_ops():
    from repro.robust import PreparedCache

    cache = PreparedCache(capacity=8, registry=MetricsRegistry())
    errs = []

    def work(tid):
        try:
            for i in range(2_000):
                cache.put((tid, i % 16), i)
                cache.get((tid, (i * 7) % 16))
                len(cache)
        except BaseException as e:  # OrderedDict corruption raises here
            errs.append(e)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(8)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert not errs
    assert len(cache) <= 8


def test_prepared_cache_clear_and_engine_invalidate(schema):
    db = _db(schema, "dense")
    eng = GQFastEngine(db)
    eng.prepare(SQL)
    assert len(eng._cache) == 1
    assert eng.invalidate_prepared() == 1
    assert len(eng._cache) == 0
    eng.prepare(SQL)  # re-prepare works after invalidation


# ---------------------------------------------------------------------------
# Taxonomy
# ---------------------------------------------------------------------------


def test_integrity_error_taxonomy():
    e = IntegrityError("bad bytes", table="DT", key="Doc", column="__dst__",
                       expected_crc=1, actual_crc=2)
    assert isinstance(e, QueryError) and isinstance(e, RuntimeError)
    assert e.code == "INTEGRITY"
    assert not e.retryable  # retrying a corrupted read cannot help
    d = e.to_dict()
    assert d["code"] == "INTEGRITY" and d["context"]["table"] == "DT"


def test_build_manifest_covers_every_column(schema):
    db = _db(schema, "auto")
    man = build_manifest(db.device)
    expect = set()
    for (t, k), di in db.device.indexes.items():
        expect.add(f"I_{t}.{k}/__dst__")
        expect.update(f"I_{t}.{k}/{m}" for m in di.measure_cols)
    assert set(man) == expect
    for dig in man.values():
        assert {"kind", "count", "encoded_crc", "decoded_crc"} <= set(dig)
