"""Three-term roofline from dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute    = HLO_FLOPs / peak_FLOPs            (per chip: the compiled module
  memory     = HLO_bytes / HBM_bw                 is already the SPMD per-device
  collective = Σ collective_bytes / link_bw       program)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
collective bytes are parsed from the compiled HLO text: operand shapes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<single>[a-z0-9_\[\],{}\s]*?))\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_result_bytes(line: str, op: str) -> int:
    """Result tensor bytes of an HLO collective line: the shape(s) sit between
    '=' and the op name (``%ag = f32[2048,1,128]{2,1,0} all-gather(...)``);
    result size ≈ payload moved per device for ag/ar/rs/a2a/cp."""
    try:
        seg = line.split("=", 1)[1]
        seg = seg[: seg.index(op)]
    except (IndexError, ValueError):
        return 0
    total = 0
    for m in _SHAPE_RE.finditer(seg):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict[str, float]:
    """Per-collective-type byte totals from compiled HLO text (per device)."""
    out: dict[str, float] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = _COLL_RE.search(s)
        if not m:
            continue
        if "-done(" in s:
            continue  # async pairs: count the -start only
        op = m.group("op")
        b = _line_result_bytes(s, op)
        out[op] = out.get(op, 0.0) + b
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.__getitem__)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def loop_trips(rec: dict) -> int:
    """XLA cost_analysis (and the HLO text) count while-loop bodies ONCE; the
    LM cells run scan-over-layers (×L) and grad-accumulation (×micro). Correct
    by the known outer trip counts (GNN/recsys/gqfast cells unroll — factor 1).
    Inner attention chunk scans still undercount prefill/decode slightly
    (documented in EXPERIMENTS.md §Roofline)."""
    try:
        from repro.configs.registry import get_arch

        arch = get_arch(rec["arch"])
        if arch.kind != "lm":
            return 1
        L = arch.full.n_layers
        if rec.get("kind") == "train":
            import re as _re

            m = _re.search(r"micro=(\d+)", rec.get("notes", ""))
            micro = int(m.group(1)) if m else 1
            return L * micro
        return L
    except Exception:
        return 1


def roofline_from_record(rec: dict, chips: int = 256) -> Roofline:
    coll = sum(rec.get("collectives", {}).values())
    trips = loop_trips(rec)
    return Roofline(
        compute_s=rec.get("flops", 0.0) * trips / PEAK_FLOPS,
        memory_s=rec.get("bytes_accessed", 0.0) * trips / HBM_BW,
        collective_s=coll * trips / ICI_BW,
    )


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    if not os.path.isdir(art_dir):
        return recs
    for name in sorted(os.listdir(art_dir)):
        if name.endswith(".json"):
            with open(os.path.join(art_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def report(art_dir: str = "artifacts/dryrun", mesh: str | None = "pod_16x16") -> str:
    """Markdown roofline table over all recorded cells."""
    rows = []
    header = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL_FLOPS/HLO_FLOPs | bytes/dev | note |"
    )
    rows.append(header)
    rows.append("|" + "---|" * 10)
    for rec in load_records(art_dir):
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("variant"):
            continue  # perf variants reported in §Perf, not the baseline table
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | — | — | — | "
                f"— | — | — | SKIP: {rec['reason'][:60]}… |"
            )
            continue
        if rec["status"] != "ok":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | — | — | — | "
                f"— | — | — | ERROR: {rec['error'][:60]} |"
            )
            continue
        rl = roofline_from_record(rec)
        mf = rec.get("model_flops") or 0.0
        # model_flops is the GLOBAL estimate; compiled flops are per device
        chips = 512 if "multipod" in rec["mesh"] else 256
        trips = loop_trips(rec)
        ratio = (mf / chips) / (rec["flops"] * trips) if rec.get("flops") else 0.0
        mem = rec.get("memory", {})
        dev_bytes = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rl.compute_s:.4f} | {rl.memory_s:.4f} | {rl.collective_s:.4f} | "
            f"**{rl.dominant}** | {ratio:.2f} | {dev_bytes/1e9:.2f} GB | {rec.get('notes','')} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    print(report(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun",
                 mesh=None))
