"""DIN + embedding substrate tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.recsys import make_din_batch
from repro.models.din import DINConfig, din_forward, din_init, din_loss, din_retrieval_scores
from repro.models.embedding import embedding_bag, mod_shard_table

settings.register_profile("r", deadline=None, max_examples=15)
settings.load_profile("r")

CFG = DINConfig(n_items=5000, n_users=500, n_cates=50, seq_len=16)


@pytest.fixture(scope="module")
def params():
    return din_init(CFG, jax.random.key(0))


def test_forward_shapes(params):
    b = make_din_batch(32, seq_len=16, n_items=5000, n_users=500)
    logits = din_forward(params, b, CFG)
    assert logits.shape == (32,)
    assert bool(jnp.isfinite(logits).all())


def test_retrieval_consistent_with_forward(params):
    """Scoring candidate c for one user via retrieval == via pointwise forward."""
    rb = make_din_batch(1, seq_len=16, n_items=5000, n_users=500, n_candidates=64)
    scores = din_retrieval_scores(params, rb, CFG)
    fwd_b = {
        "user": jnp.tile(rb["user"], 64),
        "hist_items": jnp.tile(rb["hist_items"], (64, 1)),
        "hist_mask": jnp.tile(rb["hist_mask"], (64, 1)),
        "cand_item": rb["cand_items"],
    }
    fwd = din_forward(params, fwd_b, CFG)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(fwd), rtol=1e-4, atol=1e-5)


def test_history_mask_effect(params):
    """Masked history positions must not influence the score."""
    b = make_din_batch(8, seq_len=16, n_items=5000, n_users=500)
    s1 = din_forward(params, b, CFG)
    b2 = dict(b)
    # scramble items at masked positions
    rng = np.random.default_rng(0)
    hist = np.asarray(b["hist_items"]).copy()
    mask = np.asarray(b["hist_mask"])
    hist[mask == 0] = rng.integers(0, 5000, (mask == 0).sum())
    b2["hist_items"] = jnp.asarray(hist)
    s2 = din_forward(params, b2, CFG)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)


def test_train_decreases_loss(params):
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    oc = AdamWConfig(lr=1e-2, weight_decay=0.0)
    p = params
    opt = adamw_init(p, oc)
    losses = []
    for step in range(12):
        b = make_din_batch(64, seq_len=16, n_items=5000, n_users=500, seed=step % 3)
        (loss, _), g = jax.value_and_grad(lambda q: din_loss(q, b, CFG), has_aux=True)(p)
        p, opt, _ = adamw_update(g, opt, p, oc)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


@given(st.integers(0, 2**31), st.integers(1, 12), st.sampled_from(["sum", "mean", "max"]))
def test_embedding_bag_property(seed, n_bags, mode):
    rng = np.random.default_rng(seed)
    V, D, n_ids = 50, 6, 40
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    ids = rng.integers(0, V, n_ids)
    bags = np.sort(rng.integers(0, n_bags, n_ids))
    out = np.asarray(
        embedding_bag(table, jnp.asarray(ids, jnp.int32), jnp.asarray(bags, jnp.int32),
                      n_bags, mode=mode)
    )
    tb = np.asarray(table)
    for bg in range(n_bags):
        rows = tb[ids[bags == bg]]
        if rows.shape[0] == 0:
            if mode != "max":
                np.testing.assert_allclose(out[bg], 0.0, atol=1e-6)
            continue
        expect = {"sum": rows.sum(0), "mean": rows.mean(0), "max": rows.max(0)}[mode]
        np.testing.assert_allclose(out[bg], expect, rtol=1e-4, atol=1e-5)


def test_embedding_bag_weighted():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(20, 4)), jnp.float32)
    ids = jnp.asarray([1, 2, 3, 4], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1], jnp.int32)
    w = jnp.asarray([0.5, 2.0, 1.0, 0.0], jnp.float32)
    out = np.asarray(embedding_bag(table, ids, bags, 2, weights=w))
    tb = np.asarray(table)
    np.testing.assert_allclose(out[0], 0.5 * tb[1] + 2.0 * tb[2], rtol=1e-5)
    np.testing.assert_allclose(out[1], tb[3], rtol=1e-5)


def test_mod_shard_table_roundtrip():
    rng = np.random.default_rng(2)
    tbl = rng.normal(size=(103, 8)).astype(np.float32)
    sh = mod_shard_table(tbl, 4)
    assert sh.shape == (4, 26, 8)
    for v in range(103):
        r, local = v % 4, v // 4
        np.testing.assert_array_equal(sh[r, local], tbl[v])
