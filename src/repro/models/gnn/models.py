"""The four assigned GNN architectures, one functional (init, apply) pair each.

  schnet        [arXiv:1706.08566]  cfconv: RBF-filter ⊙ gather → segment_sum
  egnn          [arXiv:2102.09844]  E(n): scalar-distance MLP msgs + coord update
  mace          [arXiv:2206.07697]  E(3)-ACE: SH ⊗ radial A-basis, correlation-3
                                    symmetric CG contractions (real basis)
  equiformer_v2 [arXiv:2306.12059]  eSCN: per-edge Wigner rotation to edge frame,
                                    SO(2) m-restricted linear conv, graph attention

All share the GraphBatch contract; `apply` returns node embeddings [N, d_hidden];
`head` maps them to node logits (classification shapes) or per-graph energy
(molecule shape). See DESIGN.md §5 for documented simplifications.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .common import (
    aggregate,
    edge_hint,
    node_hint,
    bessel_rbf,
    cosine_cutoff,
    edge_vectors,
    gaussian_rbf,
    mlp_apply,
    mlp_init,
    readout,
)
from .equivariant import (
    irreps_dim,
    l_slices,
    real_cg,
    real_sph_harm,
    rotation_to_edge_frame,
    wigner_d_real,
)

N_SPECIES = 100


REMAT = True  # toggled by the 'naive' dry-run variant (§Perf before/after)


def _ckpt(fn):
    """Per-block remat: per-edge intermediates are recomputed in backward —
    without it the 12-layer equiformer saves every [E, C, irreps] tensor."""
    if not REMAT:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)



@dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # schnet | egnn | mace | equiformer_v2
    n_layers: int
    d_hidden: int
    n_rbf: int = 16
    cutoff: float = 10.0
    l_max: int = 0
    m_max: int = 0
    n_heads: int = 1
    correlation: int = 1
    d_feat: int = 0  # input node-feature width (0 → atom-type embedding only)
    n_classes: int = 0  # 0 → energy head


# ---------------------------------------------------------------------------
# SchNet
# ---------------------------------------------------------------------------


def schnet_init(cfg: GNNConfig, key) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_layers * 3)
    d = cfg.d_hidden
    p = {"embed": jax.random.normal(ks[0], (N_SPECIES, d)) * 0.1, "blocks": []}
    if cfg.d_feat:
        p["feat_proj"] = mlp_init(ks[1], [cfg.d_feat, d])
    for i in range(cfg.n_layers):
        p["blocks"].append({
            "filter": mlp_init(ks[2 + 3 * i], [cfg.n_rbf, d, d]),
            "in": mlp_init(ks[3 + 3 * i], [d, d]),
            "out": mlp_init(ks[4 + 3 * i], [d, d, d]),
        })
    return p


def _ssp(x):  # shifted softplus (SchNet activation)
    return jax.nn.softplus(x) - math.log(2.0)


def schnet_apply(p: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    n = batch["z"].shape[0]
    x = jnp.take(p["embed"], batch["z"], axis=0)
    if cfg.d_feat and "node_feat" in batch:
        x = x + mlp_apply(p["feat_proj"], batch["node_feat"])
    _, r = edge_vectors(batch["pos"], batch["edge_src"], batch["edge_dst"])
    rbf = gaussian_rbf(r, cfg.n_rbf, cfg.cutoff) * batch["edge_mask"][:, None]
    for blk in p["blocks"]:
        def block(x, blk=blk):
            W = mlp_apply(blk["filter"], rbf, act=_ssp, final_act=True)
            h = mlp_apply(blk["in"], x)
            msg = edge_hint(jnp.take(h, batch["edge_src"], axis=0)) * W
            agg = aggregate(msg, batch["edge_dst"], n)
            return node_hint(x + mlp_apply(blk["out"], agg, act=_ssp))
        x = _ckpt(block)(x)
    return x


# ---------------------------------------------------------------------------
# EGNN
# ---------------------------------------------------------------------------


def egnn_init(cfg: GNNConfig, key) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_layers * 3)
    d = cfg.d_hidden
    p = {"embed": jax.random.normal(ks[0], (N_SPECIES, d)) * 0.1, "blocks": []}
    if cfg.d_feat:
        p["feat_proj"] = mlp_init(ks[1], [cfg.d_feat, d])
    for i in range(cfg.n_layers):
        p["blocks"].append({
            "phi_e": mlp_init(ks[2 + 3 * i], [2 * d + 1, d, d]),
            "phi_x": mlp_init(ks[3 + 3 * i], [d, d, 1]),
            "phi_h": mlp_init(ks[4 + 3 * i], [2 * d, d, d]),
        })
    return p


def egnn_apply(p: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    n = batch["z"].shape[0]
    src, dst = batch["edge_src"], batch["edge_dst"]
    h = jnp.take(p["embed"], batch["z"], axis=0)
    if cfg.d_feat and "node_feat" in batch:
        h = h + mlp_apply(p["feat_proj"], batch["node_feat"])
    x = batch["pos"]
    em = batch["edge_mask"][:, None]
    for blk in p["blocks"]:
        def block(xh, blk=blk):
            x, h = xh
            vec = edge_hint(jnp.take(x, src, axis=0) - jnp.take(x, dst, axis=0))
            d2 = jnp.sum(vec**2, axis=-1, keepdims=True)
            hi = edge_hint(jnp.take(h, dst, axis=0))
            hj = edge_hint(jnp.take(h, src, axis=0))
            m = mlp_apply(blk["phi_e"], jnp.concatenate([hi, hj, d2], -1), final_act=True) * em
            # coordinate update (normalized difference, EGNN eq. 4)
            coef = mlp_apply(blk["phi_x"], m) * em
            xup = aggregate(vec / (jnp.sqrt(d2) + 1.0) * coef, dst, n)
            magg = aggregate(m, dst, n)
            return (x + xup, node_hint(h + mlp_apply(blk["phi_h"], jnp.concatenate([h, magg], -1))))
        x, h = _ckpt(block)((x, h))
    return h


# ---------------------------------------------------------------------------
# MACE (E(3)-ACE, correlation order 3, channel-wise real-CG contractions)
# ---------------------------------------------------------------------------


def _mace_paths(l_max: int) -> list[tuple[int, int, int]]:
    return [
        (l1, l2, l3)
        for l1 in range(l_max + 1)
        for l2 in range(l_max + 1)
        for l3 in range(l_max + 1)
        if abs(l1 - l2) <= l3 <= l1 + l2
    ]


def mace_init(cfg: GNNConfig, key) -> dict:
    C, dim = cfg.d_hidden, irreps_dim(cfg.l_max)
    paths2 = _mace_paths(cfg.l_max)
    ks = jax.random.split(key, 6 + cfg.n_layers * (4 + len(paths2)))
    p: dict = {"embed": jax.random.normal(ks[0], (N_SPECIES, C)) * 0.1, "blocks": []}
    if cfg.d_feat:
        p["feat_proj"] = mlp_init(ks[1], [cfg.d_feat, C])
    ki = 2
    for _ in range(cfg.n_layers):
        blk = {
            # radial MLP: one weight per (channel, l1, l2) A-path
            "radial": mlp_init(ks[ki], [cfg.n_rbf, 64, C * len(paths2)]),
            "w_A": jax.random.normal(ks[ki + 1], (len(paths2), C)) / math.sqrt(len(paths2)),
            "w_B2": jax.random.normal(ks[ki + 2], (len(paths2), C)) / math.sqrt(len(paths2)),
            "w_B3": jax.random.normal(ks[ki + 3], (len(paths2), C)) / math.sqrt(len(paths2)),
            "lin": jax.random.normal(ks[ki + 4], (C, C)) / math.sqrt(C),
        }
        p["blocks"].append(blk)
        ki += 5
    return p


def _couple(x: jnp.ndarray, y: jnp.ndarray, l1: int, l2: int, l3: int,
            sl: list[slice]) -> jnp.ndarray:
    Cmat = jnp.asarray(real_cg(l1, l2, l3), x.dtype)
    return jnp.einsum("ncm,ncp,mpq->ncq", x[..., sl[l1]], y[..., sl[l2]], Cmat)


def mace_apply(p: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    n = batch["z"].shape[0]
    C, lm = cfg.d_hidden, cfg.l_max
    dim = irreps_dim(lm)
    sl = l_slices(lm)
    src, dst = batch["edge_src"], batch["edge_dst"]
    vec, r = edge_vectors(batch["pos"], src, dst)
    Y = edge_hint(real_sph_harm(lm, vec))  # [E, dim]
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    env = (cosine_cutoff(r, cfg.cutoff) * batch["edge_mask"])[:, None]
    paths = _mace_paths(lm)

    h0 = jnp.take(p["embed"], batch["z"], axis=0)
    if cfg.d_feat and "node_feat" in batch:
        h0 = h0 + mlp_apply(p["feat_proj"], batch["node_feat"])
    # node irreps: scalar channel initialized from embedding
    h = node_hint(jnp.zeros((n, C, dim)).at[:, :, 0].set(h0))

    for blk in p["blocks"]:
      def block(h, blk=blk):
        Rw = mlp_apply(blk["radial"], rbf).reshape(-1, C, len(paths)) * env[..., None]
        Rw = edge_hint(Rw)
        hj = edge_hint(jnp.take(h, src, axis=0))  # [E, C, dim]
        # A-basis: Σ_j R ⊙ (Y_{l1} ⊗ h_{l2})_{l3}
        A = jnp.zeros((n, C, dim))
        for pi, (l1, l2, l3) in enumerate(paths):
            Cm = jnp.asarray(real_cg(l1, l2, l3), h.dtype)
            msg = jnp.einsum("em,ecp,mpq->ecq", Y[:, sl[l1]], hj[..., sl[l2]], Cm)
            msg = msg * Rw[:, :, pi : pi + 1]
            A = A.at[..., sl[l3]].add(aggregate(msg, dst, n))
        # B-basis: symmetric contractions, correlation order 1..3
        B = A * blk["w_A"][0][None, :, None]  # ν = 1 (per-channel scale)
        AA = jnp.zeros_like(A)
        for pi, (l1, l2, l3) in enumerate(paths):  # ν = 2
            AA = AA.at[..., sl[l3]].add(
                _couple(A, A, l1, l2, l3, sl) * blk["w_B2"][pi][None, :, None]
            )
        B = B + AA
        AAA = jnp.zeros_like(A)
        for pi, (l1, l2, l3) in enumerate(paths):  # ν = 3: (A⊗A)_{l1} ⊗ A_{l2} → l3
            AAA = AAA.at[..., sl[l3]].add(
                _couple(AA, A, l1, l2, l3, sl) * blk["w_B3"][pi][None, :, None]
            )
        B = B + AAA
        # channel-mixing update + residual (reduce-scatter back to C-sharded)
        return node_hint(h + jnp.einsum("ncq,cd->ndq", B, blk["lin"]) / len(paths))
      h = _ckpt(block)(h)
    return h[:, :, 0]  # scalar (invariant) channels


# ---------------------------------------------------------------------------
# EquiformerV2 (eSCN SO(2) convolution + graph attention)
# ---------------------------------------------------------------------------


def _m_restricted_dim(l_max: int, m_max: int) -> int:
    return sum(min(2 * l + 1, 2 * m_max + 1) for l in range(l_max + 1))


def equiformer_init(cfg: GNNConfig, key) -> dict:
    C, lm, mm = cfg.d_hidden, cfg.l_max, cfg.m_max
    ks = jax.random.split(key, 4 + cfg.n_layers * 6)
    n_l = lm + 1
    p: dict = {"embed": jax.random.normal(ks[0], (N_SPECIES, C)) * 0.1, "blocks": []}
    if cfg.d_feat:
        p["feat_proj"] = mlp_init(ks[1], [cfg.d_feat, C])
    for i in range(cfg.n_layers):
        k = ks[3 + 6 * i : 9 + 6 * i]
        blk = {
            # SO(2) conv: m=0 real matrix over (l, channel); m>0 complex pair
            "w_m0": jax.random.normal(k[0], (n_l, C, C)) / math.sqrt(C * n_l),
            "w_re": jax.random.normal(k[1], (mm, n_l, C, C)) / math.sqrt(C * n_l),
            "w_im": jax.random.normal(k[2], (mm, n_l, C, C)) / math.sqrt(C * n_l),
            "radial": mlp_init(k[3], [cfg.n_rbf, 64, C]),
            "attn": mlp_init(k[4], [2 * C, C, cfg.n_heads]),
            "ffn": mlp_init(k[5], [C, 2 * C, C]),
        }
        p["blocks"].append(blk)
    return p


def equiformer_apply(p: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    n = batch["z"].shape[0]
    C, lm, mm, H = cfg.d_hidden, cfg.l_max, cfg.m_max, cfg.n_heads
    dim = irreps_dim(lm)
    sl = l_slices(lm)
    src, dst = batch["edge_src"], batch["edge_dst"]
    vec, r = edge_vectors(batch["pos"], src, dst)
    rot = edge_hint(rotation_to_edge_frame(vec))  # [E,3,3]
    D = [edge_hint(d) for d in wigner_d_real(lm, rot)]  # per-l [E, 2l+1, 2l+1]
    Dt = [jnp.swapaxes(d, -1, -2) for d in D]
    rbf = edge_hint(gaussian_rbf(r, cfg.n_rbf, cfg.cutoff))
    env = (cosine_cutoff(r, cfg.cutoff) * batch["edge_mask"])[:, None]

    h0 = jnp.take(p["embed"], batch["z"], axis=0)
    if cfg.d_feat and "node_feat" in batch:
        h0 = h0 + mlp_apply(p["feat_proj"], batch["node_feat"])
    h = node_hint(jnp.zeros((n, C, dim)).at[:, :, 0].set(h0))

    for blk in p["blocks"]:
      def block(h, blk=blk):
        hj = edge_hint(jnp.take(h, src, axis=0))  # [E, C, dim]
        # rotate into edge frame, keep only |m| <= m_max coefficients (eSCN)
        rstack = []
        for l in range(lm + 1):
            xr = jnp.einsum("emk,eck->ecm", D[l], hj[..., sl[l]])  # rotated
            lo = max(0, l - mm)
            hi = min(2 * l, l + mm)
            rstack.append(xr[..., lo : hi + 1])  # m ∈ [-min(l,mm), min(l,mm)]
        # SO(2) linear conv: mixes channels and l at fixed m
        rad = mlp_apply(blk["radial"], rbf) * env  # [E, C] radial gate
        out_l: list[jnp.ndarray] = []
        for l in range(lm + 1):
            ml = min(l, mm)
            acc = jnp.zeros((hj.shape[0], C, 2 * l + 1))
            for lp in range(lm + 1):
                mlp_ = min(lp, mm)
                x = rstack[lp]  # [E, C, 2*mlp_+1]
                mshare = min(ml, mlp_)
                # m = 0 component
                y0 = jnp.einsum("ec,cd->ed", x[..., mlp_], blk["w_m0"][lp])
                acc = acc.at[..., l].add(y0)
                # m > 0: complex-structured 2×2 mixing of (cos, sin) parts
                for m in range(1, mshare + 1):
                    xc = x[..., mlp_ + m]  # cos part (m>0 real SH)
                    xs = x[..., mlp_ - m]  # sin part
                    wre, wim = blk["w_re"][m - 1, lp], blk["w_im"][m - 1, lp]
                    yc = jnp.einsum("ec,cd->ed", xc, wre) - jnp.einsum("ec,cd->ed", xs, wim)
                    ys = jnp.einsum("ec,cd->ed", xs, wre) + jnp.einsum("ec,cd->ed", xc, wim)
                    acc = acc.at[..., l + m].add(yc)
                    acc = acc.at[..., l - m].add(ys)
            out_l.append(acc * rad[..., None])
        # attention weights from invariant (l=0) features
        inv_i = jnp.take(h[:, :, 0], dst, axis=0)
        inv_msg = out_l[0][..., 0]
        logits = mlp_apply(blk["attn"], jnp.concatenate([inv_i, inv_msg], -1))  # [E, H]
        logits = logits - jax.ops.segment_max(logits, dst, num_segments=n)[dst]
        expw = jnp.exp(logits) * batch["edge_mask"][:, None]
        denom = aggregate(expw, dst, n)[dst] + 1e-9
        alpha = (expw / denom)  # [E, H] segment softmax
        ch_per_head = C // H
        alpha_c = jnp.repeat(alpha, ch_per_head, axis=1)  # [E, C]
        # rotate back and aggregate
        msg = jnp.zeros((hj.shape[0], C, dim))
        for l in range(lm + 1):
            msg = msg.at[..., sl[l]].set(
                jnp.einsum("emk,ecm->eck", Dt[l], out_l[l])
            )
        msg = msg * alpha_c[..., None]
        agg = aggregate(msg.reshape(msg.shape[0], -1), dst, n).reshape(n, C, dim)
        h = h + agg
        # gated FFN on invariant channel, scaling all irreps (equivariant gate)
        gate = mlp_apply(blk["ffn"], h[:, :, 0])
        h = h * jax.nn.sigmoid(gate)[..., None]
        return node_hint(h.at[:, :, 0].add(gate))
      h = _ckpt(block)(h)
    return h[:, :, 0]


# ---------------------------------------------------------------------------
# Dispatch table + heads
# ---------------------------------------------------------------------------

GNN_MODELS = {
    "schnet": (schnet_init, schnet_apply),
    "egnn": (egnn_init, egnn_apply),
    "mace": (mace_init, mace_apply),
    "equiformer_v2": (equiformer_init, equiformer_apply),
}


def gnn_init(cfg: GNNConfig, key) -> dict:
    init, _ = GNN_MODELS[cfg.arch]
    ks = jax.random.split(key, 2)
    p = {"backbone": init(cfg, ks[0])}
    out = cfg.n_classes if cfg.n_classes else 1
    p["head"] = mlp_init(ks[1], [cfg.d_hidden, cfg.d_hidden, out])
    return p


def gnn_apply(p: dict, batch: dict, cfg: GNNConfig, n_graphs: int = 1) -> jnp.ndarray:
    _, apply = GNN_MODELS[cfg.arch]
    x = apply(p["backbone"], batch, cfg)
    out = mlp_apply(p["head"], x)
    if cfg.n_classes:
        return out  # [N, n_classes] node logits
    return readout(out, batch, n_graphs)[:, 0]  # [n_graphs] energies


def gnn_loss(p: dict, batch: dict, cfg: GNNConfig, n_graphs: int = 1):
    out = gnn_apply(p, batch, cfg, n_graphs)
    if cfg.n_classes:
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
        loss = (nll * batch["node_mask"]).sum() / jnp.maximum(batch["node_mask"].sum(), 1)
    else:
        loss = jnp.mean((out - batch["labels"]) ** 2)
    return loss, {"loss": loss}
