"""Shared model substrate: norms, RoPE, sharding hints, init, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard_hint(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that degrades to a no-op outside a mesh context
    and silently drops axis names the current mesh doesn't have (so the same
    model code runs in 1-device smoke tests, the 256-chip pod and the 512-chip
    multi-pod mesh)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

        def filt(s, dim):
            if s is None:
                return None
            if isinstance(s, (tuple, list)):
                kept = tuple(a for a in s if a in names)
                if not kept:
                    return None
                n = 1
                for a in kept:
                    n *= sizes[a]
                return kept if dim % n == 0 else None
            if s not in names:
                return None
            return s if dim % sizes[s] == 0 else None

        full = tuple(spec) + (None,) * (x.ndim - len(spec))
        return jax.lax.with_sharding_constraint(
            x, P(*(filt(s, d) for s, d in zip(full, x.shape)))
        )
    except Exception:
        return x


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE; logits [..., V] fp32-softmaxed."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
