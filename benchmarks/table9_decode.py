"""Paper Tables 9/10: decompression throughput.

BCA decode via (a) the Pallas bitunpack kernel (interpret mode on CPU — the
structural path; TPU is the target), (b) the pure-XLA oracle, (c) numpy host
codec; Huffman/DictBCA host decode for the measure-column regime. Reports
values/s; the paper's observation to reproduce: Huffman is CPU-bound and
order-of-magnitude slower than bit-aligned decode on FK columns, and bitmaps
win on dense unique fragments."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import codecs as C
from repro.kernels import ops

from .common import emit, timeit


def run() -> None:
    rng = np.random.default_rng(0)
    # FK-column regime: large domain, unique-ish values (paper Table 9)
    domain = 1_000_000_000
    n = 100_000
    vals = rng.integers(0, domain, n)
    width = C.bits_needed(domain)
    raw = C.pack_bits(vals, width).tobytes()
    raw += b"\0" * ((-len(raw)) % 4)
    packed = jnp.asarray(np.frombuffer(raw, dtype=np.uint32))

    t = timeit(lambda: np.asarray(ops.bitunpack(packed, width, n, use_pallas=False)))
    emit("table9/fk/bca_xla", t * 1e6, f"vals_per_s={n/t:.3e}")
    t = timeit(lambda: np.asarray(ops.bitunpack(packed, width, n)), iters=3)
    emit("table9/fk/bca_pallas_interpret", t * 1e6, f"vals_per_s={n/t:.3e} (CPU interpret; TPU target)")
    bca = C.BCACodec(domain)
    buf = bca.encode(vals)
    t = timeit(lambda: bca.decode(buf, n), iters=3)
    emit("table9/fk/bca_numpy", t * 1e6, f"vals_per_s={n/t:.3e}")

    hc = C.HuffmanCodec(rng.zipf(1.5, 50_000).astype(np.int64) % 65536)
    frag = hc.sym[rng.integers(0, len(hc.sym), 20_000)]
    hbuf = hc.encode(frag)
    t = timeit(lambda: hc.decode(hbuf, len(frag)), iters=2, warmup=1)
    emit("table9/fk/huffman_host", t * 1e6, f"vals_per_s={len(frag)/t:.3e}")

    # measure-column regime: domain 100, Zipf (paper Table 10)
    col = rng.zipf(1.5, 200_000).astype(np.int64) % 100
    hc2 = C.HuffmanCodec(col)
    frag2 = col[:100_000]
    hbuf2 = hc2.encode(frag2)
    t = timeit(lambda: hc2.decode(hbuf2, len(frag2)), iters=2, warmup=1)
    emit("table10/measure/huffman_host", t * 1e6,
         f"vals_per_s={len(frag2)/t:.3e} ratio={len(hbuf2)/ (8*len(frag2)):.3f}")
    dc = C.DictBCACodec(col)
    dbuf = dc.encode(frag2)
    t = timeit(lambda: dc.decode(dbuf, len(frag2)), iters=3)
    emit("table10/measure/dictbca_host", t * 1e6,
         f"vals_per_s={len(frag2)/t:.3e} ratio={len(dbuf)/(8*len(frag2)):.3f}")
    # DictBCA on-device decode path (bitunpack + gather)
    draw = dbuf + b"\0" * ((-len(dbuf)) % 4)
    dwords = jnp.asarray(np.frombuffer(draw, dtype=np.uint32))
    dictionary = jnp.asarray(dc.dictionary)
    t = timeit(lambda: np.asarray(
        jnp.take(dictionary, ops.bitunpack(dwords, dc.width, len(frag2), use_pallas=False))
    ))
    emit("table10/measure/dictbca_xla", t * 1e6, f"vals_per_s={len(frag2)/t:.3e}")


if __name__ == "__main__":
    run()
