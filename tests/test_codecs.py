"""Codec roundtrips (hypothesis property tests), space model, Fig.-12 chooser."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core import codecs as C

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@st.composite
def fragment(draw, unique=False, max_domain=5000):
    domain = draw(st.integers(2, max_domain))
    n = draw(st.integers(1, min(200, domain if unique else 200)))
    if unique:
        vals = draw(
            st.lists(st.integers(0, domain - 1), min_size=n, max_size=n, unique=True)
        )
        return np.sort(np.asarray(vals, np.int64)), domain
    vals = draw(st.lists(st.integers(0, domain - 1), min_size=n, max_size=n))
    return np.asarray(vals, np.int64), domain


@given(fragment())
def test_ua_roundtrip(fd):
    vals, domain = fd
    c = C.UACodec(domain)
    assert np.array_equal(c.decode(c.encode(vals), len(vals)), vals)


@given(fragment())
def test_bca_roundtrip(fd):
    vals, domain = fd
    c = C.BCACodec(domain)
    assert np.array_equal(c.decode(c.encode(vals), len(vals)), vals)


@given(fragment(unique=True, max_domain=2000))
def test_ub_roundtrip(fd):
    vals, domain = fd
    c = C.UBCodec(domain)
    assert np.array_equal(c.decode(c.encode(vals), len(vals)), vals)


@given(fragment(unique=True, max_domain=100000))
def test_bb_roundtrip(fd):
    vals, domain = fd
    c = C.BBCodec()
    assert np.array_equal(c.decode(c.encode(vals), len(vals)), vals)


@given(st.integers(0, 2**31), st.integers(1, 60))
def test_huffman_roundtrip_seeded(seed, nuniq):
    rng = np.random.default_rng(seed)
    col = rng.zipf(1.5, size=500).astype(np.int64) % nuniq
    c = C.HuffmanCodec(col)
    frag = col[:37]
    assert np.array_equal(c.decode(c.encode(frag), len(frag)), frag)


@given(st.integers(0, 2**31))
def test_dictbca_roundtrip(seed):
    rng = np.random.default_rng(seed)
    col = rng.zipf(1.5, size=300).astype(np.int64) % 50
    c = C.DictBCACodec(col)
    frag = col[10:200]
    assert np.array_equal(c.decode(c.encode(frag), len(frag)), frag)


def test_huffman_beats_bca_on_zipf():
    rng = np.random.default_rng(0)
    col = rng.zipf(1.5, size=20000).astype(np.int64) % 100
    hc = C.HuffmanCodec(col)
    bits_h = hc.encoded_bits(col)
    bits_bca = len(col) * C.bits_needed(100)
    assert bits_h < bits_bca  # entropy coding wins on skew (paper Table 8)


def test_dictbca_near_huffman_on_zipf():
    """DictBCA (escape-coded) is the documented TPU substitute for Huffman:
    never worse than fixed-width packing, within ~2.3× of Huffman across skews
    (DESIGN.md §2; exact ratios in benchmarks/table9)."""
    rng = np.random.default_rng(0)
    for zipf_s, nuniq in [(1.5, 100), (1.2, 1000), (2.0, 100)]:
        col = rng.zipf(zipf_s, size=20000).astype(np.int64) % nuniq
        hc = C.HuffmanCodec(col)
        dc = C.DictBCACodec(col)
        bits_h = hc.encoded_bits(col)
        bits_d = dc.encoded_bits(col)
        bits_fixed = len(col) * C.bits_needed(len(np.unique(col)))
        assert bits_d <= bits_fixed
        assert bits_d < 2.3 * bits_h, (zipf_s, nuniq, bits_d / bits_h)


# ---- analytic space model (paper §5 + Appendix 9.1 cases) -------------------


def test_space_model_case1_ua_never_minimal():
    for n, d in [(10, 100), (100, 10**6), (3, 2**40)]:
        assert C.space_ua(n, d) >= C.space_bca(n, d)


def test_space_model_case2_small_domain_ub():
    # D <= 8 → UB minimal
    assert C.choose_key_encoding(3, 8) == "UB"


def test_space_model_dense_fragment_ub():
    # D/8 <= N < D/2 and D > 2^7 → UB (paper Case 7)
    assert C.choose_key_encoding(5000, 20000) == "UB"


def test_space_model_sparse_fragment_bb():
    # N ≤ D/128-ish with large domain → BB beats BCA (paper Case 5, Fig. 12)
    assert C.choose_key_encoding(100, 10**7) in ("BB", "BCA")
    # the paper's Doc-fragment regime (dotted line in Fig. 12): BB
    assert C.choose_key_encoding(3470, 23_000_000) == "BB"


def test_measure_encoding_huffman_on_low_entropy():
    assert C.choose_measure_encoding(1000, 50, entropy_bits=1.5) == "Huffman"
    assert C.choose_measure_encoding(10, 2**20, entropy_bits=19.9) == "BCA"


@given(st.integers(1, 10**6), st.integers(2, 2**40))
def test_space_model_nonnegative(n, d):
    for f in (C.space_ua, C.space_ub, C.space_bca, C.space_bb):
        assert f(n, d) >= 0


def test_bb_exact_vs_model():
    """BB varint bytes for a concrete fragment match the paper's example."""
    c = C.BBCodec()
    # gaps 100, 3000, 95 (paper §5 example): 1 + 2 + 1 bytes
    vals = np.cumsum([100, 3001, 96]) - 1
    assert len(c.encode(vals)) == 4
