"""Multi-device distribution tests. Device count is fixed at process start, so
these run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run8(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_query_engine_8dev_matches_single():
    out = _run8("""
        import numpy as np, jax
        from repro.data.synth_graph import *
        from repro.core.engine import GQFastDatabase, GQFastEngine
        schema = make_pubmed(n_docs=500, n_terms=50, n_authors=200)
        db = GQFastDatabase(schema, account_space=False)
        base = GQFastEngine(db)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        dist = GQFastEngine(db, mesh=mesh)
        for q, p in [(QUERY_AS, {"a0": 7}), (QUERY_AD, {"t1": 3, "t2": 9}),
                     (QUERY_FSD, {"d0": 5})]:
            assert np.allclose(base.query(q, **p), dist.query(q, **p),
                               rtol=1e-4, atol=1e-4)
        print("MATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_batched_distributed_query():
    out = _run8("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.data.synth_graph import *
        from repro.core.engine import GQFastDatabase, GQFastEngine
        from repro.core import executor as X
        from repro.core.planner import plan_query
        from repro.core.sql import parse
        schema = make_pubmed(n_docs=400, n_terms=40, n_authors=150)
        db = GQFastDatabase(schema, account_space=False)
        base = GQFastEngine(db)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        plan = plan_query(schema, parse(QUERY_AS))
        fb = X.compile_frontier_distributed(db.device, plan, mesh,
                                            ("data", "model"), batched=True)
        out = np.asarray(fb(jnp.arange(6)))
        expect = np.stack([base.query(QUERY_AS, a0=i) for i in range(6)])
        assert np.allclose(out, expect, rtol=1e-4, atol=1e-4)
        print("MATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_sharded_embedding_lookup_8dev():
    out = _run8("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.models.embedding import sharded_embedding_lookup, mod_shard_table
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        V, D, ns = 1003, 16, 8
        tbl = rng.normal(size=(V, D)).astype(np.float32)
        sh = jnp.asarray(mod_shard_table(tbl, ns))
        ids = jnp.asarray(rng.integers(0, V, 64).astype(np.int32))
        sharded = jax.device_put(sh, jax.sharding.NamedSharding(mesh, P("model", None, None)))
        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map
        f = jax.jit(shard_map(
            lambda t, i: sharded_embedding_lookup(t.reshape(-1, D), i, ns),
            mesh=mesh, in_specs=(P("model", None, None), P()), out_specs=P()))
        out = np.asarray(f(sharded, ids))
        assert np.allclose(out, tbl[np.asarray(ids)], atol=1e-5)
        print("MATCH")
    """)
    assert "MATCH" in out


@pytest.mark.slow
def test_compressed_psum_8dev():
    """EF int8 all-reduce across 8 devices ≈ exact mean; error-feedback keeps
    the long-run bias tiny."""
    out = _run8("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import compressed_psum
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        gl = rng.normal(size=(8, 256)).astype(np.float32)  # per-device grads
        g_sh = jax.device_put(jnp.asarray(gl), jax.sharding.NamedSharding(mesh, P("data", None)))
        e0 = jax.device_put(jnp.zeros((8, 256)), jax.sharding.NamedSharding(mesh, P("data", None)))
        def body(g, e):
            m, er = compressed_psum(g[0], e[0], "data")
            return m, er[None]

        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map
        f = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P(), P("data", None))))

        mean, err = f(g_sh, e0)
        true = gl.mean(0)
        rel = np.abs(np.asarray(mean) - true).max() / np.abs(true).max()
        assert rel < 0.05, rel  # one-shot int8 tolerance
        # error feedback correctness: err == (g + 0) − dequant(local)
        mean2, err2 = f(g_sh, err)
        # over two steps the accumulated mean is closer to the exact sum
        two = np.asarray(mean) + np.asarray(mean2)
        rel2 = np.abs(two - 2 * true).max() / np.abs(2 * true).max()
        assert rel2 < rel, (rel2, rel)
        print("MATCH", rel, rel2)
    """)
    assert "MATCH" in out


def test_shard_hint_noop_without_mesh():
    from repro.models.common import shard_hint

    x = jnp.ones((4, 4))
    y = shard_hint(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_spec_filtering_on_small_mesh():
    from repro.dist.sharding import _filter, lm_param_spec

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    # 'model' axis absent → dropped by the mesh filter; divisibility by the
    # 1-sized 'data' axis always holds
    spec = _filter(mesh, lm_param_spec("layers/wq", (2, 64, 4, 16), mesh, n_kv_heads=2))
    assert all(s is None or s == "data" for s in spec)
