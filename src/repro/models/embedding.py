"""EmbeddingBag and model-parallel embedding tables.

JAX has no native EmbeddingBag or CSR sparse — per the assignment this IS part
of the system: built from ``jnp.take`` + ``jax.ops.segment_sum`` (exactly a
GQ-Fast fragment lookup + γ hop, DESIGN.md §5).

The sharded lookup row-mod-shards the table over the ``model`` axis and
exchanges only batch×dim activations (psum), never gathering the table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import shard_hint


def embedding_bag(
    table: jnp.ndarray,  # [V, D]
    ids: jnp.ndarray,  # [n_ids] flat ids of all bags
    bag_ids: jnp.ndarray,  # [n_ids] which bag each id belongs to
    n_bags: int,
    weights: jnp.ndarray | None = None,  # per-id weights
    mode: str = "sum",
) -> jnp.ndarray:
    """Ragged multi-hot lookup-and-reduce (torch ``nn.EmbeddingBag`` semantics,
    CSR-style (ids, bag offsets→bag_ids) layout)."""
    vecs = jnp.take(table, ids, axis=0)  # [n_ids, D]
    if weights is not None:
        vecs = vecs * weights[:, None]
    out = jax.ops.segment_sum(vecs, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids, n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    elif mode == "max":
        out = jax.ops.segment_max(vecs, bag_ids, num_segments=n_bags)
    return out


def sharded_embedding_lookup(
    table: jnp.ndarray,  # [V, D] — row-mod-sharded over 'model' when meshed
    ids: jnp.ndarray,  # [...] int32
    n_shards: int,
    axis_name: str = "model",
) -> jnp.ndarray:
    """Lookup for a table partitioned row-mod over ``axis_name`` inside
    shard_map: shard r owns rows {v : v % n_shards == r}; every shard looks up
    its local rows for the full id batch (masked) and a psum combines — the
    collective moves batch×D, not the table."""
    r = jax.lax.axis_index(axis_name)
    local = jnp.take(table, ids // n_shards, axis=0)
    mask = (ids % n_shards == r).astype(table.dtype)
    return jax.lax.psum(local * mask[..., None], axis_name)


def mod_shard_table(table, n_shards: int):
    """Host-side: reorder a [V, D] table into the row-mod layout expected by
    :func:`sharded_embedding_lookup` ([n_shards · ceil(V/n) rows])."""
    import numpy as np

    V, D = table.shape
    rows_per = -(-V // n_shards)
    out = np.zeros((n_shards * rows_per, D), table.dtype)
    for rshard in range(n_shards):
        rows = np.arange(rshard, V, n_shards)
        out[rshard * rows_per : rshard * rows_per + rows.shape[0]] = table[rows]
    return out.reshape(n_shards, rows_per, D)
