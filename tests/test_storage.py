"""Device column-store tests (DESIGN.md §Storage): pack→unpack round trips
across widths 1–32, DeviceColumn contract, storage policy, and packed-vs-
decoded executor equivalence on all three strategies vs the numpy oracle."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.core.fragments import _pack_words
from repro.core.reference import run_sql
from repro.data import synth_graph as SG
from repro.kernels import ops
from repro.storage import (
    DenseColumn,
    DictPackedColumn,
    PackedColumn,
    build_device_column,
    choose_device_encoding,
    device_space_report,
    resolve_device_encoding,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _roundtrip(vals: np.ndarray, width: int) -> np.ndarray:
    words = _pack_words(vals, width)
    return np.asarray(ops.bitunpack(words, width, vals.shape[0]))


# ---------------------------------------------------------------------------
# _pack_words → bitunpack round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", list(range(1, 33)))
def test_pack_unpack_all_widths(width):
    """Every width 1–32, with word-straddling offsets (any width ∤ 32) and a
    count that is neither a multiple of 1024 nor of 32."""
    rng = np.random.default_rng(width)
    count = 1024 + 513  # straddles block and group boundaries
    vals = rng.integers(0, 2**width, size=count, dtype=np.uint64)
    got = _roundtrip(vals, width)
    # width 32 occupies the full int32 range: compare modulo 2^32
    assert np.array_equal(got.astype(np.uint32), vals.astype(np.uint32))


@pytest.mark.parametrize("count", [1, 31, 32, 33, 1023, 1024, 1025, 2050, 4097])
def test_pack_unpack_odd_counts(count):
    """Non-multiple-of-1024 counts: the kernel's zero-padded tail blocks must
    not leak into the first ``count`` values."""
    rng = np.random.default_rng(count)
    for width in (1, 7, 11, 17, 29):
        vals = rng.integers(0, 2**width, size=count, dtype=np.uint64)
        assert np.array_equal(_roundtrip(vals, width), vals.astype(np.int64))


def test_storage_imports_standalone():
    """repro.storage must be importable before repro.core (the engine imports
    storage, so an eager core import inside storage would cycle)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.storage, repro.core; print('OK')"],
        capture_output=True, text=True, env=env, timeout=120,
    )
    assert out.returncode == 0, out.stderr[-2000:]


def test_pack_unpack_empty_fragment():
    vals = np.zeros(0, dtype=np.uint64)
    for width in (1, 13, 32):
        assert _roundtrip(vals, width).shape == (0,)


def test_spmv_kernels_empty_edge_list():
    """A zero-row relation must hop to the ⊕-identity, not crash pallas_call."""
    w = np.ones(10, np.float32)
    e_i = np.zeros(0, np.int32)
    e_w = np.zeros(0, np.uint32)
    for op, ident in [("sum", 0.0), ("min", np.inf), ("bool", 0.0)]:
        out = np.asarray(ops.fragment_spmv(w, e_i, e_i, e_i.astype(np.float32), 7, op=op))
        assert np.all(out == ident)
        out = np.asarray(ops.fragment_spmv_packed(
            w, e_i, e_w, None, None, n_dst=7, dst_width=5, op=op))
        assert np.all(out == ident)


def test_dict_encoding_capped_by_dictionary_size():
    """The fused kernel pins the dictionary in VMEM, so high-cardinality
    columns must not choose dict even when it wins on HBM bytes."""
    from repro.storage.policy import DICT_MAX_ENTRIES, _candidate_bytes

    rng = np.random.default_rng(3)
    # distinct count just over the cap; 17-bit dict indices would beat dense
    vals = np.arange(DICT_MAX_ENTRIES + 1).repeat(4)
    rng.shuffle(vals)
    assert "dict" not in _candidate_bytes(vals, 2**40, is_key=False)
    col = build_device_column(_CF(vals, 2**40), "dict", jnp.float32)
    assert col.kind == "dense"  # explicit request degrades rather than OOMs


def test_pack_unpack_property():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the [test] extra"
    )
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(st.integers(1, 32), st.integers(0, 5000), st.integers(0, 2**31))
    def prop(width, count, seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(0, 2**width, size=count, dtype=np.uint64)
        got = _roundtrip(vals, width)
        assert np.array_equal(got.astype(np.uint32), vals.astype(np.uint32))

    prop()


# ---------------------------------------------------------------------------
# DeviceColumn contract
# ---------------------------------------------------------------------------


class _CF:
    """Minimal ColumnFragments stand-in for build_device_column."""

    def __init__(self, values, domain, packed=None, packed_width=0):
        self.values = values
        self.domain = domain
        self.packed = packed
        self.packed_width = packed_width


def test_device_column_kinds_agree():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1000, size=3000)
    vals[::7] = 3  # skew so the dictionary ordering is non-trivial
    cf = _CF(vals, 1000)
    ids = rng.integers(0, vals.shape[0], size=257)
    dense = build_device_column(cf, "dense", jnp.float32)
    packed = build_device_column(cf, "packed", jnp.float32)
    dpack = build_device_column(cf, "dict", jnp.float32)
    assert isinstance(dense, DenseColumn)
    assert isinstance(packed, PackedColumn) and isinstance(dpack, DictPackedColumn)
    base = np.asarray(dense.materialize())
    for col in (packed, dpack):
        assert np.array_equal(np.asarray(col.materialize()), base)
        assert np.array_equal(np.asarray(col.gather(ids)), base[ids])
        assert col.device_nbytes < dense.device_nbytes
        assert col.count == vals.shape[0]


def test_packed_column_reuses_loader_words():
    vals = np.arange(100) % 17
    packed_words = _pack_words(vals, 5)
    cf = _CF(vals, 17, packed=packed_words, packed_width=5)
    col = build_device_column(cf, "packed", jnp.int32)
    assert col.width == 5
    assert np.array_equal(np.asarray(col.materialize()), vals)


def test_policy_chooser():
    rng = np.random.default_rng(1)
    narrow = rng.integers(0, 50, size=10_000)  # 6-bit: packed ≈ 5× smaller
    assert choose_device_encoding(narrow, 50, is_key=True) == "packed"
    # wide domain but few distinct values → dict wins for measures
    sparse = rng.choice([0, 9_999_999, 123456], size=10_000)
    assert choose_device_encoding(sparse, 10_000_000, is_key=False) == "dict"
    # keys never dict-encode
    assert choose_device_encoding(sparse, 10_000_000, is_key=True) == "packed"
    # ≥32-bit values can't pack
    assert choose_device_encoding(narrow, 2**40, is_key=True) == "dense"
    with pytest.raises(ValueError):
        resolve_device_encoding("bogus", ("T", "K", "c"), narrow, 50, is_key=True)
    with pytest.raises(ValueError):
        resolve_device_encoding(
            {("T", "K", "c"): "dict"}, ("T", "K", "c"), narrow, 50, is_key=True
        )
    # per-column override + auto fill
    spec = {("T", "K", "c"): "dense"}
    assert resolve_device_encoding(spec, ("T", "K", "c"), narrow, 50, True) == "dense"
    assert resolve_device_encoding(spec, ("T", "K", "d"), narrow, 50, True) == "packed"


def test_signed_and_wide_value_columns():
    """Bit packing is unsigned: signed columns must not pack (silent low-bit
    truncation); dict still applies — the dictionary keeps original values."""
    rng = np.random.default_rng(2)
    signed = rng.choice([-7, -1, 3, 12], size=4000)
    assert choose_device_encoding(signed, 13, is_key=False) == "dict"
    assert "packed" not in (
        choose_device_encoding(signed, 13, is_key=True),  # keys: dense only
    )
    # explicit packed request on signed data degrades to dense, without a scan
    assert resolve_device_encoding("packed", ("T", "K", "c"), signed, 13, False) == "dense"
    col = build_device_column(_CF(signed, 13), "dict", jnp.float32)
    assert np.array_equal(np.asarray(col.materialize()), signed)
    # sparse huge-magnitude values: the rank mapping must scale with #distinct,
    # not the value range (would be a ~17 GB allocation otherwise)
    sparse = rng.choice(np.array([5, 2**31 - 3, 123456789]), size=4000)
    col = build_device_column(_CF(sparse, 2**31), "dict", jnp.int32)
    assert col.kind == "dict" and col.device_nbytes < 4 * 4000
    assert np.array_equal(np.asarray(col.materialize()), sparse)


# ---------------------------------------------------------------------------
# Packed vs decoded execution — all strategies, vs the numpy oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pubmed():
    return SG.make_pubmed(n_docs=1500, n_terms=80, n_authors=400, seed=3)


@pytest.fixture(scope="module")
def dbs(pubmed):
    packed = GQFastDatabase(pubmed, account_space=False)  # auto → packed columns
    dense = GQFastDatabase(pubmed, account_space=False, device_encodings="dense")
    return packed, dense


CASES = [
    ("SD", SG.QUERY_SD, {"d0": 5}),
    ("FSD", SG.QUERY_FSD, {"d0": 5}),
    ("AS", SG.QUERY_AS, {"a0": 7}),
    ("AD", SG.QUERY_AD, {"t1": 3, "t2": 9}),
    ("FAD", SG.QUERY_FAD, {"t1": 3, "t2": 9}),
]


def test_auto_policy_packs_bca_columns(dbs):
    packed, _ = dbs
    for (t, k), di in packed.device.indexes.items():
        assert di.dst_col.kind == "packed", (t, k)


@pytest.mark.parametrize("name,q,params", CASES, ids=[c[0] for c in CASES])
def test_frontier_packed_bit_identical(dbs, pubmed, name, q, params):
    """Acceptance: packed device storage changes bytes, not results — the
    frontier output must be *bit-identical* to the decoded path, and both
    match the materializing numpy oracle."""
    packed, dense = dbs
    a = GQFastEngine(packed).query(q, **params)
    b = GQFastEngine(dense).query(q, **params)
    assert np.array_equal(a, b), "packed frontier diverged from decoded"
    ref = run_sql(pubmed, q, params)
    np.testing.assert_allclose(a, ref, rtol=1e-4, atol=1e-4)
    assert (a != 0).sum() > 0


@pytest.mark.parametrize("name,q,params", CASES[:3], ids=[c[0] for c in CASES[:3]])
def test_fragment_loop_packed_matches(dbs, pubmed, name, q, params):
    packed, dense = dbs
    a = GQFastEngine(packed, strategy="fragment_loop").query(q, **params)
    b = GQFastEngine(dense, strategy="fragment_loop").query(q, **params)
    assert np.array_equal(a, b)
    ref = run_sql(pubmed, q, params)
    np.testing.assert_allclose(a, ref, rtol=5e-3, atol=1e-2)


def test_distributed_packed_matches(dbs):
    """1-device mesh exercises shard_edges' materialize-per-shard fallback."""
    from repro.launch.mesh import make_mesh

    packed, dense = dbs
    mesh = make_mesh((1,), ("data",))
    for name, q, params in CASES[:3]:
        a = GQFastEngine(packed, mesh=mesh).query(q, **params)
        b = GQFastEngine(dense, mesh=mesh).query(q, **params)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_distributed_packed_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np
            from repro.data.synth_graph import *
            from repro.core.engine import GQFastDatabase, GQFastEngine
            from repro.launch.mesh import make_mesh
            schema = make_pubmed(n_docs=500, n_terms=50, n_authors=200)
            packed = GQFastDatabase(schema, account_space=False)
            dense = GQFastDatabase(schema, account_space=False,
                                   device_encodings="dense")
            mesh = make_mesh((8,), ("data",))
            for q, p in [(QUERY_AS, {"a0": 7}), (QUERY_SD, {"d0": 5})]:
                a = GQFastEngine(packed, mesh=mesh).query(q, **p)
                b = GQFastEngine(dense, mesh=mesh).query(q, **p)
                assert np.allclose(a, b, rtol=1e-4, atol=1e-4)
            print("MATCH")
        """)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "MATCH" in out.stdout


# ---------------------------------------------------------------------------
# Space acceptance: ≥2× on BCA-eligible columns, real device bytes
# ---------------------------------------------------------------------------


def test_device_space_report_2x(dbs):
    packed, dense = dbs
    rep = device_space_report(packed.device)
    base = device_space_report(dense.device)
    for idx_name, idx in rep["indexes"].items():
        for cname, col in idx["columns"].items():
            if col["kind"] in ("packed", "dict"):
                assert col["dense_bytes"] >= 2 * col["device_bytes"], (idx_name, cname)
    assert rep["total_bytes"] < base["total_bytes"]
    assert base["total_bytes"] == rep["dense_bytes"]
    # the engine-level report carries the device section
    assert packed.space_report()["device"]["total_bytes"] == rep["total_bytes"]


def test_device_encoding_override_per_column(pubmed):
    db = GQFastDatabase(
        pubmed, account_space=False,
        device_encodings={("DT", "Term", "Fre"): "dict"},
    )
    di = db.device.index("DT", "Term")
    assert di.measure_cols["Fre"].kind == "dict"
    assert di.dst_col.kind == "packed"  # auto fills the rest
    got = GQFastEngine(db).query(SG.QUERY_SD, d0=5)
    ref = run_sql(pubmed, SG.QUERY_SD, {"d0": 5})
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_device_encoding_unknown_address_rejected(pubmed):
    """A typo'd per-column override must error, not silently fall to auto."""
    with pytest.raises(ValueError, match="match no index column"):
        GQFastDatabase(
            pubmed, account_space=False,
            device_encodings={("DT", "Term", "fre"): "dense"},  # wrong case
        )


def test_materialized_memo_accounted_and_shared(pubmed):
    """Fallback-strategy decodes pin one shared dense copy per packed column;
    the space report surfaces it instead of silently claiming compression."""
    db = GQFastDatabase(pubmed, account_space=False)
    assert device_space_report(db.device)["materialized_bytes"] == 0
    eng = GQFastEngine(db, strategy="fragment_loop")
    eng.prepare(SG.QUERY_SD)
    col = db.device.index("DT", "Term").dst_col
    first = col.materialize()
    assert col.materialize() is first  # memo: no second decoded copy
    rep = device_space_report(db.device)
    assert rep["materialized_bytes"] >= 4 * col.count
