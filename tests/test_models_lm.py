"""LM substrate tests: chunked attention vs full oracle, decode==forward,
MoE dispatch invariants, RoPE, param counts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    MoEConfig,
    TransformerConfig,
    chunked_attention,
    decode_step,
    forward,
    init_kv_cache,
    init_params,
    loss_fn,
    moe_ffn,
    prefill,
)

CFG = TransformerConfig("t", 2, 64, 4, 2, 128, 97, d_head=16, qkv_bias=True,
                        remat=False, attn_kv_chunk=16)
MCFG = TransformerConfig("tm", 2, 64, 4, 4, 96, 97, d_head=16, remat=False,
                         attn_kv_chunk=16,
                         moe=MoEConfig(8, 2, 32, dense_residual=True))


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.key(0))


@pytest.mark.parametrize("S,kv_chunk,causal", [(37, 8, True), (64, 64, True), (16, 4, False)])
def test_chunked_attention_oracle(S, kv_chunk, causal):
    B, H, Hkv, hd = 2, 4, 2, 16
    ks = jax.random.split(jax.random.key(S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out = chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
    kr, vr = jnp.repeat(k, H // Hkv, 2), jnp.repeat(v, H // Hkv, 2)
    s = jnp.einsum("bshk,bthk->bhst", q, kr) / np.sqrt(hd)
    if causal:
        s = jnp.where(np.tril(np.ones((S, S), bool))[None, None], s, -1e30)
    refo = jnp.einsum("bhst,bthk->bshk", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo), rtol=2e-3, atol=2e-3)


def test_decode_matches_forward(params):
    toks = jax.random.randint(jax.random.key(1), (2, 33), 0, 97)
    pl_logits, cache, _ = prefill(params, toks, CFG, 64)
    f_logits, _ = forward(params, toks, CFG)
    np.testing.assert_allclose(np.asarray(pl_logits), np.asarray(f_logits[:, -1]), atol=1e-3)
    nt = jnp.argmax(pl_logits, -1).astype(jnp.int32)
    d_logits, _ = decode_step(params, cache, nt, jnp.int32(33), CFG)
    ext = jnp.concatenate([toks, nt[:, None]], axis=1)
    f2, _ = forward(params, ext, CFG)
    np.testing.assert_allclose(np.asarray(d_logits), np.asarray(f2[:, -1]), atol=1e-3)


def test_multistep_decode(params):
    toks = jax.random.randint(jax.random.key(2), (2, 10), 0, 97)
    logits, cache, _ = prefill(params, toks, CFG, 32)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    seq = [cur]
    for i in range(3):
        logits, cache = decode_step(params, cache, cur, jnp.int32(10 + i), CFG)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        seq.append(cur)
    # oracle: greedy via repeated full forward
    full = toks
    for i in range(4):
        fl, _ = forward(params, full, CFG)
        nxt = jnp.argmax(fl[:, -1], -1).astype(jnp.int32)
        assert np.array_equal(np.asarray(nxt), np.asarray(seq[i])), f"step {i}"
        full = jnp.concatenate([full, nxt[:, None]], 1)


def test_moe_forward_and_aux():
    p = init_params(MCFG, jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (2, 32), 0, 97)
    logits, aux = forward(p, toks, MCFG)
    assert logits.shape == (2, 32, 97)
    assert float(aux) > 0  # load-balance loss active
    assert bool(jnp.isfinite(logits).all())


def test_moe_capacity_overflow_drops_cleanly():
    cfg = TransformerConfig("o", 1, 32, 2, 2, 32, 31, d_head=16, remat=False,
                            moe=MoEConfig(4, 2, 16, capacity_factor=0.25))
    p = init_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 32))
    y, aux = moe_ffn(jax.tree.map(lambda a: a[0], p["layers"]), x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_moe_identical_tokens_identical_outputs():
    """Permutation/dispatch bookkeeping: identical tokens must get identical
    outputs regardless of their capacity slot."""
    cfg = TransformerConfig("p", 1, 32, 2, 2, 32, 31, d_head=16, remat=False,
                            moe=MoEConfig(4, 1, 16, capacity_factor=4.0))
    p = init_params(cfg, jax.random.key(0))
    row = jax.random.normal(jax.random.key(2), (1, 32))
    x = jnp.tile(row, (16, 1))
    y, _ = moe_ffn(jax.tree.map(lambda a: a[0], p["layers"]), x, cfg)
    np.testing.assert_allclose(np.asarray(y - y[0]), 0.0, atol=1e-5)


def test_loss_decreases_sanity(params):
    from repro.data.lm_data import lm_batch
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    p = params
    losses = []
    for step in range(10):
        batch = lm_batch(step, 8, 32, 97, seed=5)
        (loss, _), g = jax.value_and_grad(lambda q: loss_fn(q, batch, CFG), has_aux=True)(p)
        p, opt, _ = adamw_update(g, opt, p, opt_cfg)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_param_count_formula():
    for cfg in (CFG, MCFG):
        p = init_params(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(p))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.02  # biases excluded from formula


def test_pad_heads():
    cfg = TransformerConfig("x", 1, 64, 56, 8, 64, 100, d_head=16)
    padded = cfg.pad_heads(16)
    assert padded.n_heads == 64 and padded.n_kv_heads == 8
    assert cfg.pad_heads(8).n_heads == 56
