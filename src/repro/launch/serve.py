"""Serving launcher: GQ-Fast analytics micro-batching server, or LM decode.

  PYTHONPATH=src python -m repro.launch.serve --workload analytics
  PYTHONPATH=src python -m repro.launch.serve --workload lm

The analytics workload is the paper's target deployment turned into a real
serving loop: many concurrent dashboard queries that differ only in parameter
bindings. The server collects queued requests per query shape, pads each
micro-batch to a fixed bucket size (one compile per shape), runs ONE batched
SpMM pass over the engine via the fault-tolerant runner
(``repro.robust.run_batch_with_policy`` — every hop streams the edge arrays
once for the whole bucket, and failures degrade down the ladder instead of
crashing the loop), scatters the structured per-request outcomes back, and
reports measured queries/sec against the sequential single-query baseline.

Robustness surface (DESIGN.md §Robustness):

  * every micro-batch runs under a :class:`repro.robust.RobustPolicy`
    (deadline via ``--deadline-ms``, retry + degradation ladder); request
    failures come back as typed per-request errors, never tracebacks;
  * ``--queue-bound N`` sheds load beyond N queued requests (typed
    OVERLOAD errors, ``serve.requests_shed`` counter) instead of letting the
    queue grow without bound;
  * SIGINT/SIGTERM drain the loop and still flush ``--metrics-json`` /
    ``--profile-json`` (the ``finally`` path);
  * ``--chaos`` installs a seeded :class:`repro.robust.faults.FaultPlan`
    (kernel-dispatch raises + per-attempt delays + per-attempt raises)
    *before* prepare, so trace-time kernel faults and run-time attempt
    faults both fire — the CI chaos smoke lane.

Durability surface (DESIGN.md §Durability):

  * ``--snapshot-dir`` fast-starts from the latest checksummed snapshot
    generation (``storage/snapshot.py``) instead of rebuilding indexes; a
    fresh build publishes generation 1 there for the next start;
  * SIGHUP — or ``--reload-at N``, every N batches — triggers a verified
    hot swap: the next generation loads, checksum-verifies, and warms on a
    background thread while the old one keeps serving, then swaps in at a
    micro-batch boundary (zero dropped in-flight requests by construction:
    the loop only swaps between fully-answered batches). A generation that
    fails verification or warm-up rolls back — the old generation keeps
    serving, ``serve.reload_failures`` counts, the typed error is logged;
  * ``--scrub`` runs a full integrity pass before serving and background
    :class:`repro.robust.scrub.Scrubber` ticks during it; a heal re-prepares
    every shape (executables may close over replaced arrays);
  * ``--verify-responses`` replays every answered request on the pure-numpy
    oracle (``core/reference.py`` — no fault sites, trustworthy under
    chaos) and counts ``serve.responses_corrupt`` mismatches;
  * ``--chaos-corrupt`` extends the chaos plan with corrupt-mode faults at
    ``storage.materialize`` (healed by verified reads), ``scrub.verify``
    (detect → heal from snapshot), and ``snapshot.load`` (first hot-swap
    rolls back) — the corrupt-and-heal CI lane.
"""
from __future__ import annotations

import argparse
import time
from collections import deque


def _chaos_plan(seed: int, corrupt: bool = False):
    """The chaos smoke lane's seeded fault mix: a bounded burst of kernel-
    dispatch failures (fires at trace time → ladder demotions), sporadic
    50 ms per-attempt delays (trips ``--deadline-ms``), and sporadic
    retryable attempt failures (exercises retry/backoff after jit caching
    makes kernel sites quiescent).

    ``corrupt`` adds the durability mix (all bounded so late scenarios are
    deterministic): two corrupted materialize reads (the verified-read path
    heals them from the memo), three corrupted scrubber reads (persists
    through the scrubber's re-read retries → detect → quarantine → heal from
    snapshot → re-verify), and one corrupted snapshot-restore read (the
    first hot-swap reload fails verification and rolls back; the next
    succeeds)."""
    from repro.robust import faults

    plan = (
        faults.FaultPlan(seed=seed)
        .add(faults.FaultSpec(site="ops.", mode="raise", prob=0.5, max_fires=4))
        .add(faults.FaultSpec(site="runner.execute", mode="delay",
                              delay_ms=50.0, prob=0.2))
        .add(faults.FaultSpec(site="runner.execute", mode="raise",
                              prob=0.15, max_fires=6))
    )
    if corrupt:
        plan.add(faults.FaultSpec(site="storage.materialize", mode="corrupt",
                                  max_fires=2))
        plan.add(faults.FaultSpec(site="scrub.verify", mode="corrupt",
                                  max_fires=3))
        plan.add(faults.FaultSpec(site="snapshot.load", mode="corrupt",
                                  max_fires=1))
    return plan


def load_generation(snapshot_dir: str, queries: dict, sample_params,
                    bucket: int, generation: int | None = None,
                    strategy: str = "frontier"):
    """The fallible half of a verified hot swap: restore one snapshot
    generation (every array checksum-verified — raises
    :class:`repro.robust.errors.IntegrityError` on any mismatch), build an
    engine on it, prepare and warm every query shape (single + batched
    executables, so the swap adds no compile stall), and return
    ``(engine, prepared, generation)``. Raises without side effects on the
    caller's serving state — the rollback contract is simply "don't swap"."""
    import numpy as np

    from repro.core.engine import GQFastEngine
    from repro.storage.snapshot import latest_generation, restore_db

    gen = generation if generation is not None else latest_generation(snapshot_dir)
    if gen is None:
        raise FileNotFoundError(f"no snapshot generations in {snapshot_dir}")
    db = restore_db(snapshot_dir, gen)
    eng = GQFastEngine(db, strategy=strategy)
    prepared = {}
    for name, sql in queries.items():
        pq = eng.prepare(sql)
        p = sample_params(name)
        pq(**p)
        pq.execute_batch(**{k: np.full(bucket, v) for k, v in p.items()})
        prepared[name] = pq
    return eng, prepared, gen


def _serve_analytics(args) -> None:
    import contextlib
    import contextvars
    import json
    import signal
    import threading

    import numpy as np

    from repro.core.engine import GQFastDatabase, GQFastEngine, batch_bucket
    from repro.data import synth_graph as SG
    from repro.obs.metrics import MetricsRegistry
    from repro.robust import RetryPolicy, RobustPolicy, run_batch_with_policy
    from repro.robust import faults
    from repro.robust.errors import QueryError, ResourceError

    reg = MetricsRegistry()

    print("loading database…")
    t0 = time.time()
    db = None
    generation = 0
    if args.snapshot_dir:
        from repro.robust.errors import IntegrityError
        from repro.storage.snapshot import latest_generation

        gen = latest_generation(args.snapshot_dir)
        if gen is not None:
            from repro.storage.snapshot import restore_db

            try:
                db = restore_db(args.snapshot_dir, gen)
                generation = gen
                reg.counter("serve.fast_starts").inc()
                print(f"  fast start: restored generation {gen} "
                      f"from {args.snapshot_dir}")
            except IntegrityError as e:
                # a corrupted snapshot never serves; rebuild from source
                reg.counter("serve.restore_failures").inc()
                reg.counter(f"robust.errors.{e.code}").inc()
                print(f"  snapshot restore REJECTED [{e.code}]: {e}\n"
                      "  rebuilding from source data…")
    if db is None:
        schema = SG.make_pubmed(
            n_docs=args.docs, n_terms=1_200, n_authors=args.docs // 5, seed=5
        )
        db = GQFastDatabase(schema, account_space=False)
        if args.snapshot_dir:
            from repro.storage.snapshot import latest_generation, snapshot_db

            snapshot_db(db, args.snapshot_dir)
            generation = latest_generation(args.snapshot_dir) or 1
            print(f"  published snapshot generation {generation} "
                  f"to {args.snapshot_dir}")
    schema = db.schema
    eng = GQFastEngine(db)
    reg.gauge("serve.db_load_ms").set((time.time() - t0) * 1e3)
    print(f"  {time.time()-t0:.1f}s "
          f"(DT {schema.relationships['DT'].num_rows} rows, "
          f"DA {schema.relationships['DA'].num_rows} rows)")

    # integrity manifest: a restored DB carries one; a fresh build gets one
    # whenever something will check it (scrubber ticks or corrupt-mode chaos)
    if (args.scrub or args.chaos_corrupt) \
            and getattr(db.device, "integrity", None) is None:
        from repro.storage.integrity import attach_manifest

        attach_manifest(db.device)

    queries = {
        "AS": SG.QUERY_AS, "SD": SG.QUERY_SD, "FSD": SG.QUERY_FSD,
        "AD": SG.QUERY_AD, "FAD": SG.QUERY_FAD,
    }
    rng = np.random.default_rng(0)

    # parameter samplers draw from the loaded graph's actual id domains —
    # the entity sizes in the schema, not whatever the default scale was
    n_authors = schema.entities["Author"].size
    n_docs = schema.entities["Document"].size
    n_terms = schema.entities["Term"].size

    def sample_params(kind: str) -> dict[str, int]:
        if kind == "AS":
            return {"a0": int(rng.integers(0, n_authors))}
        if kind in ("SD", "FSD"):
            return {"d0": int(rng.integers(0, n_docs))}
        return {"t1": int(rng.integers(0, n_terms)),
                "t2": int(rng.integers(0, n_terms))}

    policy = RobustPolicy(
        retry=RetryPolicy(max_attempts=2, base_ms=2.0, seed=args.chaos_seed),
        deadline_ms=args.deadline_ms,
        registry=reg,
    )

    def _open_out(path: str):
        import os

        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        return open(path, "w")

    def dump_metrics() -> None:
        if args.metrics_json:
            with _open_out(args.metrics_json) as fh:
                fh.write(reg.to_json(indent=2))

    # the chaos plan must be live BEFORE prepare: kernel-dispatch fault sites
    # fire at trace time, so only compiles under the plan can see them
    chaos = faults.active(_chaos_plan(args.chaos_seed, args.chaos_corrupt)) \
        if args.chaos else contextlib.nullcontext()
    stop: dict = {"signal": None}
    reload_req = {"pending": 0}

    def _on_signal(signum, frame):  # drain, flush, exit cleanly
        stop["signal"] = signum

    def _on_hup(signum, frame):  # verified hot swap at the next batch boundary
        reload_req["pending"] += 1

    old_handlers = {
        s: signal.signal(s, _on_signal)
        for s in (signal.SIGINT, signal.SIGTERM)
    }
    if hasattr(signal, "SIGHUP"):
        old_handlers[signal.SIGHUP] = signal.signal(signal.SIGHUP, _on_hup)

    # response verification oracle: the pure-numpy reference engine has no
    # fault sites, so its answers stay trustworthy while a chaos plan is live
    if args.verify_responses:
        from repro.core.reference import run_sql as _oracle_run_sql

    results: list = []
    sizes: list[int] = []
    plan = None
    try:
        with chaos as plan:
            # prepare every shape; under chaos a prepare may eat an injected
            # fault — retry once (the faults are retryable), then serve the
            # remaining shapes and fail that shape's requests with the typed
            # error instead of crashing the server
            prepared, prep_errors = {}, {}
            for name, sql in queries.items():
                for attempt in (1, 2):
                    try:
                        prepared[name] = eng.prepare(sql)
                        break
                    except QueryError as e:
                        prep_errors[name] = e
                        reg.counter(f"robust.errors.{e.code}").inc()
                        reg.counter("serve.prepare_failures").inc()
            for name in list(prep_errors):
                if name in prepared:
                    prep_errors.pop(name, None)

            bucket = batch_bucket(args.batch)

            # one mutable serving reference: the hot-swap applies by
            # replacing these four entries together at a batch boundary
            serving = {"eng": eng, "prepared": prepared,
                       "prep_errors": prep_errors, "generation": generation,
                       "scrubber": None}
            reg.gauge("serve.serving_generation").set(float(generation))

            heal_events: list[str] = []

            def _make_scrubber(for_db):
                from repro.robust.scrub import Scrubber

                return Scrubber(
                    for_db, snapshot_dir=args.snapshot_dir, cols_per_tick=2,
                    registry=reg, on_heal=heal_events.append,
                )

            if args.scrub:
                # pre-serving gate: one full pass — at-rest corruption is
                # detected (and healed from snapshot) before any query reads it
                sc = _make_scrubber(db)
                gate = sc.scrub_full()
                print(f"  integrity gate: {gate['verified']} verified, "
                      f"{gate['healed']} healed, {gate['failed']} failed")
                if args.scrub_interval_ms > 0:
                    sc.start(args.scrub_interval_ms / 1e3)
                serving["scrubber"] = sc

            reload_state: dict = {"thread": None, "result": None, "error": None}

            def _start_reload() -> None:
                def work():
                    try:
                        reload_state["result"] = load_generation(
                            args.snapshot_dir, queries, sample_params, bucket,
                        )
                    except BaseException as e:  # noqa: BLE001 — typed below
                        reload_state["error"] = e

                # copy_context: the chaos FaultPlan is a ContextVar, which
                # threads do not inherit — the reload must run under the plan
                # so snapshot.load faults fire during chaos lanes
                ctx = contextvars.copy_context()
                th = threading.Thread(
                    target=lambda: ctx.run(work), name="reloader", daemon=True
                )
                reload_state["thread"] = th
                th.start()

            def _apply_reload() -> None:
                """Runs only at micro-batch boundaries: the previous batch is
                fully answered, so the swap drops zero in-flight requests."""
                th = reload_state["thread"]
                if th is None or th.is_alive():
                    return
                th.join()
                reload_state["thread"] = None
                err = reload_state.pop("error", None)
                res = reload_state.pop("result", None)
                reload_state.update(result=None, error=None)
                if err is not None:
                    # rollback: the old generation keeps serving untouched
                    code = getattr(err, "code", type(err).__name__)
                    reg.counter("serve.reload_failures").inc()
                    reg.counter(f"robust.errors.{code}").inc()
                    print(f"  reload FAILED, generation "
                          f"{serving['generation']} keeps serving "
                          f"[{code}]: {err}")
                    return
                new_eng, new_prepared, gen = res
                old_sc = serving["scrubber"]
                if old_sc is not None:
                    old_sc.stop()
                serving.update(
                    eng=new_eng, prepared=new_prepared, prep_errors={},
                    generation=gen,
                )
                if old_sc is not None:
                    sc = _make_scrubber(new_eng.db)
                    if args.scrub_interval_ms > 0:
                        sc.start(args.scrub_interval_ms / 1e3)
                    serving["scrubber"] = sc
                reg.counter("serve.generation_reloads").inc()
                reg.gauge("serve.serving_generation").set(float(gen))
                print(f"  hot-swapped to generation {gen}")

            def _reprepare_after_heal() -> None:
                """Executables can close over replaced device buffers — after
                a heal, drop and rebuild every prepared shape."""
                n_heals = len(heal_events)
                heal_events.clear()
                serving["eng"].invalidate_prepared()
                fresh = 0
                for name, sql in queries.items():
                    try:
                        serving["prepared"][name] = serving["eng"].prepare(sql)
                        serving["prep_errors"].pop(name, None)
                        fresh += 1
                    except QueryError as e:
                        serving["prep_errors"][name] = e
                        reg.counter(f"robust.errors.{e.code}").inc()
                reg.counter("serve.reprepares").inc(fresh)
                print(f"  re-prepared {fresh} shapes after "
                      f"{n_heals} heal(s)")
            names = list(queries)
            stream = [
                (i, names[int(rng.integers(0, len(names)))])
                for i in range(args.requests)
            ]
            stream = [(i, kind, sample_params(kind)) for i, kind in stream]

            print(f"warmup (one batched compile per shape, bucket={bucket})…")
            t0 = time.time()
            for kind in prepared:
                p = sample_params(kind)
                try:
                    prepared[kind](**p)  # single-query executable (baseline)
                    prepared[kind].execute_batch(
                        **{k: np.full(bucket, v) for k, v in p.items()}
                    )
                except QueryError as e:  # chaos can fail a warmup compile;
                    reg.counter(f"robust.errors.{e.code}").inc()  # the ladder
                    # re-compiles per rung at serve time, so keep going
            print(f"  {time.time()-t0:.1f}s")

            if args.profile_json:
                # one EXPLAIN ANALYZE profile of the first shape, for artifacts
                try:
                    kind = next(iter(prepared))
                    prof = prepared[kind].profile(**sample_params(kind))
                    with _open_out(args.profile_json) as fh:
                        fh.write(prof.to_json(indent=2))
                    print(f"  wrote QueryProfile({kind}) to {args.profile_json}")
                except QueryError as e:
                    print(f"  profile skipped (injected fault): {e.code}")

            # sequential baseline: the same mix served one query at a time
            # (skipped under chaos — raw calls would surface injected faults)
            seq_qps = None
            if not args.chaos and prepared:
                base_n = min(args.requests, 25)
                t0 = time.perf_counter()
                served = 0
                for _, kind, params in stream[:base_n]:
                    if kind in prepared:
                        prepared[kind](**params)
                        served += 1
                seq_dt = time.perf_counter() - t0
                seq_qps = served / seq_dt if seq_dt > 0 else None
                if seq_qps:
                    reg.gauge("serve.sequential_queries_per_sec").set(seq_qps)

            print(f"serving {args.requests} requests, micro-batch ≤ {args.batch}"
                  + (f", deadline {args.deadline_ms:.0f}ms"
                     if args.deadline_ms else "")
                  + (" [CHAOS]" if args.chaos else "") + "…")
            results = [None] * len(stream)
            queue = deque(stream)

            # load shedding: beyond --queue-bound queued requests, reject the
            # tail with a typed OVERLOAD error instead of queueing unboundedly
            if args.queue_bound and len(queue) > args.queue_bound:
                shed = ResourceError(
                    f"queue bound {args.queue_bound} exceeded; request shed",
                    code="OVERLOAD", retryable=True,
                    queue_bound=args.queue_bound,
                )
                n_shed = len(queue) - args.queue_bound
                for _ in range(n_shed):
                    i, _, _ = queue.pop()
                    results[i] = {"status": "error", **shed.to_dict()}
                reg.counter("serve.requests_shed").inc(n_shed)
                reg.counter(f"robust.errors.{shed.code}").inc(n_shed)
                print(f"  shed {n_shed} requests over queue bound "
                      f"{args.queue_bound}")

            lat_all = reg.histogram("serve.request_latency_ms")
            t0 = time.perf_counter()
            while queue:
                if stop["signal"] is not None:
                    n = len(queue)
                    reg.counter("serve.requests_unserved").inc(n)
                    print(f"  signal {stop['signal']}: draining, {n} requests"
                          " unserved")
                    break
                # batch boundary: apply a finished reload, launch a requested
                # one, re-prepare after heals — never mid-batch
                _apply_reload()
                if (reload_req["pending"] > 0 and reload_state["thread"] is None
                        and args.snapshot_dir):
                    reload_req["pending"] -= 1
                    _start_reload()
                if heal_events:
                    _reprepare_after_heal()
                prepared = serving["prepared"]
                prep_errors = serving["prep_errors"]
                tb = time.perf_counter()
                # collect: drain up to `batch` requests of the head's shape
                i0, kind, p0 = queue.popleft()
                group = [(i0, p0)]
                skipped: deque = deque()
                while queue and len(group) < args.batch:
                    item = queue.popleft()
                    if item[1] == kind:
                        group.append((item[0], item[2]))
                    else:
                        skipped.append(item)
                queue.extendleft(reversed(skipped))
                if kind not in prepared:  # shape never compiled (chaos)
                    err = prep_errors[kind]
                    for req_id, _ in group:
                        results[req_id] = {"status": "error", **err.to_dict()}
                    reg.counter("serve.requests_error").inc(len(group))
                    continue
                # pad to the warmed bucket (repeat the last binding) so the
                # runner's own batch_bucket sees exactly one compiled shape
                arrays = {
                    k: np.asarray([p[k] for _, p in group]
                                  + [group[-1][1][k]] * (bucket - len(group)))
                    for k in p0
                }
                try:
                    faults.fire("serve.request", kind=kind, n=len(group))
                    outcomes = run_batch_with_policy(
                        prepared[kind], arrays,
                        deadline_ms=args.deadline_ms, policy=policy,
                    )[:len(group)]
                except QueryError as e:  # the serve.request fault site
                    reg.counter(f"robust.errors.{e.code}").inc()
                    outcomes = None
                for row, (req_id, _) in enumerate(group):
                    oc = outcomes[row] if outcomes is not None else None
                    if oc is None:
                        results[req_id] = {"status": "error",
                                           "code": "FAULT_INJECTED"}
                        reg.counter("serve.requests_error").inc()
                    elif oc.status == "error":
                        results[req_id] = oc.to_dict()
                        reg.counter("serve.requests_error").inc()
                    else:
                        results[req_id] = oc
                        reg.counter(f"serve.requests_{oc.status}").inc()
                sizes.append(len(group))
                # every request in the group completes when its batch does
                batch_ms = (time.perf_counter() - tb) * 1e3
                for _ in group:
                    lat_all.observe(batch_ms)
                reg.histogram(f"serve.request_latency_ms.{kind}").observe(batch_ms)
                reg.counter("serve.requests_served").inc(len(group))
                reg.counter("serve.batches_executed").inc()
                reg.counter("serve.padded_rows").inc(bucket - len(group))
                if args.verify_responses and outcomes is not None:
                    # replay every answered request on the numpy oracle —
                    # the zero-corrupted-responses guarantee is checked, not
                    # assumed (outside the latency measurement)
                    sdb = serving["eng"].db.schema
                    for row, (_, pr) in enumerate(group):
                        oc = outcomes[row]
                        if oc is None or oc.status == "error" or oc.value is None:
                            continue
                        expect = _oracle_run_sql(sdb, queries[kind], pr)
                        reg.counter("serve.responses_verified").inc()
                        got = np.asarray(oc.value)
                        if got.shape != expect.shape or not np.allclose(
                                got, expect, rtol=1e-4, atol=1e-5):
                            reg.counter("serve.responses_corrupt").inc()
                            print(f"  CORRUPT RESPONSE: {kind} params={pr} "
                                  f"max|Δ|={np.abs(got - expect).max():.3g}")
                if args.reload_at and len(sizes) % args.reload_at == 0:
                    reload_req["pending"] += 1
                reg.gauge("serve.batch_occupancy").set(float(np.mean(sizes)))
                reg.gauge("serve.bucket_padding_waste").set(
                    1.0 - float(np.sum(sizes)) / (len(sizes) * bucket)
                )
                elapsed = time.perf_counter() - t0
                reg.gauge("serve.queries_per_sec").set(
                    float(np.sum(sizes)) / elapsed if elapsed > 0 else 0.0
                )
                if args.metrics_every and len(sizes) % args.metrics_every == 0:
                    dump_metrics()

            dt = time.perf_counter() - t0
            # finish outstanding hot swaps: every requested reload completes
            # (or rolls back) before the summary, so `--reload-at` near the
            # end of the stream still exercises the full swap path
            while stop["signal"] is None and args.snapshot_dir and (
                    reload_state["thread"] is not None
                    or reload_req["pending"] > 0):
                if reload_state["thread"] is None:
                    reload_req["pending"] -= 1
                    _start_reload()
                reload_state["thread"].join()
                _apply_reload()
            if serving["scrubber"] is not None:
                serving["scrubber"].stop()
    finally:
        for s, h in old_handlers.items():
            signal.signal(s, h)
        # the flush contract: metrics reach disk on clean exit, signal drain,
        # AND unexpected failure alike
        dump_metrics()

    if plan is not None:
        print("  chaos fault stats:", json.dumps(plan.stats()))
        print("  robust counters:",
              json.dumps(reg.counters_with_prefix("robust.")))
    if args.snapshot_dir or args.scrub or args.verify_responses:
        durable = {
            k: v for k, v in reg.counters_with_prefix("serve.").items()
            if k.split(".", 1)[1] in (
                "fast_starts", "restore_failures", "generation_reloads",
                "reload_failures", "reprepares", "responses_verified",
                "responses_corrupt",
            )
        }
        print("  durability counters:", json.dumps(durable))
        print("  integrity counters:",
              json.dumps(reg.counters_with_prefix("robust.integrity.")))

    answered = sum(r is not None for r in results)
    by_status = {"ok": 0, "degraded": 0, "error": 0}
    for r in results:
        if r is None:
            continue
        status = r["status"] if isinstance(r, dict) else r.status
        by_status[status] = by_status.get(status, 0) + 1
    if stop["signal"] is None:
        # no crash, no silent loss: every request has a structured outcome
        assert answered == len(results), (answered, len(results))
    n_batches = max(len(sizes), 1)
    qps = answered / dt if dt > 0 else 0.0
    reg.gauge("serve.queries_per_sec").set(qps)
    if seq_qps:
        reg.gauge("serve.speedup_vs_sequential").set(qps / seq_qps)
    dump_metrics()
    snap = lat_all.snapshot()
    print(f"\n  {answered}/{len(results)} requests answered in {dt:.2f}s over "
          f"{len(sizes)} batched passes "
          f"(mean occupancy {np.mean(sizes) if sizes else 0:.1f}/{bucket})")
    print(f"  outcomes: {by_status['ok']} ok, {by_status['degraded']} degraded,"
          f" {by_status['error']} error")
    if snap.get("count"):
        print(f"  latency p50/p95/p99: {snap['p50']:.1f} / {snap['p95']:.1f} / "
              f"{snap['p99']:.1f} ms")
    print(f"  micro-batched: {qps:8.1f} queries/s")
    if seq_qps:
        print(f"  sequential:    {seq_qps:8.1f} queries/s "
              f"(speedup ×{qps/seq_qps:.1f})")
    if args.metrics_json:
        print(f"  metrics written to {args.metrics_json}")
    if args.echo_metrics:
        print(json.dumps(reg.snapshot()["gauges"], indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["analytics", "lm"], default="analytics")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default: 256 analytics, 60 lm)")
    ap.add_argument("--batch", type=int, default=32,
                    help="analytics: max requests per micro-batch "
                         "(padded to the engine's bucket size)")
    ap.add_argument("--docs", type=int, default=20_000,
                    help="analytics: synthetic database scale")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="analytics: dump the metrics registry (latency "
                         "histograms, occupancy/padding gauges, qps) as JSON")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="analytics: rewrite --metrics-json every N batches "
                         "(0: only at exit)")
    ap.add_argument("--profile-json", default=None, metavar="PATH",
                    help="analytics: dump one QueryProfile as JSON after warmup")
    ap.add_argument("--echo-metrics", action="store_true",
                    help="analytics: print the gauge snapshot at exit")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="analytics: per-request wall-clock deadline; overruns"
                         " return typed DEADLINE errors")
    ap.add_argument("--queue-bound", type=int, default=0,
                    help="analytics: shed requests beyond this queue depth "
                         "with typed OVERLOAD errors (0: unbounded)")
    ap.add_argument("--chaos", action="store_true",
                    help="analytics: serve under a seeded fault-injection "
                         "plan (kernel raises + attempt delays/raises)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="analytics: FaultPlan / retry-jitter seed")
    ap.add_argument("--chaos-corrupt", action="store_true",
                    help="analytics: add corrupt-mode faults to the chaos "
                         "plan (materialize reads, scrubber reads, snapshot "
                         "restore) — requires --chaos")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="analytics: fast-start from the latest checksummed "
                         "snapshot generation here (publishing one on fresh "
                         "build); enables SIGHUP/--reload-at hot swaps")
    ap.add_argument("--reload-at", type=int, default=0, metavar="N",
                    help="analytics: trigger a verified hot-swap reload "
                         "every N served batches (0: SIGHUP only)")
    ap.add_argument("--scrub", action="store_true",
                    help="analytics: full integrity scrub before serving + "
                         "background scrubber ticks during it")
    ap.add_argument("--scrub-interval-ms", type=float, default=200.0,
                    help="analytics: background scrub tick interval "
                         "(0: pre-serve gate only)")
    ap.add_argument("--verify-responses", action="store_true",
                    help="analytics: replay every answered request on the "
                         "numpy oracle; count serve.responses_corrupt")
    args = ap.parse_args()

    if args.workload == "analytics":
        if args.requests is None:
            args.requests = 256
        _serve_analytics(args)
        return
    if args.requests is None:
        args.requests = 60

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.models.transformer import decode_step, init_params, prefill

    arch = get_arch("qwen2.5-3b")
    cfg = arch.smoke_cfg
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    logits, cache, pos = prefill(params, toks, cfg, 128)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    out = [cur]
    for i in range(args.requests):
        logits, cache = step(params, cache, cur, jnp.int32(32 + i))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {args.requests} decode steps × batch 4: "
          f"{dt/args.requests*1e3:.1f} ms/step, {4*args.requests/dt:.1f} tok/s")
    print("sample tokens:", np.asarray(jnp.stack(out))[:10, 0].tolist())


if __name__ == "__main__":
    main()
