"""Observability subsystem tests (DESIGN.md §Observability).

Covers the tracer (nesting, exception safety, the allocation-free disabled
path), the metrics registry (histogram percentiles vs a numpy oracle, JSON
round-trip), and the profiling path (``PreparedQuery.profile`` bit-identical
to plain execution across all three strategies; ``explain(analyze=True)``
renders per-op timings and predicted-vs-observed hop fractions).
"""
import json

import numpy as np
import pytest

from repro.core.engine import GQFastDatabase, GQFastEngine
from repro.data.synth_graph import QUERY_AD, QUERY_AS, QUERY_SD, make_pubmed
from repro.obs import metrics as M
from repro.obs import trace as T
from repro.obs.profile import mispredicted


# ---------------------------------------------------------------- tracing


def test_spans_nest_and_record_wall_time():
    with T.recording() as tr:
        with T.span("outer"):
            with T.span("inner_a"):
                pass
            with T.span("inner_b", key="v"):
                pass
    assert [s.name for s in tr.roots] == ["outer"]
    outer = tr.roots[0]
    assert [c.name for c in outer.children] == ["inner_a", "inner_b"]
    assert outer.wall_ms is not None and outer.wall_ms >= 0
    assert outer.children[1].meta["key"] == "v"
    # self time never exceeds total and never goes negative
    assert 0 <= outer.self_wall_ms() <= outer.wall_ms + 1e-9


def test_span_closes_and_flags_status_under_exception():
    with T.recording() as tr:
        with pytest.raises(ValueError):
            with T.span("boom"):
                with T.span("child"):
                    raise ValueError("x")
    boom = tr.roots[0]
    assert boom.status == "error:ValueError"
    assert boom.wall_ms is not None  # closed despite the exception
    assert boom.children[0].status == "error:ValueError"
    # the stack fully unwound: new spans attach at the root again
    with T.recording() as tr2:
        with T.span("after"):
            pass
    assert [s.name for s in tr2.roots] == ["after"]


def test_disabled_fast_path_allocates_nothing():
    assert T.current() is None and not T.enabled()
    # every disabled span() call returns the same shared singleton
    s1, s2 = T.span("a"), T.span("b", big="meta")
    assert s1 is s2 is T.NULL_SPAN
    assert not hasattr(s1, "__dict__")  # __slots__ = (): no per-call state
    with s1 as s:
        s.annotate(x=1)
        assert s.fence(42) == 42
    T.annotate(ignored=True)  # no open span, no tracer: must be a no-op


def test_recording_nests_and_restores():
    with T.recording() as outer:
        with T.span("o"):
            pass
        with T.recording() as inner:
            with T.span("i"):
                pass
        assert T.current() is outer  # outer tracer resumes
        with T.span("o2"):
            pass
    assert T.current() is None
    assert [s.name for s in outer.roots] == ["o", "o2"]
    assert [s.name for s in inner.roots] == ["i"]


def test_tracer_to_dict_serializes_tree():
    with T.recording() as tr:
        with T.span("root", arr=np.arange(3)) as sp:
            sp.annotate(n=3)
            with T.span("leaf"):
                pass
    d = tr.to_dict()
    json.dumps(d)  # JSON-safe: non-scalar meta stringified
    assert d["spans"][0]["name"] == "root"
    assert d["spans"][0]["meta"]["n"] == 3
    assert d["spans"][0]["children"][0]["name"] == "leaf"


# ---------------------------------------------------------------- metrics


def test_counter_and_gauge():
    reg = M.MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)  # get-or-create returns the same metric
    reg.gauge("g").set(7)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 7.0


def test_histogram_exact_moments_and_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=2.0, sigma=1.5, size=5000)  # spread across buckets
    h = M.Histogram()
    h.observe_many(vals)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(vals.sum())
    assert h.min == vals.min() and h.max == vals.max()
    for q in (50, 95, 99):
        est, oracle = h.percentile(q), float(np.percentile(vals, q))
        # interpolation error is bounded by the containing bucket's width
        bi = np.searchsorted(np.asarray(h.bounds), oracle)
        lo = h.bounds[bi - 1] if bi > 0 else h.min
        hi = h.bounds[bi] if bi < len(h.bounds) else h.max
        assert abs(est - oracle) <= (hi - lo) + 1e-9, (q, est, oracle)


def test_histogram_edge_cases():
    h = M.Histogram(bounds=(1.0, 2.0, 4.0))
    assert np.isnan(h.percentile(50))
    h.observe(3.0)
    assert h.percentile(0) == h.percentile(100) == 3.0  # single value: exact
    h.observe(100.0)  # overflow bucket
    assert h.counts[-1] == 1
    assert h.percentile(100) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        M.Histogram(bounds=(2.0, 1.0))


def test_metrics_json_round_trip():
    reg = M.MetricsRegistry()
    reg.counter("reqs").inc(41)
    reg.gauge("occ").set(5.5)
    h = reg.histogram("lat")
    h.observe_many([0.1, 1.0, 12.0, 250.0, 9000.0])
    clone = M.MetricsRegistry.from_json(reg.to_json())
    assert clone.snapshot() == reg.snapshot()
    # and the empty-histogram shape survives too
    reg2 = M.MetricsRegistry()
    reg2.histogram("empty")
    assert M.MetricsRegistry.from_json(reg2.to_json()).snapshot() == reg2.snapshot()


# ---------------------------------------------------------------- profiling


@pytest.fixture(scope="module")
def small_db():
    schema = make_pubmed(n_docs=1500, n_terms=80, n_authors=400, seed=3)
    return GQFastDatabase(schema, account_space=False)


CASES = [
    ("frontier", QUERY_SD, {"d0": 17}),
    ("frontier", QUERY_AD, {"t1": 3, "t2": 7}),  # mask seed + semijoin
    ("fragment_loop", QUERY_SD, {"d0": 17}),     # scalar walk (ops fuse)
    ("fragment_loop", QUERY_AD, {"t1": 3, "t2": 7}),  # frontier fallback
]


@pytest.mark.parametrize("strategy,sql,params", CASES)
def test_profile_bit_identical_to_call(small_db, strategy, sql, params):
    eng = GQFastEngine(small_db, strategy=strategy)
    pq = eng.prepare(sql)
    plain = np.asarray(pq(**params))
    prof = pq.profile(reps=1, **params)
    # the profile result comes from the same compiled executable as __call__
    assert np.array_equal(np.asarray(prof.result), plain)
    assert prof.strategy == strategy
    assert prof.total_wall_ms > 0


def test_profile_distributed_bit_identical(small_db):
    from repro.launch.mesh import make_mesh

    eng = GQFastEngine(small_db, mesh=make_mesh((1,), ("data",)))
    pq = eng.prepare(QUERY_SD)
    plain = np.asarray(pq(d0=17))
    prof = pq.profile(reps=1, d0=17)
    assert np.array_equal(np.asarray(prof.result), plain)
    assert prof.strategy == "distributed"
    assert prof.timing_method == "prefix-delta"
    # prefix-delta times every op (nothing fuses away under shard_map)
    assert all(o.wall_ms is not None for o in prof.ops)


def test_profile_covers_every_ir_op_and_hops(small_db):
    eng = GQFastEngine(small_db, strategy="frontier")
    pq = eng.prepare(QUERY_AS)
    prof = pq.profile(reps=1, a0=5)
    assert len(prof.ops) == len(pq.phys.ops)
    measured = [o for o in prof.ops if not o.fused]
    assert measured, "eager-span walk must time at least the non-fused ops"
    # one HopProfile per hop estimate, with both fractions populated
    assert len(prof.hops) == len(pq.hop_estimates)
    for h in prof.hops:
        assert 0.0 <= h.observed_active_fraction <= 1.0
        assert h.est_active_fraction >= 0.0
    d = json.loads(prof.to_json())
    assert d["strategy"] == "frontier" and d["ops"] and d["hops"]


def test_explain_analyze_renders_timings_and_fractions(small_db):
    eng = GQFastEngine(small_db, strategy="frontier")
    pq = eng.prepare(QUERY_SD)
    plain = pq.explain()
    text = pq.explain(analyze=True, d0=17)
    assert plain in text  # analyze extends, never replaces, the static plan
    assert "analyze: total" in text
    assert "wall" in text and "kernel" in text
    assert "predicted vs observed active fraction" in text
    assert "est=" in text and "obs=" in text


def test_mispredict_classification():
    assert not mispredicted(0.1, 0.15)          # within 2x
    assert mispredicted(0.1, 0.30)              # observed 3x over
    assert mispredicted(0.1, 0.01)              # observed 10x under
    assert not mispredicted(0.0, 0.0)           # both empty: agree
    assert mispredicted(0.0, 0.5)               # predicted none, saw plenty
    assert not mispredicted(0.2, 0.4, factor=2.0)  # boundary is inclusive


def test_per_op_self_walls_sum_to_total(small_db):
    # the eager instrumented walk runs un-jitted, so its raw per-op walls can
    # be orders of magnitude above the compiled total; the profile must
    # rescale them so the self-wall column is consistent with total_wall_ms
    eng = GQFastEngine(small_db, strategy="frontier")
    pq = eng.prepare(QUERY_SD)
    prof = pq.profile(reps=3, d0=17)
    assert prof.timing_method == "eager-span-scaled"
    walls = [o.wall_ms for o in prof.ops if o.wall_ms is not None]
    assert walls, "at least the non-fused ops must carry a self wall"
    assert abs(sum(walls) - prof.total_wall_ms) <= max(
        1e-6 * prof.total_wall_ms, 1e-9
    )
    for o in prof.ops:
        if o.wall_ms is not None:  # raw eager measurement preserved per op
            assert o.meta["eager_wall_ms"] >= 0.0
            assert o.kernel_ms is None or o.kernel_ms <= o.wall_ms + 1e-9


def test_profile_feeds_strategy_calibration(small_db):
    eng = GQFastEngine(small_db, strategy="auto")
    pq = eng.prepare(QUERY_SD)
    assert pq.plan_sig and eng.calibration.get(pq.plan_sig) is None
    prof = pq.profile(reps=1, d0=17)
    obs = eng.calibration.get(pq.plan_sig)
    assert obs == [h.observed_active_fraction for h in prof.hops]
    # the store overrides the fanout model on the next strategy choice
    eng.calibration.record(pq.plan_sig, [0.01])
    assert eng._pick_strategy(pq.plan, pq.plan_sig) == "fragment_loop"
    eng.calibration.record(pq.plan_sig, [0.5])
    assert eng._pick_strategy(pq.plan, pq.plan_sig) == "frontier"


def test_strategy_mispredict_counter_increments(small_db):
    eng = GQFastEngine(small_db, strategy="frontier")
    pq = eng.prepare(QUERY_AD)  # semijoin hop: estimate is the trivial 1.0
    before = M.REGISTRY.counter("strategy_mispredict").value
    prof = pq.profile(reps=1, t1=3, t2=7)
    after = M.REGISTRY.counter("strategy_mispredict").value
    n_mis = sum(1 for h in prof.hops if h.mispredict)
    assert after - before == n_mis


def test_disabled_call_path_untouched(small_db):
    """With no tracer installed, __call__ takes the plain path (no span
    machinery) and execution under recording matches it exactly."""
    eng = GQFastEngine(small_db, strategy="frontier")
    pq = eng.prepare(QUERY_SD)
    plain = np.asarray(pq(d0=9))
    with T.recording() as tr:
        recorded = np.asarray(pq(d0=9))
    assert np.array_equal(plain, recorded)
    names = [s.name for s in tr.iter_spans()]
    assert "execute" in names


def test_prepare_emits_lifecycle_spans(small_db):
    eng = GQFastEngine(small_db, strategy="frontier")
    with T.recording() as tr:
        eng.prepare(QUERY_AS)
    names = [s.name for s in tr.iter_spans()]
    for phase in ("prepare", "parse", "plan", "lower", "compile"):
        assert phase in names, names
    prep = tr.roots[0]
    assert prep.name == "prepare"
    assert [c.name for c in prep.children] == ["parse", "plan", "lower", "compile"]
