"""DIN recsys arch config × the four assigned serving/training shapes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import named, recsys_batch_shardings, recsys_state_shardings
from ..models.din import (
    DINConfig,
    din_forward,
    din_init,
    din_loss,
    din_retrieval_scores,
)
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .base import ArchConfig, Cell


def _pad512(n: int) -> int:
    return -(-n // 512) * 512


DIN_SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, candidates=_pad512(1_000_000), kind="retrieval"),
}


class DINArch(ArchConfig):
    kind = "recsys"
    shape_ids = list(DIN_SHAPES)

    def __init__(self):
        self.arch_id = "din"
        self.full = DINConfig()  # embed_dim 18, seq 100, 80-40 attn, 200-80 mlp
        self.smoke_cfg = DINConfig(n_items=5000, n_users=500, n_cates=50, seq_len=16)
        self.opt = AdamWConfig(lr=1e-3, weight_decay=0.0)

    def make_cell(self, shape_id: str, mesh, variant: str = "") -> Cell:
        sh = DIN_SHAPES[shape_id]
        cfg = self.full
        B, T = sh["batch"], cfg.seq_len
        f32, i32 = jnp.float32, jnp.int32
        params_abs = jax.eval_shape(lambda: din_init(cfg, jax.random.key(0)))

        if sh["kind"] == "train":
            batch_abs = {
                "user": jax.ShapeDtypeStruct((B,), i32),
                "hist_items": jax.ShapeDtypeStruct((B, T), i32),
                "hist_mask": jax.ShapeDtypeStruct((B, T), f32),
                "cand_item": jax.ShapeDtypeStruct((B,), i32),
                "label": jax.ShapeDtypeStruct((B,), i32),
            }
            opt_abs = jax.eval_shape(functools.partial(adamw_init, cfg=self.opt), params_abs)
            state_abs = (params_abs, opt_abs)

            def fn(state, batch):
                params, opt_state = state
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: din_loss(p, batch, cfg), has_aux=True
                )(params)
                params, opt_state, om = adamw_update(grads, opt_state, params, self.opt)
                return (params, opt_state), {**metrics, **om}

            return Cell(self.arch_id, shape_id, fn, (state_abs, batch_abs),
                        (recsys_state_shardings(state_abs, mesh),
                         recsys_batch_shardings(batch_abs, mesh)),
                        None, "train", 6.0 * cfg.active_param_count() * B)

        if sh["kind"] == "serve":
            batch_abs = {
                "user": jax.ShapeDtypeStruct((B,), i32),
                "hist_items": jax.ShapeDtypeStruct((B, T), i32),
                "hist_mask": jax.ShapeDtypeStruct((B, T), f32),
                "cand_item": jax.ShapeDtypeStruct((B,), i32),
            }

            def fn(params, batch):
                return din_forward(params, batch, cfg)

            return Cell(self.arch_id, shape_id, fn, (params_abs, batch_abs),
                        (recsys_state_shardings(params_abs, mesh),
                         recsys_batch_shardings(batch_abs, mesh)),
                        None, "serve", 2.0 * cfg.active_param_count() * B)

        NC = sh["candidates"]
        batch_abs = {
            "user": jax.ShapeDtypeStruct((1,), i32),
            "hist_items": jax.ShapeDtypeStruct((1, T), i32),
            "hist_mask": jax.ShapeDtypeStruct((1, T), f32),
            "cand_items": jax.ShapeDtypeStruct((NC,), i32),
        }

        def fn(params, batch):
            return din_retrieval_scores(params, batch, cfg)

        return Cell(self.arch_id, shape_id, fn, (params_abs, batch_abs),
                    (recsys_state_shardings(params_abs, mesh),
                     recsys_batch_shardings(batch_abs, mesh)),
                    None, "serve", 2.0 * cfg.active_param_count() * NC)

    def smoke(self) -> dict:
        from ..data.recsys import make_din_batch

        cfg = self.smoke_cfg
        params = din_init(cfg, jax.random.key(0))
        b = make_din_batch(16, seq_len=cfg.seq_len, n_items=cfg.n_items, n_users=cfg.n_users)
        opt = adamw_init(params, self.opt)
        (loss, _), grads = jax.value_and_grad(
            lambda p: din_loss(p, b, cfg), has_aux=True
        )(params)
        params2, _, om = adamw_update(grads, opt, params, self.opt)
        rb = make_din_batch(1, seq_len=cfg.seq_len, n_items=cfg.n_items,
                            n_users=cfg.n_users, n_candidates=256)
        scores = din_retrieval_scores(params, rb, cfg)
        return {
            "loss": float(loss),
            "scores_shape": tuple(scores.shape),
            "finite": bool(jnp.isfinite(loss)) and bool(jnp.isfinite(scores).all())
            and all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(params2)),
        }


DIN = DINArch()
