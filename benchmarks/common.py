"""Shared benchmark infra: timing, cached datasets, CSV emission."""
from __future__ import annotations

import functools
import time

import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time (s) over warm runs (paper §7: warm, averaged)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# structured record sink mirroring the CSV stream — benchmarks/run.py drains
# it into BENCH_<suite>.json artifacts for the CI perf trajectory
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "", **extra) -> None:
    """Print one CSV row and record it (plus any structured ``extra`` fields,
    e.g. ``device_bytes=...``) for the JSON artifact."""
    print(f"{name},{us_per_call:.1f},{derived}")
    RECORDS.append(
        {"name": name, "us_per_call": round(float(us_per_call), 1),
         "derived": derived, **extra}
    )


# trace summaries keyed by record name — benchmarks/run.py embeds them into
# the suite's BENCH_perf.json entry under "traces"
TRACES: dict[str, dict] = {}


def emit_trace(name: str, summary: dict) -> None:
    """Attach an observability summary (per-phase / per-op timings from a
    ``QueryProfile``) to the named benchmark record in the JSON artifact."""
    TRACES[name] = summary


@functools.lru_cache(maxsize=None)
def pubmed_m():
    """PubMed-M-like: high Term fanout (MeSH-only regime)."""
    from repro.data.synth_graph import make_pubmed

    return make_pubmed(n_docs=20_000, n_terms=1_000, n_authors=6_000,
                       avg_terms_per_doc=6.0, avg_authors_per_doc=3.0,
                       zipf_term=1.1, seed=11)


@functools.lru_cache(maxsize=None)
def pubmed_ms():
    """PubMed-MS-like: supplemental terms → larger Term domain, lower fanout."""
    from repro.data.synth_graph import make_pubmed

    return make_pubmed(n_docs=20_000, n_terms=12_000, n_authors=6_000,
                       avg_terms_per_doc=8.0, avg_authors_per_doc=3.0,
                       zipf_term=1.05, seed=12)


@functools.lru_cache(maxsize=None)
def semmeddb():
    from repro.data.synth_graph import make_semmeddb

    return make_semmeddb(n_concepts=5_000, n_csemtypes=6_000,
                         n_predications=10_000, n_sentences=40_000, seed=13)


@functools.lru_cache(maxsize=None)
def gqfast_db(which: str):
    from repro.core.engine import GQFastDatabase

    schema = {"m": pubmed_m, "ms": pubmed_ms, "sem": semmeddb}[which]()
    return GQFastDatabase(schema, account_space=True, keep_packed=True)
