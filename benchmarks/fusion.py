"""Pipelined fusion sweep: fused 2-hop regions vs the unfused composition.

DESIGN.md §Pipelined fusion: a fused region executes hop1 → in-register mask →
hop2 in ONE kernel pass, the intermediate frontier resident in VMEM scratch —
the unfused composition materialises that frontier to HBM, reads it back for
hop2, and pays a second dispatch. This suite sweeps a 2-hop chain whose first
hop preserves source locality over seed selectivity 10⁻³ … 10⁻¹, both sides
running ``block_skipping='auto'`` so the delta is fusion alone.

What is gated (CI fast lane goes red on violation):

  * ``bit_identical`` everywhere — the fused kernel applies the same ⊕ in the
    same block order, so results must agree EXACTLY;
  * ``speedup_hbm_model`` ≥ ``MIN_SPEEDUP_SELECTIVE`` wherever s ≤ 1e-2: the
    fused-vs-unfused ratio of HBM bytes each path actually moves, counted
    from the block lists the dispatchers really plan (roofline §: the hop
    kernels are bandwidth-bound, ~0 FLOPs/byte, so on the TPU target the
    byte ratio IS the speedup). The count charges fused honestly for its
    reach-derived hop2 superset (it streams MORE edge blocks than the
    support-planned unfused hop2) and undercounts unfused by ignoring its
    separate mask-op traffic — the gate is a floor.

Wall-clock on this CPU interpret backend is emitted per row
(``wall_speedup``) but NOT gated: interpret cost is per-operand-per-step
bookkeeping, so a fused step carrying both hops' operand sets costs ~2× an
unfused step regardless of how little it computes — the exact inverse of the
HBM economics the kernel is built for. (The selectivity suite CAN gate wall
because eager bucketing shrinks step counts for both sides of its
comparison.) At s = 0.1 the edge streams dominate both paths and the model
ratio collapses toward 1× — that row is informational, showing the regime
boundary.
"""
from __future__ import annotations

import numpy as np

from .common import emit, timeit

SELECTIVITIES = (1e-3, 1e-2, 1e-1)
MIN_SPEEDUP_SELECTIVE = 1.3

N0, DEG1 = 131_072, 8   # hop1: E1 = 1,048,576 → 256 edge blocks
N1, DEG2 = 131_072, 4   # hop2: E2 =   524,288 → 128 edge blocks
N2 = 8_192
LOCALITY = 2_048        # hop1 dst stays within ±LOCALITY of its source
BATCH = 8

#: streamed bytes per edge block: src + dst int32 + dense f32 measure
BLOCK_BYTES = 4096 * 12
F4 = 4  # f32 vector element


def _chain(seed: int = 21):
    """2-hop chain E0→E1→E2; hop1 locality-preserving so a narrow seed
    support reaches a narrow band of hop2 blocks (reach-matrix pruning)."""
    rng = np.random.default_rng(seed)
    src1 = np.repeat(np.arange(N0, dtype=np.int32), DEG1)
    dst1 = np.clip(
        src1 + rng.integers(-LOCALITY, LOCALITY + 1, src1.shape[0]), 0, N1 - 1
    ).astype(np.int32)
    m1 = rng.random(src1.shape[0]).astype(np.float32)
    src2 = np.repeat(np.arange(N1, dtype=np.int32), DEG2)
    dst2 = rng.integers(0, N2, src2.shape[0]).astype(np.int32)
    m2 = rng.random(src2.shape[0]).astype(np.float32)
    mask = (rng.random(N1) < 0.8).astype(np.float32)
    return src1, dst1, m1, src2, dst2, m2, mask


def _frontier(selectivity: float) -> np.ndarray:
    k = max(1, round(selectivity * N0))
    w = np.zeros(N0, np.float32)
    w[:k] = 1.0
    return w


def _planned_blocks(support: np.ndarray, blocks) -> int:
    """Streamed block count for one unfused hop the way the eager dispatcher
    plans it: per-block activity from the support cumsum, bucketed to the
    fixed capacity the active kernel pads to (padded steps re-stream a
    clamped block on hardware, so they count)."""
    from repro.kernels import active

    smin, smax = np.asarray(blocks[0]), np.asarray(blocks[1])
    nb = smin.shape[0]
    cs = np.concatenate([np.zeros(1, np.int64), np.cumsum(support.astype(np.int64))])
    flags = cs[smax + 1] > cs[smin]
    frac = flags.sum() / nb
    if frac > active.SKIP_BLOCK_FRACTION:
        return nb  # auto planner falls back to the full scan
    return active.bucket_capacity(int(flags.sum()), nb)


def run() -> None:
    from repro.kernels import active, ops
    from repro.kernels.params import EDGE_BLOCK

    src1, dst1, m1, src2, dst2, m2, mask = _chain()
    b1 = active.block_ranges(src1)
    b2 = active.block_ranges(src2)
    nb1 = active.n_edge_blocks(src1.shape[0])
    # reach[b1, b2]: does hop1 block b1 write any mid id inside hop2 block b2
    smin2, smax2 = np.asarray(b2[0]), np.asarray(b2[1])
    reach = np.zeros((nb1, smin2.shape[0]), bool)
    for i in range(nb1):
        vals = dst1[i * EDGE_BLOCK:(i + 1) * EDGE_BLOCK]
        reach[i] = (vals.min() <= smax2) & (vals.max() >= smin2)
    h1 = ops.FusedHopOperands(src1, dst1, m1, None, N1, m_mode="dense",
                              blocks=b1)
    h2 = ops.FusedHopOperands(src2, dst2, m2, None, N2, m_mode="dense",
                              blocks=b2, reach=reach)
    failures: list[str] = []

    def hbm_bytes(w: np.ndarray, batch: int):
        """(unfused_bytes, fused_bytes, counts) for one execution, from the
        block lists both dispatchers actually plan for this frontier."""
        c1 = _planned_blocks(np.asarray(w != 0).any(0) if w.ndim == 2 else w != 0, b1)
        # unfused hop2 plans from the REALIZED masked intermediate — run the
        # real hop1 kernel to get it, exactly like _compose_unfused
        u = np.asarray(ops.fragment_spmv_packed(
            w if w.ndim == 1 else w.any(0).astype(np.float32),
            src1, dst1, m1, None, n_dst=N1, m_mode="dense", op="sum",
            blocks=b1, block_skipping="auto"))
        u = np.where(mask > 0, u, 0.0)
        c2_un = _planned_blocks(u != 0, b2)
        # fused hop2 list: the reach superset the fused dispatch streams
        bi1, na1, bi2, na2 = ops._fused_block_lists(
            w, "sum", h1, h2, src1.shape[0], src2.shape[0], "auto")
        c1_fu, c2_fu = int(bi1.shape[0]), int(bi2.shape[0])
        unfused = (
            batch * N0 * F4            # frontier read
            + c1 * BLOCK_BYTES         # hop1 edge streams
            + 2 * batch * N1 * F4      # intermediate u: HBM write + read back
            + c2_un * BLOCK_BYTES      # hop2 edge streams
            + batch * N2 * F4          # output write
        )
        fused = (
            batch * N0 * F4
            + c1_fu * BLOCK_BYTES
            + c2_fu * BLOCK_BYTES      # reach superset: ≥ c2_un
            + batch * N2 * F4          # u never leaves VMEM
        )
        return unfused, fused, (c1, c2_un, c1_fu, c2_fu)

    def check(tag: str, unfused_fn, fused_fn, w, selectivity: float,
              batch: int, gated: bool):
        want = np.asarray(unfused_fn())
        got = np.asarray(fused_fn())
        bit = bool(np.array_equal(want, got))
        t_un = timeit(lambda: unfused_fn().block_until_ready())
        t_fu = timeit(lambda: fused_fn().block_until_ready())
        ub, fb, counts = hbm_bytes(w, batch)
        model = ub / fb
        wall = t_un / t_fu
        emit(
            f"fusion/{tag}/s={selectivity:g}",
            t_fu * 1e6,
            f"model={model:.2f}x,wall={wall:.2f}x",
            selectivity=selectivity,
            unfused_us=round(t_un * 1e6, 1),
            fused_us=round(t_fu * 1e6, 1),
            wall_speedup=round(wall, 2),
            unfused_hbm_bytes=ub,
            fused_hbm_bytes=fb,
            speedup_hbm_model=round(model, 2),
            blocks_hop1=counts[0], blocks_hop2_unfused=counts[1],
            blocks_hop1_fused=counts[2], blocks_hop2_fused=counts[3],
            bit_identical=bit,
        )
        if not bit:
            failures.append(f"{tag} s={selectivity:g}: fused != unfused")
        if gated and model < MIN_SPEEDUP_SELECTIVE:
            failures.append(
                f"{tag} hbm-model speedup {model:.2f}x at s={selectivity:g} "
                f"(gate {MIN_SPEEDUP_SELECTIVE}x)"
            )

    for s in SELECTIVITIES:
        w = _frontier(s)
        check(
            "spmv",
            lambda: ops.fragment_spmv_fused(
                w, h1, h2, mask, op="sum", fusion="off",
                block_skipping="auto"),
            lambda: ops.fragment_spmv_fused(
                w, h1, h2, mask, op="sum", fusion="on",
                block_skipping="auto"),
            w, s, batch=1, gated=s <= 1e-2,
        )

    # batched SpMM: B staggered seeds share one fused pass; the intermediate
    # the unfused path round-trips is [B, n_mid], so pipelining pays B-fold
    W = np.stack([np.roll(_frontier(1e-2), i * N0 // BATCH)
                  for i in range(BATCH)])
    check(
        "spmm",
        lambda: ops.fragment_spmm_fused(
            W, h1, h2, mask, op="sum", fusion="off", block_skipping="auto"),
        lambda: ops.fragment_spmm_fused(
            W, h1, h2, mask, op="sum", fusion="on", block_skipping="auto"),
        W, 1e-2, batch=BATCH, gated=True,
    )

    if failures:
        raise RuntimeError("fusion gates failed: " + "; ".join(failures))
