"""Pallas TPU kernel: decode-fused fragment join-aggregate (paper §5-6).

The packed-aware variant of :mod:`.fragment_spmv`: ``dst_ids`` and/or the
measure column arrive as BCA bit-packed uint32 word streams and are decoded
*inside* the SpMV edge-block loop — the fused-decompression design that is
GQ-Fast's headline result. Per 4096-edge grid step the kernel pulls
``EDGE_BLOCK·width/32`` words into VMEM, runs the static-column-select group
decode (:func:`.bitunpack.decode_groups`), and feeds the decoded block straight
into gather ⊗ measure → scatter-⊕. The decoded columns are never materialized
in HBM; device memory holds the packed words only.

Block geometry comes from :mod:`.params` (the single source of truth):
EDGE_BLOCK = 4096 = 4·1024 values, so every block is word-aligned for any
width (1024·width ≡ 0 mod 32) and the packed input block is exactly
(EDGE_BLOCK/32, width) words — a static BlockSpec, no halo.

Measure modes (static config):
  * ``none``   — no measure operand; ⊗-factor 1 (COUNT/EXISTS hops).
  * ``dense``  — float32 edge stream, as in the unpacked kernel (used when the
    measure expression is not a single packed column).
  * ``packed`` — BCA words; decoded ints are the measure values.
  * ``dict``   — BCA words of dictionary indices + a VMEM-resident dictionary
    (the DictBCA/Huffman-substitute decode: unpack + one small gather).

Identical per-block math and combine order as the unpacked kernel, so results
are bit-identical to the decoded path.

Padding: ``src`` pads past the frontier (gather fills the ⊕-identity, which
zeroes the edge product under every op); packed streams pad with zero words —
trailing bits of a partial word are already zero in the `_pack_words` layout,
so padding values decode to 0 and land on dst 0 with identity weight.

:func:`fragment_spmv_packed_active` is the frontier-sparsity variant
(kernels/active.py): the surviving-block list rides in SMEM via
``pltpu.PrefetchScalarGridSpec`` and drives every stream's ``index_map``, so
only active blocks are DMA'd *or decoded* — skipping saves the BCA unpack work
too. The operand layout (:func:`_packed_operands`) and per-block decode
(:func:`_decode_block`) are shared across the scan/active × SpMV/SpMM packed
kernels so the four paths cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitunpack import GROUP, decode_groups
from .fragment_spmv import (
    IDENTITY,
    _combine,
    _edge_product,
    _segment_combine,
)
from .params import EDGE_BLOCK

GROUPS_PER_EDGE_BLOCK = EDGE_BLOCK // GROUP  # 128 groups of 32 values


def _decode_block(dst_width: int, m_mode: str, m_width: int, dst_ref, rest):
    """One edge block's (dst, measure) from the refs, decoding packed streams
    in VMEM. Shared by all four packed kernel bodies (scan/active × SpMV/SpMM)
    so the mode dispatch cannot drift between them."""
    if dst_width:
        dst = decode_groups(dst_ref[...], dst_width).reshape(-1)
    else:
        dst = dst_ref[...]
    if m_mode == "none":
        m = jnp.ones(EDGE_BLOCK, jnp.float32)
    elif m_mode == "dense":
        m = rest[0][...]
    else:
        idx = decode_groups(rest[0][...], m_width).reshape(-1)
        if m_mode == "dict":
            m = jnp.take(rest[1][...], idx)
        else:
            m = idx.astype(jnp.float32)
    return dst, m


def _kernel(n_dst: int, op: str, dst_width: int, m_mode: str, m_width: int, *refs):
    w_ref, src_ref, dst_ref, *rest, out_ref = refs

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    dst, m = _decode_block(dst_width, m_mode, m_width, dst_ref, rest)
    prod = _edge_product(w_ref[...], src_ref[...], m, op)
    blk = _segment_combine(prod, dst, n_dst, op)
    out_ref[...] = _combine(out_ref[...], blk, op)


def _block_words(words: jnp.ndarray, width: int, n_blocks: int) -> jnp.ndarray:
    """Zero-pad the word stream to whole edge blocks and shape it (G, width)."""
    need = n_blocks * GROUPS_PER_EDGE_BLOCK * width
    if words.shape[0] < need:
        words = jnp.concatenate([words, jnp.zeros(need - words.shape[0], jnp.uint32)])
    return words[:need].reshape(n_blocks * GROUPS_PER_EDGE_BLOCK, width)


def _packed_operands(
    weights, src_ids, dst, measure, mdict,
    dst_width: int, m_mode: str, m_width: int, n_blocks: int, pad: int,
    n_src: int | None = None,
):
    """Operand list + spec kinds for the packed kernels, shared by the scan and
    active variants of both the SpMV and the SpMM. Kinds: ``('resident',
    block_shape)`` (whole array, every grid step) | ``'edge'`` (EDGE_BLOCK
    stream) | ``('words', width)`` (packed word stream, (G, width) blocks).

    ``weights=None`` builds the operand set for a fused region's *second* hop
    (:mod:`.fragment_spmv_fused`), whose frontier lives in a VMEM scratch
    buffer rather than an input — ``n_src`` must then be given so the src
    padding still lands one past the frontier (⊕-identity under the gather's
    fill_value)."""
    if n_src is None:
        n_src = weights.shape[-1]
    if pad:
        src_ids = jnp.concatenate([src_ids, jnp.full(pad, n_src, jnp.int32)])
    if weights is None:
        operands, kinds = [src_ids], ["edge"]
    else:
        operands = [weights, src_ids]
        kinds = [("resident", weights.shape), "edge"]
    if dst_width:
        operands.append(_block_words(dst, dst_width, n_blocks))
        kinds.append(("words", dst_width))
    else:
        if pad:
            dst = jnp.concatenate([dst, jnp.zeros(pad, jnp.int32)])
        operands.append(dst)
        kinds.append("edge")
    if m_mode == "dense":
        if pad:
            measure = jnp.concatenate([measure, jnp.zeros(pad, jnp.float32)])
        operands.append(measure)
        kinds.append("edge")
    elif m_mode in ("packed", "dict"):
        operands.append(_block_words(measure, m_width, n_blocks))
        kinds.append(("words", m_width))
        if m_mode == "dict":
            operands.append(mdict)
            kinds.append(("resident", mdict.shape))
    elif m_mode != "none":
        raise ValueError(f"unknown measure mode {m_mode!r}")
    return operands, kinds


def _scan_specs(kinds) -> list[pl.BlockSpec]:
    """BlockSpecs for the sequential scan: grid step i streams block i."""
    specs = []
    for k in kinds:
        if k == "edge":
            specs.append(pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)))
        elif k[0] == "resident":
            shape = k[1]
            specs.append(
                pl.BlockSpec(shape, lambda i, _z=(0,) * len(shape): _z)
            )
        else:  # ('words', width)
            specs.append(
                pl.BlockSpec((GROUPS_PER_EDGE_BLOCK, k[1]), lambda i: (i, 0))
            )
    return specs


def _active_specs(kinds) -> list[pl.BlockSpec]:
    """BlockSpecs for the active-block variant: the SMEM-prefetched block list
    (``bi``) drives every stream's index map — grid step i fetches block
    ``bi[i]``; resident operands ignore it."""
    specs = []
    for k in kinds:
        if k == "edge":
            specs.append(pl.BlockSpec((EDGE_BLOCK,), lambda i, na, bi: (bi[i],)))
        elif k[0] == "resident":
            shape = k[1]
            specs.append(
                pl.BlockSpec(shape, lambda i, na, bi, _z=(0,) * len(shape): _z)
            )
        else:  # ('words', width)
            specs.append(
                pl.BlockSpec(
                    (GROUPS_PER_EDGE_BLOCK, k[1]), lambda i, na, bi: (bi[i], 0)
                )
            )
    return specs


@functools.partial(
    jax.jit,
    static_argnames=("n_dst", "op", "dst_width", "m_mode", "m_width", "interpret"),
)
def fragment_spmv_packed(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst: jnp.ndarray,  # uint32 words if dst_width else int32[E]
    measure: jnp.ndarray | None,  # uint32 words | f32[E] | None, per m_mode
    mdict: jnp.ndarray | None,  # f32[u] dictionary, m_mode == 'dict' only
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    E = src_ids.shape[0]
    if E == 0:  # empty relation: no edge contributes, everything is ⊕-identity
        return jnp.full((n_dst,), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)
    operands, kinds = _packed_operands(
        weights, src_ids, dst, measure, mdict,
        dst_width, m_mode, m_width, n_blocks, pad,
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_dst, op, dst_width, m_mode, m_width),
        grid=(n_blocks,),
        in_specs=_scan_specs(kinds),
        out_specs=pl.BlockSpec((n_dst,), lambda i: (0,)),  # accumulate over grid
        out_shape=jax.ShapeDtypeStruct((n_dst,), jnp.float32),
        interpret=interpret,
    )(*operands)


def _kernel_active(
    n_dst: int, op: str, dst_width: int, m_mode: str, m_width: int, *refs
):
    na_ref, bi_ref, w_ref, src_ref, dst_ref, *rest, out_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[op])

    @pl.when(i < na_ref[0])
    def _compute():
        dst, m = _decode_block(dst_width, m_mode, m_width, dst_ref, rest)
        prod = _edge_product(w_ref[...], src_ref[...], m, op)
        blk = _segment_combine(prod, dst, n_dst, op)
        out_ref[...] = _combine(out_ref[...], blk, op)


@functools.partial(
    jax.jit,
    static_argnames=("n_dst", "op", "dst_width", "m_mode", "m_width", "interpret"),
)
def fragment_spmv_packed_active(
    weights: jnp.ndarray,
    src_ids: jnp.ndarray,
    dst: jnp.ndarray,
    measure: jnp.ndarray | None,
    mdict: jnp.ndarray | None,
    block_idx: jnp.ndarray,  # int32[C] — surviving block ids
    n_active: jnp.ndarray,  # int32[1]
    n_dst: int,
    dst_width: int = 0,
    m_mode: str = "none",
    m_width: int = 0,
    op: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    """Frontier-sparsity decode-fused SpMV: only surviving blocks are DMA'd
    and decoded. Same operand layout and per-block math as
    :func:`fragment_spmv_packed` → bit-identical results."""
    if op not in IDENTITY:
        raise ValueError(f"unknown combine op {op!r}")
    E = src_ids.shape[0]
    if E == 0:
        return jnp.full((n_dst,), IDENTITY[op], jnp.float32)
    pad = (-E) % EDGE_BLOCK
    n_blocks = max(1, (E + pad) // EDGE_BLOCK)
    operands, kinds = _packed_operands(
        weights, src_ids, dst, measure, mdict,
        dst_width, m_mode, m_width, n_blocks, pad,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(block_idx.shape[0],),
        in_specs=_active_specs(kinds),
        out_specs=pl.BlockSpec((n_dst,), lambda i, na, bi: (0,)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_active, n_dst, op, dst_width, m_mode, m_width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst,), jnp.float32),
        interpret=interpret,
    )(n_active, block_idx, *operands)
