"""repro: GQ-Fast (Fast In-Memory SQL Analytics on Graphs) on JAX/TPU."""
__version__ = "0.1.0"
