"""Architecture registry: --arch <id> → ArchConfig (the 10 assigned + the
paper's own workload)."""
from __future__ import annotations

from .base import ArchConfig
from .din_arch import DIN
from .gnn_family import EGNN, EQUIFORMER_V2, MACE, SCHNET
from .gqfast_arch import GQFAST
from .lm_archs import ARCTIC_480B, CODEQWEN15_7B, LLAMA3_8B, OLMOE_1B_7B, QWEN25_3B

ARCHS: dict[str, ArchConfig] = {
    "codeqwen1.5-7b": CODEQWEN15_7B,
    "qwen2.5-3b": QWEN25_3B,
    "llama3-8b": LLAMA3_8B,
    "arctic-480b": ARCTIC_480B,
    "olmoe-1b-7b": OLMOE_1B_7B,
    "mace": MACE,
    "egnn": EGNN,
    "equiformer-v2": EQUIFORMER_V2,
    "schnet": SCHNET,
    "din": DIN,
    "gqfast-pubmed": GQFAST,
}

ASSIGNED = [a for a in ARCHS if a != "gqfast-pubmed"]


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id}; available: {list(ARCHS)}")
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    out = []
    for aid, arch in ARCHS.items():
        for sid in arch.shape_ids:
            out.append((aid, sid))
    return out
