"""Checksummed, generation-stamped database snapshots (DESIGN.md §Durability).

The engine rebuilds its §5 data organization — fragment indexes plus the
compressed device column store — from raw tables on every process start.
This module makes that state durable and *verifiable*:

  * :func:`snapshot_db` persists a ``GQFastDatabase`` as ``gen_<n>/`` under a
    snapshot directory: one ``.npy`` file per logical array plus a
    ``MANIFEST.json`` carrying a CRC32C per array, the schema/layout
    metadata, and the per-column integrity digests
    (``storage/integrity.py``). Device columns are written as their
    *encoded* bytes (packed BCA words, dictionaries, dense arrays) so
    restore round-trips without re-encoding — the snapshot is the wire
    layout, not a logical dump. Publication is crash-safe via the shared
    atomic writer (``ckpt/atomic.py``): a generation is either fully visible
    with fsynced contents or absent.

  * :func:`restore_db` loads a generation, verifies **every** array file
    against its manifest CRC (and the rebuilt device columns against their
    encoded digests) *before* the database is handed to the engine, and
    raises a typed, non-retryable
    :class:`~repro.robust.errors.IntegrityError` naming the offending
    table/column on any mismatch — a corrupted snapshot never serves data.
    The restored DB carries its integrity manifest, so verified reads and
    the scrubber (robust/scrub.py) work out of the box.

Layout::

    <dir>/gen_0000000042/
        MANIFEST.json            # format, generation, schema, arrays, digests
        arrays/a00000.npy …      # one file per logical array (manifest maps
                                 # logical name → file + crc32c/dtype/shape)

Logical array names: ``host/<t>.<k>/indptr``, ``host/<t>.<k>/<col>/values``
(+``/packed``), ``dev/<t>.<k>/<col>/{array|words|dict}``,
``dev/<t>.<k>/block_src_{min,max}``, ``attr/<entity>/<name>``. Derivable
arrays (CSR ``src_ids``, ``degrees``) are rebuilt from ``indptr`` on restore
rather than stored. Relationship-table rows are reconstructed from the
fk1-direction index, so restored raw tables are in (fk1, fk2)-sorted order —
relationally identical to the originals (aggregation is order-independent),
not byte-identical row order.

Fault site ``snapshot.load`` (robust/faults.py): ``raise``/``delay`` fire at
restore entry; ``corrupt`` transforms each loaded array *before* checksum
verification, so chaos plans can prove restore-time corruption is caught.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any

import numpy as np

from ..ckpt.atomic import list_stamped, publish_dir, retain_stamped, stamped_name
from ..robust import faults as _faults
from ..robust.errors import IntegrityError
from .columns import DenseColumn, DictPackedColumn, PackedColumn
from .integrity import (
    attach_manifest,
    build_manifest,
    crc32c,
    crc32c_parts,
    encoded_parts,
)

#: Manifest format version — bump on layout changes; restore refuses formats
#: it does not understand rather than misreading them.
FORMAT = 1

GEN_PREFIX = "gen_"
MANIFEST = "MANIFEST.json"
ARRAY_DIR = "arrays"


def list_generations(directory: str) -> list[int]:
    return list_stamped(directory, GEN_PREFIX)


def latest_generation(directory: str) -> int | None:
    gens = list_generations(directory)
    return gens[-1] if gens else None


def generation_path(directory: str, generation: int) -> str:
    return os.path.join(directory, stamped_name(GEN_PREFIX, generation))


# ---------------------------------------------------------------------------
# Snapshot (write)
# ---------------------------------------------------------------------------


def _device_column_arrays(col) -> dict[str, np.ndarray]:
    """The encoded device arrays of one column keyed by their role — written
    to disk exactly as stored, the no-re-encoding contract."""
    if isinstance(col, DenseColumn):
        return {"array": np.asarray(col.array)}
    if isinstance(col, DictPackedColumn):
        return {"words": np.asarray(col.words), "dict": np.asarray(col.dictionary)}
    if isinstance(col, PackedColumn):
        return {"words": np.asarray(col.words)}
    raise TypeError(f"not a device column: {type(col).__name__}")


def _collect(db) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Flatten ``db`` into (logical-name → host array, schema/layout meta)."""
    arrays: dict[str, np.ndarray] = {}
    indexes_meta: dict[str, Any] = {}
    for (t, k), idx in db.host_indexes.items():
        iid = f"{t}.{k}"
        arrays[f"host/{iid}/indptr"] = np.asarray(idx.indptr)
        cols_meta: dict[str, Any] = {}
        for c, cf in idx.columns.items():
            arrays[f"host/{iid}/{c}/values"] = np.asarray(cf.values)
            if cf.packed is not None:
                arrays[f"host/{iid}/{c}/packed"] = np.asarray(cf.packed)
            cols_meta[c] = {
                "domain": int(cf.domain),
                "encoding": cf.encoding,
                "encoded_bytes": int(cf.encoded_bytes),
                "packed_width": int(cf.packed_width),
                "has_packed": cf.packed is not None,
            }
        di = db.device.indexes[(t, k)]
        dev_meta: dict[str, Any] = {}
        for name, col in [("__dst__", di.dst_col), *di.measure_cols.items()]:
            for role, arr in _device_column_arrays(col).items():
                arrays[f"dev/{iid}/{name}/{role}"] = arr
            if isinstance(col, DenseColumn):
                odt = col.array.dtype
            elif isinstance(col, DictPackedColumn):
                odt = col.dictionary.dtype
            else:
                odt = col.out_dtype
            dev_meta[name] = {
                "kind": col.kind,
                "count": int(col.count),
                "width": int(getattr(col, "width", 0)),
                "out_dtype": np.dtype(odt).name,
            }
        if di.block_src_min is not None:
            arrays[f"dev/{iid}/block_src_min"] = np.asarray(di.block_src_min)
            arrays[f"dev/{iid}/block_src_max"] = np.asarray(di.block_src_max)
        indexes_meta[iid] = {
            "table": t, "key": k, "key_entity": idx.key_entity,
            "num_edges": int(idx.num_edges),
            "columns": cols_meta, "device": dev_meta,
        }
    for e in db.schema.entities.values():
        for a, col in e.attributes.items():
            arrays[f"attr/{e.name}/{a}"] = np.asarray(col)
    schema_meta = {
        "entities": {
            e.name: {"size": int(e.size), "attributes": sorted(e.attributes)}
            for e in db.schema.entities.values()
        },
        "relationships": {
            r.name: {
                "fk1": r.fk1, "fk2": r.fk2,
                "entity1": r.entity1, "entity2": r.entity2,
                "measures": list(r.measures),
            }
            for r in db.schema.relationships.values()
        },
    }
    return arrays, {"schema": schema_meta, "indexes": indexes_meta}


def snapshot_db(db, directory: str, keep: int | None = None) -> str:
    """Persist ``db`` as the next generation under ``directory`` and return
    the published path. ``keep`` ages out all but the newest ``keep``
    generations (None: keep everything). Atomic: a crash mid-write leaves no
    partially visible generation."""
    arrays, meta = _collect(db)
    generation = (latest_generation(directory) or 0) + 1
    manifest: dict[str, Any] = {
        "format": FORMAT,
        "generation": generation,
        "created": time.time(),
        **meta,
        "integrity": getattr(db.device, "integrity", None) or build_manifest(db.device),
        "arrays": {},
    }
    width = max(5, int(math.ceil(math.log10(max(len(arrays), 2)))))
    for i, name in enumerate(sorted(arrays)):
        arr = arrays[name]
        manifest["arrays"][name] = {
            "file": f"a{i:0{width}d}.npy",
            "crc32c": crc32c(arr),
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "nbytes": int(arr.nbytes),
        }

    def write(tmp: str) -> None:
        adir = os.path.join(tmp, ARRAY_DIR)
        os.makedirs(adir)
        for name, spec in manifest["arrays"].items():
            np.save(os.path.join(adir, spec["file"]), arrays[name])
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)

    final = publish_dir(generation_path(directory, generation), write,
                        tmp_prefix=".tmp_snap_")
    if keep is not None:
        retain_stamped(directory, GEN_PREFIX, keep)
    return final


# ---------------------------------------------------------------------------
# Restore (read + verify)
# ---------------------------------------------------------------------------


def _name_context(name: str) -> dict[str, Any]:
    """Best-effort (table, key, column) context parsed from a logical array
    name — what the IntegrityError carries so operators know *which* column
    went bad, not just which file."""
    parts = name.split("/")
    ctx: dict[str, Any] = {"array": name}
    if len(parts) >= 2 and parts[0] in ("host", "dev") and "." in parts[1]:
        t, k = parts[1].split(".", 1)
        ctx["table"], ctx["key"] = t, k
        if len(parts) >= 3:
            ctx["column"] = parts[2]
    elif len(parts) == 3 and parts[0] == "attr":
        ctx["table"], ctx["column"] = parts[1], parts[2]
    return ctx


def read_manifest(gen_path: str) -> dict[str, Any]:
    mpath = os.path.join(gen_path, MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise
    except Exception as e:  # noqa: BLE001 — truncated/garbled JSON
        raise IntegrityError(
            f"snapshot manifest unreadable: {e}", path=mpath,
        ) from e
    if manifest.get("format") != FORMAT:
        raise IntegrityError(
            f"snapshot format {manifest.get('format')!r} not supported "
            f"(expected {FORMAT})", path=mpath, format=manifest.get("format"),
        )
    return manifest


def _load_array(gen_path: str, name: str, spec: dict[str, Any],
                generation: int, fault_site: str | None) -> np.ndarray:
    """Load + verify one array file. Any deviation — unreadable file, wrong
    dtype/shape (a flipped header byte), data bytes off-digest (a flipped
    payload byte) — raises IntegrityError; corrupted snapshots never return
    data."""
    path = os.path.join(gen_path, ARRAY_DIR, spec["file"])
    try:
        arr = np.load(path)
    except Exception as e:  # noqa: BLE001 — np.load raises a zoo of types
        raise IntegrityError(
            f"snapshot array {name!r} unreadable: {e}",
            path=path, generation=generation, **_name_context(name),
        ) from e
    if fault_site is not None:
        arr = _faults.corrupt(fault_site, arr)
    if str(arr.dtype) != spec["dtype"] or list(arr.shape) != spec["shape"]:
        raise IntegrityError(
            f"snapshot array {name!r} header mismatch: "
            f"{arr.dtype}{list(arr.shape)} != {spec['dtype']}{spec['shape']}",
            path=path, generation=generation, **_name_context(name),
        )
    actual = crc32c(arr)
    if actual != spec["crc32c"]:
        raise IntegrityError(
            f"snapshot array {name!r} failed checksum verification",
            path=path, generation=generation,
            expected_crc=spec["crc32c"], actual_crc=actual,
            **_name_context(name),
        )
    return arr


def _build_device_index(iid: str, imeta: dict[str, Any],
                        arrays: dict[str, np.ndarray], indptr: np.ndarray):
    """Rebuild one DeviceIndex straight from snapshot bytes — ``jnp.asarray``
    of the stored encodings, never the encoders."""
    import jax.numpy as jnp

    from ..core.executor import DeviceIndex
    from ..kernels import active as active_meta  # noqa: F401 (block ranges)

    src = np.repeat(
        np.arange(indptr.shape[0] - 1, dtype=np.int64), np.diff(indptr)
    )
    bmin = arrays.get(f"dev/{iid}/block_src_min")
    bmax = arrays.get(f"dev/{iid}/block_src_max")
    if bmin is None or bmax is None:
        bmin, bmax = active_meta.block_ranges(src)

    def col_for(name: str, cmeta: dict[str, Any]):
        base = f"dev/{iid}/{name}"
        out_dtype = np.dtype(cmeta["out_dtype"])
        if cmeta["kind"] == "dense":
            return DenseColumn(jnp.asarray(arrays[base + "/array"]))
        if cmeta["kind"] == "dict":
            return DictPackedColumn(
                jnp.asarray(arrays[base + "/words"]), int(cmeta["width"]),
                int(cmeta["count"]),
                jnp.asarray(arrays[base + "/dict"], dtype=out_dtype),
            )
        if cmeta["kind"] == "packed":
            return PackedColumn(
                jnp.asarray(arrays[base + "/words"]), int(cmeta["width"]),
                int(cmeta["count"]), out_dtype,
            )
        raise IntegrityError(
            f"snapshot device column {base!r} has unknown kind "
            f"{cmeta['kind']!r}", array=base, kind=cmeta["kind"],
        )

    dev_meta = imeta["device"]
    return DeviceIndex(
        indptr=jnp.asarray(indptr, dtype=jnp.int32),
        src_ids=jnp.asarray(src, dtype=jnp.int32),
        dst_col=col_for("__dst__", dev_meta["__dst__"]),
        degrees=jnp.asarray(np.diff(indptr), dtype=jnp.int32),
        measure_cols={
            name: col_for(name, cmeta)
            for name, cmeta in dev_meta.items() if name != "__dst__"
        },
        block_src_min=np.asarray(bmin, dtype=np.int32),
        block_src_max=np.asarray(bmax, dtype=np.int32),
    )


def restore_db(directory: str, generation: int | None = None,
               verify_reads: bool = True):
    """Rebuild a ``GQFastDatabase`` from snapshot generation ``generation``
    (default: latest). Every array file is checksum-verified and the rebuilt
    device columns are cross-checked against their encoded digests *before*
    the database object exists — on any mismatch this raises
    :class:`IntegrityError` and returns nothing. The integrity manifest is
    attached to the restored DB (``verify_reads`` additionally enables
    per-materialize decoded-view verification)."""
    import jax.numpy as jnp

    from ..core.engine import GQFastDatabase
    from ..core.executor import DeviceDB
    from ..core.fragments import ColumnFragments, FragmentIndex
    from ..core.schema import EntityTable, RelationshipTable, Schema

    _faults.fire("snapshot.load", directory=directory)
    if generation is None:
        generation = latest_generation(directory)
        if generation is None:
            raise FileNotFoundError(f"no snapshot generations in {directory}")
    gen_path = generation_path(directory, generation)
    manifest = read_manifest(gen_path)

    arrays = {
        name: _load_array(gen_path, name, spec, generation,
                          fault_site="snapshot.load")
        for name, spec in manifest["arrays"].items()
    }

    # --- schema -----------------------------------------------------------
    entities = {
        name: EntityTable(
            name, emeta["size"],
            {a: arrays[f"attr/{name}/{a}"] for a in emeta["attributes"]},
        )
        for name, emeta in manifest["schema"]["entities"].items()
    }
    relationships = {}
    for name, rmeta in manifest["schema"]["relationships"].items():
        iid = f"{name}.{rmeta['fk1']}"
        indptr = arrays[f"host/{iid}/indptr"]
        fk1_col = np.repeat(
            np.arange(indptr.shape[0] - 1, dtype=np.int64), np.diff(indptr)
        )
        cols = {rmeta["fk1"]: fk1_col,
                rmeta["fk2"]: arrays[f"host/{iid}/{rmeta['fk2']}/values"]}
        for m in rmeta["measures"]:
            cols[m] = arrays[f"host/{iid}/{m}/values"]
        relationships[name] = RelationshipTable(
            name, rmeta["fk1"], rmeta["fk2"],
            rmeta["entity1"], rmeta["entity2"], cols,
        )
    schema = Schema(entities, relationships)

    # --- host indexes + device store --------------------------------------
    host_indexes: dict[tuple[str, str], FragmentIndex] = {}
    dev: dict[tuple[str, str], Any] = {}
    for iid, imeta in manifest["indexes"].items():
        t, k = imeta["table"], imeta["key"]
        indptr = arrays[f"host/{iid}/indptr"]
        idx = FragmentIndex(t, k, imeta["key_entity"], indptr)
        for c, cmeta in imeta["columns"].items():
            idx.columns[c] = ColumnFragments(
                c, arrays[f"host/{iid}/{c}/values"], cmeta["domain"],
                cmeta["encoding"], cmeta["encoded_bytes"],
                packed=arrays.get(f"host/{iid}/{c}/packed"),
                packed_width=cmeta["packed_width"],
            )
        host_indexes[(t, k)] = idx
        dev[(t, k)] = _build_device_index(iid, imeta, arrays, indptr)

    attrs = {
        (e.name, a): jnp.asarray(col, dtype=jnp.float32)
        for e in schema.entities.values()
        for a, col in e.attributes.items()
    }
    device = DeviceDB(schema, dev, attrs, host_indexes)

    # final gate: the rebuilt device columns must hash to the digests the
    # snapshot recorded — catches writer/restorer layout drift, not just disk
    # corruption (file-level CRCs already verified above)
    digests = manifest.get("integrity", {})
    for (t, k), di in dev.items():
        for name, col in [("__dst__", di.dst_col), *di.measure_cols.items()]:
            dig = digests.get(f"I_{t}.{k}/{name}")
            if dig is None:
                continue
            actual = crc32c_parts(encoded_parts(col))
            if actual != dig["encoded_crc"]:
                raise IntegrityError(
                    f"restored column I_{t}.{k}/{name} does not match its "
                    "snapshot digest",
                    table=t, key=k, column=name, generation=generation,
                    expected_crc=dig["encoded_crc"], actual_crc=actual,
                )

    db = GQFastDatabase.from_parts(schema, host_indexes, device)
    attach_manifest(device, digests or None, verify_reads=verify_reads)
    return db


def load_column_arrays(directory: str, generation: int, table: str, key: str,
                       column: str) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Read (and checksum-verify) the encoded arrays of ONE device column
    from a snapshot — the scrubber's repair source. Returns (role → array,
    column meta). No fault site: heal reads must not be re-corrupted by the
    ``snapshot.load`` chaos spec aimed at full restores."""
    gen_path = generation_path(directory, generation)
    manifest = read_manifest(gen_path)
    iid = f"{table}.{key}"
    cmeta = manifest["indexes"][iid]["device"][column]
    base = f"dev/{iid}/{column}/"
    out = {
        name[len(base):]: _load_array(gen_path, name, spec, generation,
                                      fault_site=None)
        for name, spec in manifest["arrays"].items()
        if name.startswith(base)
    }
    if not out:
        raise IntegrityError(
            f"snapshot has no arrays for column I_{iid}/{column}",
            table=table, key=key, column=column, generation=generation,
        )
    return out, cmeta
