"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute via ``interpret=True`` (Pallas body run
as Python/XLA — the correctness validation mode mandated for this environment);
on TPU they run compiled. ``use_pallas=False`` selects the pure-XLA fallback
(identical math from :mod:`repro.kernels.ref`).

Frontier-sparsity dispatch (kernels/active.py): the four hop entries accept
``blocks=(src_min, src_max)`` per-block metadata and a ``block_skipping`` mode
('off' | 'on' | 'auto'). With metadata present and skipping engaged, the call
routes to the scalar-prefetch ``*_active`` kernel so only blocks whose src
range intersects the frontier's support are streamed. Two tiers:

  * **eager** (concrete frontier — kernel-level callers, benchmarks): the
    active list is computed in numpy, the capacity bucketed to a power of two,
    and the grid *really* shrinks; 'auto' bails back to the scan when the
    surviving fraction exceeds ``SKIP_BLOCK_FRACTION``.
  * **traced** (frontier is a jit tracer — the executor's compiled hop chain):
    the list is computed in-graph at full capacity (static shapes), inactive
    grid steps are ``pl.when``-guarded no-DMA no-ops; 'auto' wraps the choice
    in a runtime ``lax.cond`` on the surviving-block count.

Skipping is bit-identical to the scan for every op (skipped contributions are
the ⊕-identity); the XLA fallback always full-scans, which is the same result.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import active as _active
from . import ref
from ..obs import trace as _obs_trace
from ..robust import faults as _faults
from ..robust.errors import ValidationError
from .bitmap_ops import bitmap_and as _bitmap_and
from .bitmap_ops import bitmap_and_popcount as _bitmap_and_popcount
from .bitunpack import bitunpack as _bitunpack
from .fragment_spmm import fragment_spmm as _fragment_spmm
from .fragment_spmm import fragment_spmm_active as _fragment_spmm_active
from .fragment_spmm import fragment_spmm_packed as _fragment_spmm_packed
from .fragment_spmm import fragment_spmm_packed_active as _fragment_spmm_packed_active
from .fragment_spmv import IDENTITY as _IDENTITY
from .fragment_spmv import fragment_spmv as _fragment_spmv
from .fragment_spmv import fragment_spmv_active as _fragment_spmv_active
from .fragment_spmv_packed import fragment_spmv_packed as _fragment_spmv_packed
from .fragment_spmv_packed import (
    fragment_spmv_packed_active as _fragment_spmv_packed_active,
)

BLOCK_SKIPPING_MODES = ("off", "on", "auto")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _plan_skip(w, op: str, E: int, blocks, block_skipping: str):
    """Decide scan vs skip for one hop. ``None`` → full scan; otherwise
    ``(block_idx, n_active, mode)`` with mode 'static' (commit to the active
    kernel now) or 'cond' (traced 'auto': pick at runtime via lax.cond)."""
    if block_skipping not in BLOCK_SKIPPING_MODES:
        raise ValidationError(
            f"unknown block_skipping mode {block_skipping!r}",
            block_skipping=block_skipping, valid=BLOCK_SKIPPING_MODES,
        )
    if block_skipping == "off" or blocks is None or E == 0:
        return None
    nb = _active.n_edge_blocks(E)
    if nb <= 1 and block_skipping != "on":
        # nothing to skip on a 1-block index; 'on' still engages the active
        # kernel so small shapes exercise the real code path
        return None
    src_min, src_max = blocks
    zero = _IDENTITY[op]
    if isinstance(w, jax.core.Tracer):
        bi, na = _active.active_block_list(
            w, zero, jnp.asarray(src_min), jnp.asarray(src_max)
        )
        _obs_trace.annotate(skip_tier="traced", n_blocks=nb)
        return bi, na, ("cond" if block_skipping == "auto" else "static")
    support = np.asarray(w != zero)
    if support.ndim == 2:
        support = support.any(axis=0)
    bi, na, frac = _active.active_block_list_np(support, src_min, src_max)
    if block_skipping == "auto" and frac > _active.SKIP_BLOCK_FRACTION:
        _obs_trace.annotate(
            skip_tier="eager", skip_decision="scan", n_blocks=nb,
            active_blocks=int(na[0]), active_block_fraction=float(frac),
        )
        return None
    _obs_trace.annotate(
        skip_tier="eager", skip_decision="skip", n_blocks=nb,
        active_blocks=int(na[0]), active_block_fraction=float(frac),
    )
    return jnp.asarray(bi), jnp.asarray(na), "static"


def _skip_or_cond(plan, E: int, skip_fn, scan_fn):
    """Commit to the active kernel ('static') or build the runtime choice
    (traced 'auto'): lax.cond on the surviving-block count vs the
    SKIP_BLOCK_FRACTION threshold — both branches return identical values."""
    bi, na, mode = plan
    if mode == "static":
        return skip_fn(bi, na)
    thresh = max(1, int(_active.SKIP_BLOCK_FRACTION * _active.n_edge_blocks(E)))
    return jax.lax.cond(
        na[0] <= thresh, lambda: skip_fn(bi, na), scan_fn
    )


def bitunpack(packed, width: int, count: int, use_pallas: bool = True):
    if not use_pallas:
        return ref.bitunpack_ref(jnp.asarray(packed, jnp.uint32), width, count)
    return _bitunpack(jnp.asarray(packed, jnp.uint32), width, count, interpret=_interpret())


def fragment_spmv(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True,
                  blocks=None, block_skipping: str = "off"):
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if not use_pallas:
        return ref.fragment_spmv_ref(w, s, d, m, n_dst, op=op)
    _faults.fire("ops.fragment_spmv", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmv(w, s, d, m, n_dst, op=op, interpret=_interpret())
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmv_active(
            w, s, d, m, bi, na, n_dst, op=op, interpret=_interpret()
        ),
        scan,
    )


def fragment_spmm(weights, src_ids, dst_ids, measures, n_dst: int,
                  op: str = "sum", use_pallas: bool = True,
                  blocks=None, block_skipping: str = "off"):
    """Batched multi-query hop: ``Y[b, dst] ⊕= W[b, src] ⊗ m`` with one edge
    stream serving all B frontier rows (see fragment_spmm.py). ``measures``
    may be [E] (shared — the fused-kernel case) or [B, E] (per-row, e.g. a
    seed-scalar-dependent measure expression): per-row streams have no
    single-pass formulation and always take the XLA fallback, a vmap'd
    segment-combine."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst_ids, jnp.int32)
    m = jnp.asarray(measures, jnp.float32)
    if m.ndim == 2 or not use_pallas:
        return ref.fragment_spmm_ref(w, s, d, m, n_dst, op=op)
    _faults.fire("ops.fragment_spmm", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmm(w, s, d, m, n_dst, op=op, interpret=_interpret())
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmm_active(
            w, s, d, m, bi, na, n_dst, op=op, interpret=_interpret()
        ),
        scan,
    )


def fragment_spmm_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True,
                         blocks=None, block_skipping: str = "off"):
    """Decode-fused batched hop: packed dst/measure word streams decode once
    per 4096-edge block in VMEM and serve all B frontier rows."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmm_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    _faults.fire("ops.fragment_spmm_packed", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmm_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmm_packed_active(
            w, s, d, m, md, bi, na, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
        ),
        scan,
    )


def fragment_spmv_packed(weights, src_ids, dst, measure=None, mdict=None, *,
                         n_dst: int, dst_width: int = 0, m_mode: str = "none",
                         m_width: int = 0, op: str = "sum",
                         use_pallas: bool = True,
                         blocks=None, block_skipping: str = "off"):
    """Decode-fused hop: ``dst``/``measure`` may be BCA word streams that are
    unpacked block-at-a-time inside the SpMV (see fragment_spmv_packed.py)."""
    w = jnp.asarray(weights, jnp.float32)
    s = jnp.asarray(src_ids, jnp.int32)
    d = jnp.asarray(dst, jnp.uint32 if dst_width else jnp.int32)
    m = measure
    if m_mode == "dense":
        m = jnp.asarray(m, jnp.float32)
    elif m_mode in ("packed", "dict"):
        m = jnp.asarray(m, jnp.uint32)
    md = jnp.asarray(mdict, jnp.float32) if m_mode == "dict" else None
    if not use_pallas:
        return ref.fragment_spmv_packed_ref(
            w, s, d, m, md, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op,
        )
    _faults.fire("ops.fragment_spmv_packed", op=op, n_dst=n_dst)
    scan = lambda: _fragment_spmv_packed(
        w, s, d, m, md, n_dst, dst_width=dst_width,
        m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
    )
    plan = _plan_skip(w, op, s.shape[0], blocks, block_skipping)
    if plan is None:
        return scan()
    return _skip_or_cond(
        plan, s.shape[0],
        lambda bi, na: _fragment_spmv_packed_active(
            w, s, d, m, md, bi, na, n_dst, dst_width=dst_width,
            m_mode=m_mode, m_width=m_width, op=op, interpret=_interpret(),
        ),
        scan,
    )


def bitmap_and(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_ref(a, b)
    return _bitmap_and(a, b, interpret=_interpret())


def bitmap_and_popcount(a, b, use_pallas: bool = True):
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_pallas:
        return ref.bitmap_and_popcount_ref(a, b)
    return _bitmap_and_popcount(a, b, interpret=_interpret())
