"""Checkpoint manager + fault-tolerant train loop tests."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.data.lm_data import lm_batch
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.train.loop import TrainLoopConfig, train

CFG = TransformerConfig("t", 2, 64, 4, 2, 128, 211, d_head=16, remat=False,
                        attn_kv_chunk=32)


@pytest.fixture()
def tmp(tmp_path):
    return str(tmp_path)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.key(0))


def _lf(p, b):
    return loss_fn(p, b, CFG)


def _data(s):
    return lm_batch(s, 8, 32, 211, seed=1)


def test_save_restore_roundtrip(tmp, params):
    mgr = CheckpointManager(tmp, keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4)), "d": [jnp.zeros(2)]}}
    mgr.save(5, tree)
    restored, meta = mgr.restore(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp):
    mgr = CheckpointManager(tmp, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    assert mgr.list_steps() == [3, 4]


def test_atomicity_no_partial_dirs(tmp):
    mgr = CheckpointManager(tmp, keep=3)
    mgr.save(1, {"x": jnp.ones(3)})
    names = os.listdir(tmp)
    assert all(not n.startswith(".tmp_ckpt_") for n in names)


def test_restore_resharding_elastic(tmp):
    """Save on the default device; restore with an explicit 1-device mesh
    sharding (the elastic-restart path at CPU scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp, keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.spec == P("data", None)


def test_loss_decreases(tmp, params):
    lc = TrainLoopConfig(total_steps=20, ckpt_every=100, ckpt_dir=tmp)
    oc = AdamWConfig(lr=cosine_warmup(3e-3, 3, 20), weight_decay=0.01)
    _, res = train(params, _lf, _data, lc, oc, resume=False)
    assert res.history[-1]["loss"] < res.history[0]["loss"]
    assert res.step == 20


def test_preempt_resume_bit_identical(tmp, params):
    lc = TrainLoopConfig(total_steps=14, ckpt_every=100, ckpt_dir=tmp)
    oc = AdamWConfig(lr=1e-3)
    pA, _ = train(params, _lf, _data, lc, oc, resume=False)
    shutil.rmtree(tmp)
    _, r1 = train(params, _lf, _data, lc, oc, resume=False, preempt_at=7)
    assert r1.preempted and r1.step == 7
    pB, r2 = train(params, _lf, _data, lc, oc, resume=True)
    assert r2.resumed_from == 7
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_moments_match_fp32_convergence(tmp, params):
    lcs = TrainLoopConfig(total_steps=10, ckpt_every=100, ckpt_dir=tmp)
    losses = {}
    for name, oc in [("fp32", AdamWConfig(lr=3e-3)),
                     ("int8", AdamWConfig(lr=3e-3, quantize_moments=True))]:
        shutil.rmtree(tmp, ignore_errors=True)
        _, res = train(params, _lf, _data, lcs, oc, resume=False)
        losses[name] = res.history[-1]["loss"]
    assert abs(losses["int8"] - losses["fp32"]) / losses["fp32"] < 0.05


def test_straggler_telemetry_fields(tmp, params):
    lc = TrainLoopConfig(total_steps=5, ckpt_every=100, ckpt_dir=tmp)
    _, res = train(params, _lf, _data, lc, AdamWConfig(lr=1e-3), resume=False)
    for rec in res.history:
        assert set(rec) >= {"step", "loss", "grad_norm", "step_time", "straggler"}
