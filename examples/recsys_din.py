"""DIN recsys: train on a synthetic click stream, then run the retrieval
shape (one user scored against many candidates).

    PYTHONPATH=src python examples/recsys_din.py
"""
import time

import jax
import numpy as np

from repro.data.recsys import make_din_batch
from repro.models.din import DINConfig, din_init, din_loss, din_retrieval_scores
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train


def main() -> None:
    cfg = DINConfig(n_items=100_000, n_users=10_000, n_cates=1_000, seq_len=50)
    params = din_init(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"DIN: {n/1e6:.1f}M params (embedding tables dominate)")

    params, res = train(
        params,
        lambda p, b: din_loss(p, b, cfg),
        lambda step: make_din_batch(256, seq_len=50, n_items=cfg.n_items,
                                    n_users=cfg.n_users, seed=step % 16),
        TrainLoopConfig(total_steps=40, ckpt_every=1000, ckpt_dir="/tmp/repro_din_ckpt"),
        AdamWConfig(lr=3e-3, weight_decay=0.0),
        resume=False,
    )
    hist = res.history
    for rec in hist[::8]:
        print(f"  step {rec['step']:3d} loss {rec['loss']:.4f}")

    # retrieval: 1 user × 100k candidates, batched dot-style scoring (no loop)
    rb = make_din_batch(1, seq_len=50, n_items=cfg.n_items, n_users=cfg.n_users,
                        n_candidates=100_000, seed=99)
    f = jax.jit(lambda p, b: din_retrieval_scores(p, b, cfg))
    scores = np.asarray(f(params, rb))  # compile + run
    t0 = time.perf_counter()
    scores = np.asarray(f(params, rb))
    dt = time.perf_counter() - t0
    top = np.argsort(-scores)[:5]
    print(f"retrieval: scored 100k candidates in {dt*1e3:.1f} ms "
          f"({1e5/dt/1e6:.1f}M cand/s); top-5 items: {top.tolist()}")


if __name__ == "__main__":
    main()
